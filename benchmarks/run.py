"""Benchmark harness — one benchmark per paper table/figure, plus the
EstimationEngine sweep that feeds the perf trajectory.

``--suite paper`` (default) prints ``name,us_per_call,derived`` CSV rows
(derived = the figure's claim, see each docstring). ``--suite estimation``
runs the ``estimation_backends`` sweep — every EstimationEngine
(method, backend) cell timed on one summary, spectral error measured against
the two-pass LELA baseline — and writes machine-readable
``BENCH_estimation.json`` (``--out``). ``--suite streaming`` runs the
``streaming_sweep`` — chunk-size x ingestion-mode cells (sequential /
tree-merge / shuffled-rows StreamingSummarizer vs the one-shot backends)
with parity errors — and writes ``BENCH_streaming.json``
(``--out-streaming``). ``--suite error`` runs the ``error_sweep`` —
estimated-vs-true residual across rank x probe-count cells plus the
``adaptive_rank`` tolerance sweep — and writes ``BENCH_error.json``
(``--out-error``). ``--suite serving`` runs the ``serving_sweep`` —
cold-vs-warm ``SketchService`` plans through the compile-once
PipelineEngine (per-request latency, trace counts, executable-cache hits
for fixed-rank, with-error, and quality-gated plans) — and writes
``BENCH_serving.json`` (``--out-serving``). ``--suite traffic`` runs the
``traffic_sweep`` — Poisson arrivals x shape-mix x tenant-mix through the
continuously-batched ``ServingLoop`` (requests/sec, p50/p99 latency, batch
occupancy, shed rate) — and merges its report into the same
``BENCH_serving.json`` under the ``"traffic"`` key. ``--suite kernels``
runs the ``kernel_sweep`` — every tunable Pallas kernel x shape x
KernelConfig cell (the roofline ranking head plus the frozen default),
recording us/call, achieved GB/s against the model's HBM-byte count, and
the static cost terms — and writes ``BENCH_kernels.json``
(``--out-kernels``). Every suite stamps a ``meta`` block (git sha, jax
version, backend, smoke flag) into its JSON so ``tools/bench_compare.py``
can refuse cross-backend comparisons; ``--smoke`` shrinks sizes for CI.

Real datasets (SIFT10K/NIPS-BW/URL) are not redistributable offline;
spectrum-matched synthetic stand-ins validate the paper's *relative* claims
(orderings/ratios/trends). CPU container: absolute wall times are
CPU-relative; ratios are the signal.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import subprocess
import time
import zlib

import jax
import jax.numpy as jnp

from repro import core
from repro.core import estimator as est

_REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _meta(smoke: bool) -> dict:
    """Provenance block every BENCH_*.json carries: which commit, which jax,
    which device backend, and whether sizes were smoke-reduced. This is what
    lets tools/bench_compare.py refuse apples-to-oranges comparisons."""
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=_REPO, text=True,
            capture_output=True, timeout=10).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        sha = "unknown"
    return {
        "git_sha": sha,
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "smoke": bool(smoke),
    }


def _timed(fn, *args, reps=1, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) / reps * 1e6


def _gd_pair(key, d, n, corr=None, decay=1.0):
    kA, kB = jax.random.split(key)
    D = jnp.diag(1.0 / jnp.arange(1.0, n + 1.0) ** decay)
    A = jax.random.normal(kA, (d, n)) @ D
    B = A + corr * jax.random.normal(kB, (d, n)) @ D if corr is not None \
        else jax.random.normal(kB, (d, n)) @ D
    return A, B


def _cone_pair(key, d, n, theta):
    """Unit vectors from a cone of angle theta (paper Fig 2b construction)."""
    kx, kt, ks = jax.random.split(key, 3)
    x = jax.random.normal(kx, (d, 1))
    x = x / jnp.linalg.norm(x)
    t = jax.random.normal(kt, (d, 2 * n)) * (math.tan(theta / 2) / math.sqrt(d))
    sign = jnp.where(jax.random.bernoulli(ks, 0.5, (2 * n,)), 1.0, -1.0)
    y = (x + t) * sign[None, :]
    y = y / jnp.linalg.norm(y, axis=0)
    return y[:, :n], y[:, n:]


def _err(A, B, factors):
    return float(core.spectral_error(A, B, factors))


# ---------------------------------------------------------------------------

def fig2a_rescaled_jl(key):
    """Fig 2(a): rescaled-JL dot products have lower MSE than plain JL
    (paper: 0.053 vs 0.129 at d=1000, k=10). derived = mse_plain/mse_resc."""
    d, k, npairs = 1000, 10, 512
    kx, kt, ks, ka = jax.random.split(key, 4)
    x = jax.random.normal(kx, (d, npairs))
    x = x / jnp.linalg.norm(x, axis=0)
    # paper construction: y = x + t, E||t|| = tan(theta/2), theta ~ U(0.1, 3)
    theta = jax.random.uniform(ka, (npairs,), minval=0.1, maxval=3.0)
    t = jax.random.normal(kt, (d, npairs)) / math.sqrt(d) *         jnp.tan(theta / 2)[None, :]
    y = x + t
    y = y / jnp.linalg.norm(y, axis=0)
    true = jnp.sum(x * y, axis=0)

    def run():
        s = core.build_summary(ks, x, y, k)
        idx = jnp.arange(npairs)
        return (est.rescaled_entries(s, idx, idx),
                est.plain_jl_entries(s, idx, idx))

    (resc, plain), us = _timed(run)
    mse_r = float(jnp.mean((resc - true) ** 2))
    mse_p = float(jnp.mean((plain - true) ** 2))
    return us, mse_p / mse_r, f"mse_resc={mse_r:.4f} mse_plain={mse_p:.4f}"


def fig2b_cone(key):
    """Fig 2(b): ||A^TB - A~^TB~|| / ||A^TB - M~|| >= 1, growing as the cone
    angle shrinks. derived = ratio at theta=0.2rad."""
    d, n, k = 1000, 120, 32
    ratios = {}
    us_tot = 0.0
    for theta in (0.2, 0.8, 2.0):
        A, B = _cone_pair(jax.random.fold_in(key, int(theta * 10)), d, n, theta)
        M = A.T @ B

        def run():
            s = core.build_summary(key, A, B, k)
            plain = s.A_sketch.T @ s.B_sketch
            resc = est.rescaled_matrix(s)
            return (jnp.linalg.norm(M - plain, ord=2),
                    jnp.linalg.norm(M - resc, ord=2))
        (e_plain, e_resc), us = _timed(run)
        us_tot += us
        ratios[theta] = float(e_plain) / max(float(e_resc), 1e-12)
    notes = " ".join(f"theta={t}:ratio={r:.2f}" for t, r in ratios.items())
    return us_tot, ratios[0.2], notes


def fig3a_runtime(key):
    """Fig 3(a): one-pass SMP-PCA vs two-pass LELA wall time (paper: ~2x from
    halved IO passes; here both matrices are in memory so the ratio reflects
    compute only — passes over data are 1 vs 2 by construction)."""
    d, n, r = 16384, 768, 5
    A, B = _gd_pair(key, d, n, corr=0.3)
    m = int(4 * n * r * math.log(n))
    _, us_smp = _timed(lambda: core.smppca(key, A, B, r=r, k=256, m=m, T=5),
                       reps=1)
    _, us_lela = _timed(lambda: core.lela(key, A, B, r=r, m=m, T=5), reps=1)
    return us_smp, us_lela / us_smp, \
        f"smp_ms={us_smp/1e3:.0f} lela_ms={us_lela/1e3:.0f} passes=1v2"


def fig3b_error_vs_k(key):
    """Fig 3(b): SMP-PCA error decreases with k and beats SVD(A~^T B~)
    (paper: 1.8x on SIFT10K, 1.1x on NIPS-BW). Synthetic stand-in:
    SIFT-like dense image-by-feature matrix, A=B (PCA task)."""
    r = 5
    kk = jax.random.fold_in(key, 1)
    feats = jax.random.normal(kk, (2000, 128)) @ \
        jnp.diag(1.0 / jnp.arange(1.0, 129.0) ** 0.7)
    A_s = feats
    m = int(10 * 128 * r * math.log(128))
    errs = {}
    us_tot = 0.0
    for k in (64, 128, 256):
        res, us = _timed(lambda k=k: core.smppca(
            kk, A_s, A_s, r=r, k=k, m=m, T=6))
        us_tot += us
        errs[k] = _err(A_s, A_s, res.factors)
    sf, _ = _timed(lambda: core.sketch_svd(kk, A_s, A_s, r=r, k=128))
    e_svd = _err(A_s, A_s, sf)
    mono = errs[64] >= errs[256]
    return us_tot, e_svd / errs[128], \
        (f"err@k64={errs[64]:.3f} k128={errs[128]:.3f} k256={errs[256]:.3f} "
         f"sketchsvd@128={e_svd:.3f} monotone={mono}")


def table1_errors(key):
    """Table 1: Optimal <= LELA <= SMP-PCA with small gaps (synthetic GD).
    derived = err_smppca / err_optimal."""
    d, n, r, k = 2000, 1000, 5, 512
    # CPU-scale note: the paper's synthetic is n=d=1e5 where the Remark-2
    # ratio ||A||*||B||/||A^TB||_F is benign; at n=1e3 the independent case
    # is eta-divergent, so we add mild correlation (URL datasets are
    # correlated cross-covariances too). See EXPERIMENTS.md.
    A, B = _gd_pair(key, d, n, corr=0.5)
    m = int(10 * n * r * math.log(n))
    res, us = _timed(lambda: core.smppca(key, A, B, r=r, k=k, m=m, T=6))
    e_smp, e_opt = core.spectral_error_vs_optimal(A, B, r, res.factors)
    lf, _ = _timed(lambda: core.lela(key, A, B, r=r, m=m, T=6))
    e_lela = _err(A, B, lf)
    return us, float(e_smp) / float(e_opt), \
        (f"optimal={float(e_opt):.4f} lela={e_lela:.4f} "
         f"smppca={float(e_smp):.4f}")


def fig4a_phase(key):
    """Fig 4(a): phase transition at m = Theta(nr log n).
    derived = err(m=0.5x) / err(m=4x)."""
    d, n, r = 1000, 400, 3
    kU, kV = jax.random.split(key)
    A = jax.random.normal(kU, (d, n))
    B = (A @ jax.random.normal(kV, (n, r)) @ jax.random.normal(
        jax.random.fold_in(kV, 1), (r, n)) / n
         + 0.01 * jax.random.normal(jax.random.fold_in(kV, 2), (d, n)))
    base = n * r * math.log(n)
    errs = {}
    us_tot = 0.0
    for mult in (0.5, 1.0, 4.0):
        m = int(mult * base)
        lf, us = _timed(lambda m=m: core.lela(key, A, B, r=r, m=m, T=8))
        us_tot += us
        errs[mult] = _err(A, B, lf)
    return us_tot, errs[0.5] / errs[4.0], \
        " ".join(f"{mu}x:{e:.3f}" for mu, e in errs.items())


def fig4b_cone_full(key):
    """Fig 4(b): full-pipeline (sampling+ALS) error ratio SVD(A~^TB~)/SMP-PCA
    grows as the cone angle shrinks."""
    d, n, r, k = 1000, 150, 3, 64
    out = {}
    us_tot = 0.0
    m = int(10 * n * r * math.log(n))
    for theta in (0.2, 1.0):
        A, B = _cone_pair(jax.random.fold_in(key, int(theta * 10)), d, n, theta)
        res, us = _timed(lambda A=A, B=B: core.smppca(
            key, A, B, r=r, k=k, m=m, T=6))
        us_tot += us
        sf, _ = _timed(lambda A=A, B=B: core.sketch_svd(key, A, B, r=r, k=k))
        out[theta] = _err(A, B, sf) / max(_err(A, B, res.factors), 1e-9)
    return us_tot, out[0.2], \
        " ".join(f"theta={t}:ratio={v:.2f}" for t, v in out.items())


def fig4c_orthogonal(key):
    """Fig 4(c): A_r^T B_r fails when per-matrix top subspaces are orthogonal
    while the product's signal lives in shared lower directions."""
    d, n, r = 600, 60, 3
    kq, kn = jax.random.split(key)
    Q, _ = jnp.linalg.qr(jax.random.normal(kq, (d, 3 * r)))
    CA = jax.random.normal(jax.random.fold_in(key, 1), (r, n))
    CB = jax.random.normal(jax.random.fold_in(key, 2), (r, n))
    SA = jax.random.normal(jax.random.fold_in(key, 3), (r, n))
    SB = jax.random.normal(jax.random.fold_in(key, 4), (r, n))
    A = 3.0 * Q[:, :r] @ CA + 1.5 * Q[:, 2 * r:] @ SA + \
        0.05 * jax.random.normal(kn, (d, n))
    B = 3.0 * Q[:, r:2 * r] @ CB + 1.5 * Q[:, 2 * r:] @ SB + \
        0.05 * jax.random.normal(jax.random.fold_in(kn, 1), (d, n))
    m = int(14 * n * r * math.log(n))
    pp, us = _timed(lambda: core.product_of_pcas(key, A, B, r))
    e_pp = _err(A, B, pp)
    res, _ = _timed(lambda: core.smppca(key, A, B, r=r, k=512, m=m, T=6))
    e_smp = _err(A, B, res.factors)
    return us, e_pp / e_smp, f"ArBr={e_pp:.3f} smppca={e_smp:.3f}"


def grad_compression(key):
    """Beyond-paper §3 integration: tap-path (X, dY sketches) vs A=I baseline
    gradient compression quality. derived = cosine(tap reconstruction, true
    grad); notes include the A=I baseline cosine — the gap shows why the
    paper's side information (true column norms + low stable rank) matters."""
    from repro.train import sketched_dense as sd
    from repro.optim import grad_compression as gc
    n_in, n_out, T = 256, 1024, 8192
    kw, kx, kz, kp1, kp2 = jax.random.split(key, 5)
    w_true = jax.random.normal(kw, (n_in, n_out)) * 0.05
    pert = (jax.random.normal(kp1, (n_in, 6)) @
            jax.random.normal(kp2, (6, n_out))) * 0.02
    w = w_true + pert
    z = jax.random.normal(kz, (8, T // 8, 16))
    E = jax.random.normal(jax.random.fold_in(kx, 1), (16, n_in))
    x = z @ E + 0.05 * jax.random.normal(kx, (8, T // 8, n_in))
    target = x @ w_true
    taps = sd.tap_init(n_in, n_out, 128)

    def loss_fn(w, taps, x):
        return jnp.mean((sd.sketched_dense(w, taps, x, key, 128, 1024)
                         - target) ** 2)

    def run():
        _, dtaps, _ = jax.grad(loss_fn, argnums=(0, 1, 2))(w, taps, x)
        return sd.decompress_tap(key, dtaps, sd.TapConfig(sketch_k=128, rank=8))

    ghat, us = _timed(run)
    dw_true = jax.grad(lambda w: jnp.mean((x @ w - target) ** 2))(w)
    cos_t = float(jnp.sum(dw_true * ghat) /
                  (jnp.linalg.norm(dw_true) * jnp.linalg.norm(ghat)))
    ghat2 = gc.compress_leaf(key, dw_true,
                             gc.CompressionConfig(rank=8, sketch_k=128))
    cos_b = float(jnp.sum(dw_true * ghat2) /
                  (jnp.linalg.norm(dw_true) * jnp.linalg.norm(ghat2)))
    comm = (128 * (n_in + n_out) + n_in + n_out) / (n_in * n_out)
    return us, cos_t, f"cos_taps={cos_t:.3f} cos_AeqI={cos_b:.3f} comm={comm:.3f}"


def kernel_sketch_fused(key):
    """Fused Pallas sketch kernel vs oracle (interpret mode: correctness;
    derived = max abs err vs pure-jnp reference)."""
    from repro.kernels import ops, ref
    Pi = jax.random.normal(key, (128, 2048))
    A = jax.random.normal(jax.random.fold_in(key, 1), (2048, 512))
    (out, norms), us = _timed(lambda: ops.sketch_fused(Pi, A))
    out_r, n2 = ref.sketch_fused_ref(Pi, A)
    err = float(jnp.max(jnp.abs(out - out_r)))
    return us, err, "interpret-mode correctness"


def summary_backends(key):
    """SummaryEngine backend sweep on one (d, n) pair: per-backend wall time
    plus the worst cross-backend deviation from the reference summary
    (derived = that max parity error; the engine's contract says it is float
    reassociation only)."""
    d, n, k = 8192, 256, 128
    A, B = _gd_pair(key, d, n, corr=0.3)
    ref_s = core.build_summary(key, A, B, k, backend="reference")
    times, err = {}, 0.0
    for backend in ("reference", "scan", "pallas"):
        s, us = _timed(lambda b=backend: core.build_summary(
            key, A, B, k, backend=b, block=1024), reps=3)
        times[backend] = us
        err = max(err, float(jnp.max(jnp.abs(s.A_sketch - ref_s.A_sketch))))
    notes = " ".join(f"{b}_ms={t/1e3:.1f}" for b, t in times.items())
    return times["scan"], err, notes


def estimation_backends(key, *, smoke: bool = False) -> dict:
    """EstimationEngine sweep: every (method, backend) cell on ONE summary.

    Times ``estimate_product`` per cell and measures spectral error against
    the exact-entry two-pass baseline (LELA = biased sample + exact pass +
    WAltMin) — the record the acceptance gate reads: backend='jit' must beat
    the reference Python-loop WAltMin on wall time.

    Also sweeps the refined-reconstruction cells (``refinement/<method>/
    iters<i>/r<rank>``): spectral error vs rank x iters x method from a
    co-sketch-carrying summary of the same pair, so ``tools/bench_compare``
    tracks refinement accuracy (``spectral_error`` is gated lower-is-better)
    alongside wall time across commits.
    """
    if smoke:
        d, n, r, k, m, T = 1024, 64, 3, 64, 1200, 4
    else:
        d, n, r, k, m, T = 8192, 256, 5, 256, 6000, 8
    A, B = _gd_pair(key, d, n, corr=0.3)
    summary = core.build_summary(key, A, B, k, backend="reference")
    jax.block_until_ready(summary)

    # two-pass baseline: same sampler + WAltMin but exact entries
    base_f, base_us = _timed(
        lambda: core.lela(key, A, B, r=r, m=m, T=T), reps=1)
    base_err = _err(A, B, base_f)
    baseline = {"name": "lela_two_pass", "us_per_call": base_us,
                "spectral_error": base_err}

    cells = [
        ("rescaled_jl", "reference"), ("rescaled_jl", "jit"),
        ("rescaled_jl", "pallas"),
        ("lela_waltmin", "jit"),
        ("direct_svd", "reference"), ("direct_svd", "jit"),
    ]
    results = []
    for method, backend in cells:
        exact = (A, B) if method == "lela_waltmin" else None
        reps = 3 if backend == "jit" and not smoke else 1

        def run(method=method, backend=backend, exact=exact):
            out = core.estimate_product(
                key, summary, r, method=method, backend=backend, m=m, T=T,
                exact_pair=exact)
            return out.factors

        factors, us = _timed(run, reps=reps)
        results.append({
            "name": f"{method}/{backend}",
            "us_per_call": us,
            "spectral_error": _err(A, B, factors),
            "baseline_spectral_error": base_err,
        })

    # refinement sweep: spectral error vs rank x iters x method, from a
    # co-sketch-carrying summary of the same pair (s = 2r exact columns)
    s_width = 2 * r
    summary_c = core.build_summary(key, A, B, k, backend="reference",
                                   cosketch=s_width)
    jax.block_until_ready(summary_c)
    for rank in (max(2, r // 2), r):
        for ref_method, iters in (("tropp", 0), ("power", 1), ("power", 2)):
            spec = core.RefineSpec(iters=iters, method=ref_method)

            def run_refined(rank=rank, spec=spec):
                out = core.estimate_product(
                    key, summary_c, rank, method="power", backend="jit",
                    refine=spec)
                return out.factors

            factors, us = _timed(run_refined, reps=1)
            results.append({
                "name": f"refinement/{ref_method}/iters{iters}/r{rank}",
                "us_per_call": us,
                "spectral_error": _err(A, B, factors),
                "baseline_spectral_error": base_err,
            })

    times = {rec["name"]: rec["us_per_call"] for rec in results}
    return {
        "suite": "estimation_backends",
        "meta": _meta(smoke),
        "config": {"d": d, "n": n, "r": r, "k": k, "m": m, "T": T,
                   "cosketch": s_width,
                   "smoke": smoke, "backend_platform": jax.default_backend()},
        "baseline": baseline,
        "results": results,
        "jit_speedup_vs_reference":
            times["rescaled_jl/reference"] / times["rescaled_jl/jit"],
    }


def streaming_sweep(key, *, smoke: bool = False) -> dict:
    """Streaming ingestion sweep: chunk-size x ingestion-mode on one pair.

    Modes per method: ``one_shot/{reference,scan}`` (the in-memory baselines),
    ``sequential/chunk<c>`` (StreamingSummarizer, contiguous chunks),
    ``tree_merge/chunk<c>`` (independent per-chunk partial states reduced
    pairwise — the distributed/Spark shape), and ``shuffled_rows/chunk<c>``
    (arbitrary-order arrival via ``update_rows``). Every cell records wall
    time, ingested rows/s, and max deviation from the reference summary —
    the monoid contract says the deviation is float reassociation only.
    """
    if smoke:
        d, n, k = 4096, 64, 64
        chunks = (512, 1024)
    else:
        d, n, k = 32768, 256, 128
        chunks = (1024, 4096, 16384)
    A, B = _gd_pair(key, d, n, corr=0.3)
    results = []
    max_err = 0.0

    def record(name, us, summary, ref):
        nonlocal max_err
        err = float(jnp.max(jnp.abs(summary.A_sketch - ref.A_sketch)))
        max_err = max(max_err, err)
        results.append({"name": name, "us_per_call": us,
                        "rows_per_s": d / us * 1e6,
                        "max_err_vs_reference": err})

    refs = {}
    for method in ("gaussian", "srht"):
        ref, us = _timed(lambda m=method: core.build_summary(
            key, A, B, k, method=m, backend="reference"))
        refs[method] = ref
        record(f"{method}/one_shot/reference", us, ref, ref)
        s, us = _timed(lambda m=method: core.build_summary(
            key, A, B, k, method=m, backend="scan", block=chunks[-1]))
        record(f"{method}/one_shot/scan", us, s, ref)

        summ = core.StreamingSummarizer(k, method=method)
        for chunk in chunks:
            def sequential(chunk=chunk, summ=summ):
                st = summ.init(key, (d, n, n))
                for off in range(0, d, chunk):
                    st = summ.update(st, A[off:off + chunk],
                                     B[off:off + chunk], off)
                return summ.finalize(st)
            s, us = _timed(sequential)
            record(f"{method}/sequential/chunk{chunk}", us, s, ref)

            def tree(chunk=chunk, summ=summ):
                empty = summ.init(key, (d, n, n))
                parts = [summ.update(empty, A[off:off + chunk],
                                     B[off:off + chunk], off)
                         for off in range(0, d, chunk)]
                return summ.finalize(core.tree_merge(parts))
            s, us = _timed(tree)
            record(f"{method}/tree_merge/chunk{chunk}", us, s, ref)

    # arbitrary-order arrival (gaussian; same contract for srht)
    summ = core.StreamingSummarizer(k)
    ref = refs["gaussian"]
    perm = jax.random.permutation(key, d)
    chunk = chunks[0]

    def shuffled():
        st = summ.init(key, (d, n, n))
        for off in range(0, d, chunk):
            ids = perm[off:off + chunk]
            st = summ.update_rows(st, ids, A[ids], B[ids])
        return summ.finalize(st)
    s, us = _timed(shuffled)
    record(f"gaussian/shuffled_rows/chunk{chunk}", us, s, ref)

    results += _drift_cells(key, smoke=smoke)

    return {
        "suite": "streaming",
        "meta": _meta(smoke),
        "config": {"d": d, "n": n, "k": k, "chunks": list(chunks),
                   "smoke": smoke, "backend_platform": jax.default_backend()},
        "results": results,
        "max_parity_error": max_err,
    }


def _drift_cells(key, *, smoke: bool) -> list:
    """Drift cells: piecewise-stationary spectrum flip, three summary
    policies.

    Five epochs of rows; epochs 0-2 carry ``A^T B = M1`` (top subspace U1,
    8x mass), epochs 3-4 flip to ``M2`` (U2 ⟂ U1, 4x mass). Each policy
    ingests the same stream — vanilla (cumulative), decayed (gamma=0.5, one
    tick per epoch), windowed (2-epoch ring, one slide per epoch) — and
    ``tracking_error`` is the spectral residual of the final estimate's
    top-q left subspace against the CURRENT phase's U2 (lower is better;
    gated by tools/bench_compare.py). The monoid contract says vanilla
    stays pinned to the heavier U1 while the forgetting policies track the
    flip — the drift claim of docs/streaming.md, measured.
    """
    if smoke:
        d_e, n1, n2, q, k = 512, 24, 16, 4, 96
    else:
        d_e, n1, n2, q, k = 2048, 48, 32, 6, 192
    n_phase1, n_phase2 = 3, 2
    epochs = n_phase1 + n_phase2

    kU, kV1, kV2, kW = jax.random.split(key, 4)
    U_all, _ = jnp.linalg.qr(jax.random.normal(kU, (n1, 2 * q)))
    U1, U2 = U_all[:, :q], U_all[:, q:]
    V1, _ = jnp.linalg.qr(jax.random.normal(kV1, (n2, q)))
    V2, _ = jnp.linalg.qr(jax.random.normal(kV2, (n2, q)))
    M = {1: 8.0 * U1 @ V1.T, 2: 4.0 * U2 @ V2.T}
    stream = []
    for e in range(epochs):
        W, _ = jnp.linalg.qr(jax.random.normal(
            jax.random.fold_in(kW, e), (d_e, n1)))
        phase = 1 if e < n_phase1 else 2
        stream.append((W, W @ M[phase]))

    def tracking_error(summary):
        E = summary.A_sketch.T @ summary.B_sketch
        Uh = jnp.linalg.svd(E, full_matrices=False)[0][:, :q]
        return float(jnp.linalg.norm(U2 - Uh @ (Uh.T @ U2), 2))

    def vanilla():
        summ = core.StreamingSummarizer(k)
        st = summ.init(key, (epochs * d_e, n1, n2))
        for e, (A_e, B_e) in enumerate(stream):
            st = summ.update(st, A_e, B_e, e * d_e)
        return summ.finalize(st)

    def decayed():
        summ = core.StreamingSummarizer(k, decay=0.5)
        st = summ.init(key, (epochs * d_e, n1, n2))
        for e, (A_e, B_e) in enumerate(stream):
            if e:
                st = summ.advance(st)
            st = summ.update(st, A_e, B_e, e * d_e)
        return summ.finalize(st)

    def windowed():
        win = core.WindowedSummarizer(k, 2)
        w = win.init(key, (d_e, n1, n2))
        for e, (A_e, B_e) in enumerate(stream):
            if e:
                w = win.slide(w)
            w = win.update(w, A_e, B_e, 0)
        return win.finalize(w)

    cells = []
    for name, fn in (("drift/vanilla", vanilla),
                     ("drift/decay0.5", decayed),
                     ("drift/window2", windowed)):
        s, us = _timed(fn)
        cells.append({"name": name, "us_per_call": us,
                      "rows_per_s": epochs * d_e / us * 1e6,
                      "tracking_error": tracking_error(s)})
    return cells


def error_sweep(key, *, smoke: bool = False) -> dict:
    """ErrorEngine sweep: estimated vs true residual across rank x probes.

    One known-spectrum pair; for every probe count p the summary is rebuilt
    (probes ride the same single pass) and for every rank r the full
    ``estimate_product(..., with_error=True)`` pipeline runs — each cell
    records the a-posteriori Frobenius estimate, the exact residual
    (materialized here for validation only), their ratio, and the CI hit.
    The final records sweep ``adaptive_rank`` tolerances: chosen rank +
    whether the estimate met the gate. The acceptance gate reads the
    ratios: every cell must sit within 2x of the truth.
    """
    if smoke:
        d, n, k, T = 1024, 48, 64, 3
        ranks, probe_counts, tols = (2, 4, 8), (8, 32), (0.5, 0.2)
    else:
        d, n, k, T = 8192, 192, 256, 6
        ranks, probe_counts, tols = (2, 5, 10, 20), (4, 16, 64), (0.5, 0.2)
    A, B = _gd_pair(key, d, n, corr=0.3, decay=0.8)
    M = A.T @ B
    m_frob = float(jnp.linalg.norm(M))
    results = []
    for p in probe_counts:
        summary = core.build_summary(key, A, B, k, backend="scan", probes=p)
        jax.block_until_ready(summary)
        for r in ranks:
            def run(r=r, summary=summary):
                return core.estimate_product(
                    jax.random.fold_in(key, 1), summary, r,
                    m=int(6 * n * r * math.log(n)), T=T, with_error=True)
            est, us = _timed(run)
            true = float(jnp.linalg.norm(M - est.factors.dense()))
            results.append({
                "name": f"r{r}/p{p}",
                "r": r, "probes": p, "us_per_call": us,
                "frob_true": true,
                "frob_est": float(est.error.frob_est),
                "ratio_est_over_true": float(est.error.frob_est) / true,
                "rel_est": float(est.error.rel_est),
                "rel_true": true / m_frob,
                "ci_covers_true": bool(float(est.error.frob_lo) <= true
                                       <= float(est.error.frob_hi)),
            })
    adaptive = []
    summary = core.build_summary(key, A, B, k, backend="scan",
                                 probes=probe_counts[-1])
    for tol in tols:
        def run(tol=tol):
            return core.adaptive_rank(summary, tol=tol, r_max=max(ranks))
        res, us = _timed(run)
        true = float(jnp.linalg.norm(M - res.factors.dense())) / m_frob
        adaptive.append({"tol": tol, "r": res.r, "us_per_call": us,
                         "rel_est": float(res.error.rel_est),
                         "rel_true": true,
                         "met": bool(res.error.rel_est <= tol)})
    ratios = [rec["ratio_est_over_true"] for rec in results]
    return {
        "suite": "error",
        "meta": _meta(smoke),
        "config": {"d": d, "n": n, "k": k, "T": T, "ranks": list(ranks),
                   "probe_counts": list(probe_counts), "smoke": smoke,
                   "backend_platform": jax.default_backend()},
        "results": results,
        "adaptive_rank": adaptive,
        "worst_ratio": max(max(ratios), 1.0 / min(ratios)),
    }


def serving_sweep(key, *, smoke: bool = False) -> dict:
    """Serving sweep: trace counts + per-request latency, cold vs warm plans.

    One shape bucket of L requests per plan, served by a ``SketchService``
    on a fresh ``PipelineEngine``. The *cold* flush pays the plan's traces
    (compilation); every *warm* flush must be pure cache hits — zero new
    traces, one fused dispatch per bucket. Cells cover the three serving
    modes: fixed rank, fixed rank + attached error estimate, and the
    quality-gated ``r='auto'`` single-sweep path. The record the acceptance
    gate reads: ``traces_warm`` must be 0 in every cell, and
    ``cold_over_warm`` shows what compile-once buys per request.
    """
    from repro.core.pipeline import PipelineEngine
    from repro.serve.engine import SketchService
    if smoke:
        d, n, k, L, probes, m, warm_reps = 512, 32, 64, 4, 8, 800, 3
    else:
        d, n, k, L, probes, m, warm_reps = 4096, 128, 128, 16, 16, 6000, 10
    pairs = [_gd_pair(jax.random.fold_in(key, i), d, n, corr=0.3)
             for i in range(L)]
    plans = [
        ("fixed_r", dict(r=5, m=m, T=4)),
        ("fixed_r_with_error", dict(r=5, m=m, T=4, with_error=True)),
        ("auto_rank", dict(r="auto", tol=0.5, m=m, T=4)),
    ]
    results = []
    for name, kw in plans:
        engine = PipelineEngine()
        svc = SketchService(k=k, backend="scan", block=1024, probes=probes,
                            engine=engine)

        def flush_once(kw=kw, svc=svc):
            for i, (A, B) in enumerate(pairs):
                svc.submit(jax.random.fold_in(key, i), A, B)
            out = svc.flush_factors(**kw)
            jax.block_until_ready([v.factors.U for v in out.values()])
            return out

        t0 = time.perf_counter()
        flush_once()
        cold_us = (time.perf_counter() - t0) * 1e6
        traces_cold = engine.stats.traces
        t0 = time.perf_counter()
        for _ in range(warm_reps):
            flush_once()
        warm_us = (time.perf_counter() - t0) / warm_reps * 1e6
        results.append({
            "name": name,
            "requests_per_flush": L,
            "cold_us_per_request": cold_us / L,
            "warm_us_per_request": warm_us / L,
            "cold_over_warm": cold_us / warm_us,
            "traces_cold": traces_cold,
            "traces_warm": engine.stats.traces - traces_cold,
            "est_dispatches_per_flush":
                engine.stats.est_dispatches / (warm_reps + 1),
            "cache": {"hits": engine.stats.hits,
                      "misses": engine.stats.misses,
                      "evictions": engine.stats.evictions},
        })
    return {
        "suite": "serving",
        "meta": _meta(smoke),
        "config": {"d": d, "n": n, "k": k, "L": L, "probes": probes, "m": m,
                   "warm_reps": warm_reps, "smoke": smoke,
                   "backend_platform": jax.default_backend()},
        "results": results,
        "max_traces_warm": max(rec["traces_warm"] for rec in results),
    }


def traffic_sweep(*, smoke: bool = False) -> dict:
    """Measured-throughput traffic cells through the ServingLoop.

    Four regimes of the same continuously-batched stack (see
    ``repro.serve.traffic``): a single-shape steady state, a mixed-shape
    mix (three buckets batching independently), a multi-tenant mix (many
    tenants, one shared warm cache), and an overload cell (4x the
    calibrated rate into a bounded queue — the backpressure/shedding
    path). The records the acceptance gate reads: steady-state cells must
    show ``occupancy`` > 1 request/dispatch with ``traces_steady`` == 0.
    """
    from repro.serve.traffic import TrafficConfig, run_traffic
    if smoke:
        base = dict(n_requests=48, k=32, m=400, T=2, max_batch=4,
                    target_occupancy=3.0, pairs_per_shape=2)
        s1, s2, s3 = (256, 16, 12), (256, 24, 16), (384, 16, 16)
    else:
        base = dict(n_requests=256, k=64, m=1200, T=3, max_batch=8,
                    target_occupancy=4.0, pairs_per_shape=4)
        s1, s2, s3 = (2048, 64, 48), (2048, 96, 64), (3072, 64, 64)
    cells = [
        TrafficConfig(name="steady_single_shape", shapes=(s1,), **base),
        TrafficConfig(name="mixed_shapes", shapes=(s1, s2, s3), **base),
        TrafficConfig(name="multi_tenant", shapes=(s1,),
                      tenants=("acme", "globex", 7, None), **base),
        TrafficConfig(name="overload_shed", shapes=(s1,), rate_x=4.0,
                      max_queue=2 * base["max_batch"], **base),
    ]
    results = [run_traffic(cfg) for cfg in cells]
    steady = [rec for rec in results if rec["name"] != "overload_shed"]
    return {
        "suite": "traffic",
        "meta": _meta(smoke),
        "config": {"smoke": smoke,
                   "backend_platform": jax.default_backend()},
        "results": results,
        "min_steady_occupancy": min(rec["occupancy"] for rec in steady),
        "max_traces_steady": max(rec["traces_steady"] for rec in results),
        "overload_shed_rate": next(
            rec["shed_rate"] for rec in results
            if rec["name"] == "overload_shed"),
    }


def kernel_sweep(key, *, smoke: bool = False) -> dict:
    """Kernel-perf sweep: every tunable Pallas kernel x shape x config cell.

    For each kernel and canonical shape the autotuner's roofline ranking
    head (top-N candidates under the VMEM budget) plus the frozen default
    config are wall-timed through ``repro.kernels.ops`` — the same entry
    points production traffic uses — and each cell records ``us_per_call``,
    ``achieved_gbps`` (the cost model's HBM-byte count over measured time),
    and the static roofline terms. On interpret-mode CPU the absolute
    times are interpreter-relative; the ranking and the modeled terms are
    the stable signal ``tools/bench_compare.py`` tracks.
    """
    del key                      # measure_config seeds its own inputs
    from repro.kernels import tuning

    if smoke:
        shapes = {
            "sketch_fused": [(64, 512, 256)],
            "blocked_fwht": [(512, 256)],
            "sampled_dot": [(256, 256, 64, 512)],
            "flash_attention": [(4, 256, 64)],
        }
        top_n, reps = 2, 1
    else:
        shapes = {
            "sketch_fused": [(128, 4096, 512), (256, 8192, 512)],
            "blocked_fwht": [(2048, 512)],
            "sampled_dot": [(1024, 1024, 128, 4096)],
            "flash_attention": [(8, 1024, 128)],
        }
        top_n, reps = 3, 2
    results = []
    for kernel, shape_list in shapes.items():
        default = tuning.DEFAULTS[kernel]
        for shape in shape_list:
            ranked = tuning.rank_candidates(kernel, shape)
            cfgs = list(ranked[:top_n])
            if default not in cfgs:
                cfgs.append(default)
            shape_tag = "x".join(str(s) for s in shape)
            for cfg in cfgs:
                cost = tuning.roofline_cost(cfg, shape)
                us = tuning.measure_config(cfg, shape, reps=reps)
                results.append({
                    "name": f"{kernel}/{shape_tag}/{cfg.tag()}",
                    "kernel": kernel,
                    "shape": list(shape),
                    "config": cfg.tag(),
                    "static_rank": (ranked.index(cfg)
                                    if cfg in ranked else None),
                    "is_default": cfg == default,
                    "us_per_call": us,
                    "achieved_gbps": tuning.achieved_gbps(cfg, shape, us),
                    "modeled": cost.as_dict(),
                })
    return {
        "suite": "kernels",
        "meta": _meta(smoke),
        "config": {"shapes": {k: [list(s) for s in v]
                              for k, v in shapes.items()},
                   "top_n": top_n, "reps": reps, "smoke": smoke,
                   "vmem_budget_bytes": tuning.VMEM_BUDGET_BYTES,
                   "backend_platform": jax.default_backend()},
        "results": results,
    }


def ingest_sweep(key, *, smoke: bool = False) -> dict:
    """Multi-host ingest sweep: chunk pipelining x wire precision.

    Overlap cells drive the same chunk stream through
    ``StreamingSummarizer.ingest`` serial (``prefetch=0``: block after every
    fused update, then fetch+stage the next chunk) vs double-buffered
    (``prefetch=2``: chunk c+1 fetched and staged host->device while chunk c
    computes). The fetch models per-chunk arrival latency (``fetch_ms`` in
    the config — the storage/decode stall a real ingest pays per chunk);
    serial eats it on the critical path, double-buffering hides it under
    the fused update. Cells record ``chunks_per_sec``, ``rows_per_s``, and
    ``achieved_gbps`` (the A+B bytes the pass ingests end-to-end over wall
    time), timed best-of-``reps`` (pipelining is latency hiding, so the
    floor is the signal — means smear scheduler noise in). Wire cells
    compress the end-of-pass state at every ``WireSpec`` precision and
    record ``wire_bytes_per_state``, the probe-measured ``wire_error``, and
    the host-side ``wire_pack``+``wire_unpack`` round-trip time — the cost
    of putting one state on the inter-host wire. The gate cell runs
    ``choose_wire_spec`` at ``tol`` and records what the probe gate picked.
    """
    import numpy as np
    from repro.core import streaming

    if smoke:
        d, n, k, chunk, reps = 16384, 128, 128, 512, 5
    else:
        d, n, k, chunk, reps = 65536, 256, 128, 2048, 5
    probes, cosketch, tol, fetch_ms = 8, 8, 0.05, 2.0
    A, B = _gd_pair(key, d, n, corr=0.3)
    A_host, B_host = np.asarray(A), np.asarray(B)
    del A, B
    summ = core.StreamingSummarizer(k, probes=probes, cosketch=cosketch)
    n_chunks = -(-d // chunk)
    pass_bytes = A_host.nbytes + B_host.nbytes
    results = []

    def one_pass(prefetch):
        st = summ.init(key, (d, n, n))

        def chunks():
            for off in range(0, d, chunk):
                time.sleep(fetch_ms / 1e3)       # modeled arrival latency
                yield A_host[off:off + chunk], B_host[off:off + chunk]
        st = summ.ingest(st, chunks(), prefetch=prefetch)
        jax.block_until_ready(st.A_acc)
        return st

    state = None
    for prefetch in (0, 2):
        st = one_pass(prefetch)                  # warm the executables
        us = math.inf
        for _ in range(reps):
            t0 = time.perf_counter()
            st = one_pass(prefetch)
            us = min(us, (time.perf_counter() - t0) * 1e6)
        state = st
        results.append({
            "name": f"ingest/prefetch{prefetch}",
            "prefetch": prefetch,
            "us_per_call": us,
            "chunks_per_sec": n_chunks / us * 1e6,
            "rows_per_s": d / us * 1e6,
            "achieved_gbps": pass_bytes / (us / 1e6) / 1e9,
        })

    f32_bytes = None
    for spec in streaming.WIRE_DTYPES:
        comp = streaming.compress_state(state, spec)
        nbytes = streaming.wire_bytes(comp)
        if spec == "f32":
            f32_bytes = nbytes
        _, us = _timed(
            lambda c=comp: streaming.wire_unpack(streaming.wire_pack(c)))
        results.append({
            "name": f"wire/{spec}",
            "us_per_call": us,
            "wire_bytes_per_state": nbytes,
            "bytes_ratio_vs_f32": f32_bytes / nbytes,
            "wire_error": float(streaming.wire_error(state, spec)),
        })

    gate_spec, gate_err = streaming.choose_wire_spec(state, tol)
    results.append({
        "name": f"wire/gate_tol{tol}",
        "chosen_spec": gate_spec.sketch,
        "wire_error": float(gate_err),
        "wire_bytes_per_state": streaming.wire_bytes(
            streaming.compress_state(state, gate_spec)),
    })

    return {
        "suite": "ingest",
        "meta": _meta(smoke),
        "config": {"d": d, "n": n, "k": k, "chunk": chunk, "reps": reps,
                   "probes": probes, "cosketch": cosketch, "tol": tol,
                   "fetch_ms": fetch_ms, "smoke": smoke,
                   "backend_platform": jax.default_backend()},
        "results": results,
    }


BENCHES = [
    ("fig2a_rescaled_jl", fig2a_rescaled_jl),
    ("fig2b_cone", fig2b_cone),
    ("fig3a_runtime", fig3a_runtime),
    ("fig3b_error_vs_k", fig3b_error_vs_k),
    ("table1_errors", table1_errors),
    ("fig4a_phase", fig4a_phase),
    ("fig4b_cone_full", fig4b_cone_full),
    ("fig4c_orthogonal", fig4c_orthogonal),
    ("grad_compression", grad_compression),
    ("kernel_sketch_fused", kernel_sketch_fused),
    ("summary_backends", summary_backends),
]


def run_paper_suite(key) -> None:
    print("name,us_per_call,derived,notes")
    for name, fn in BENCHES:
        try:
            us, derived, notes = fn(jax.random.fold_in(
                key, zlib.crc32(name.encode()) % 2**31))
            print(f"{name},{us:.0f},{derived:.4f},{notes}", flush=True)
        except Exception as e:   # noqa: BLE001
            print(f"{name},nan,nan,ERROR {e}", flush=True)


def run_estimation_suite(key, out_path: str, smoke: bool) -> None:
    report = estimation_backends(jax.random.fold_in(
        key, zlib.crc32(b"estimation_backends") % 2**31), smoke=smoke)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {out_path}", flush=True)
    print("name,us_per_call,spectral_error,baseline_spectral_error")
    for rec in report["results"]:
        print(f"{rec['name']},{rec['us_per_call']:.0f},"
              f"{rec['spectral_error']:.4f},"
              f"{rec['baseline_spectral_error']:.4f}", flush=True)
    print(f"jit_speedup_vs_reference,"
          f"{report['jit_speedup_vs_reference']:.2f}x", flush=True)


def run_error_suite(key, out_path: str, smoke: bool) -> None:
    report = error_sweep(jax.random.fold_in(
        key, zlib.crc32(b"error") % 2**31), smoke=smoke)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {out_path}", flush=True)
    print("name,us_per_call,frob_est,frob_true,ratio,ci_covers_true")
    for rec in report["results"]:
        print(f"{rec['name']},{rec['us_per_call']:.0f},"
              f"{rec['frob_est']:.4f},{rec['frob_true']:.4f},"
              f"{rec['ratio_est_over_true']:.3f},{rec['ci_covers_true']}",
              flush=True)
    for rec in report["adaptive_rank"]:
        print(f"adaptive tol={rec['tol']},r={rec['r']},"
              f"rel_est={rec['rel_est']:.3f},rel_true={rec['rel_true']:.3f},"
              f"met={rec['met']}", flush=True)
    print(f"worst_ratio,{report['worst_ratio']:.3f}", flush=True)


def run_serving_suite(key, out_path: str, smoke: bool) -> None:
    report = serving_sweep(jax.random.fold_in(
        key, zlib.crc32(b"serving") % 2**31), smoke=smoke)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {out_path}", flush=True)
    print("name,cold_us_per_req,warm_us_per_req,cold_over_warm,"
          "traces_cold,traces_warm")
    for rec in report["results"]:
        print(f"{rec['name']},{rec['cold_us_per_request']:.0f},"
              f"{rec['warm_us_per_request']:.0f},"
              f"{rec['cold_over_warm']:.2f},"
              f"{rec['traces_cold']},{rec['traces_warm']}", flush=True)
    print(f"max_traces_warm,{report['max_traces_warm']}", flush=True)


def run_traffic_suite(out_path: str, smoke: bool) -> None:
    """Run the traffic sweep and MERGE it into the serving artifact: the
    serving sweep (cold/warm plan latency) and the traffic sweep (measured
    throughput) are two views of the same stack and share one
    ``BENCH_serving.json``, under the ``"traffic"`` key."""
    report = traffic_sweep(smoke=smoke)
    merged = {}
    if os.path.exists(out_path):
        try:
            with open(out_path) as f:
                merged = json.load(f)
        except (OSError, json.JSONDecodeError):
            merged = {}
    if not merged:
        merged = {"suite": "serving", "meta": report["meta"]}
    merged["traffic"] = report
    with open(out_path, "w") as f:
        json.dump(merged, f, indent=2)
    print(f"wrote {out_path} (traffic)", flush=True)
    print("name,offered_rps,measured_rps,p50_ms,p99_ms,occupancy,"
          "shed_rate,traces_steady")
    for rec in report["results"]:
        print(f"{rec['name']},{rec['offered_rps']:.1f},"
              f"{rec['measured_rps']:.1f},{rec['p50_ms']:.1f},"
              f"{rec['p99_ms']:.1f},{rec['occupancy']:.2f},"
              f"{rec['shed_rate']:.3f},{rec['traces_steady']}", flush=True)
    print(f"min_steady_occupancy,{report['min_steady_occupancy']:.2f}",
          flush=True)
    print(f"max_traces_steady,{report['max_traces_steady']}", flush=True)


def run_streaming_suite(key, out_path: str, smoke: bool) -> None:
    report = streaming_sweep(jax.random.fold_in(
        key, zlib.crc32(b"streaming") % 2**31), smoke=smoke)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {out_path}", flush=True)
    print("name,us_per_call,rows_per_s,max_err_vs_reference|tracking_error")
    for rec in report["results"]:
        last = rec.get("max_err_vs_reference", rec.get("tracking_error"))
        print(f"{rec['name']},{rec['us_per_call']:.0f},"
              f"{rec['rows_per_s']:.0f},{last:.2e}", flush=True)
    print(f"max_parity_error,{report['max_parity_error']:.2e}", flush=True)


def run_kernels_suite(key, out_path: str, smoke: bool) -> None:
    report = kernel_sweep(jax.random.fold_in(
        key, zlib.crc32(b"kernels") % 2**31), smoke=smoke)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {out_path}", flush=True)
    print("name,us_per_call,achieved_gbps,static_rank,is_default")
    for rec in report["results"]:
        print(f"{rec['name']},{rec['us_per_call']:.0f},"
              f"{rec['achieved_gbps']:.3f},{rec['static_rank']},"
              f"{rec['is_default']}", flush=True)


def run_ingest_suite(key, out_path: str, smoke: bool) -> None:
    report = ingest_sweep(jax.random.fold_in(
        key, zlib.crc32(b"ingest") % 2**31), smoke=smoke)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {out_path}", flush=True)
    print("name,chunks_per_sec|wire_bytes_per_state,achieved_gbps|wire_error")
    for rec in report["results"]:
        if "chunks_per_sec" in rec:
            print(f"{rec['name']},{rec['chunks_per_sec']:.1f},"
                  f"{rec['achieved_gbps']:.3f}", flush=True)
        else:
            print(f"{rec['name']},{rec['wire_bytes_per_state']},"
                  f"{rec.get('wire_error', 0.0):.2e}", flush=True)


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--suite",
                   choices=("paper", "estimation", "streaming", "error",
                            "serving", "traffic", "kernels", "ingest",
                            "all"),
                   default="paper")
    p.add_argument("--smoke", action="store_true",
                   help="reduced sizes for CI smoke runs")
    p.add_argument("--out", default="BENCH_estimation.json",
                   help="JSON artifact path for the estimation suite")
    p.add_argument("--out-streaming", default="BENCH_streaming.json",
                   help="JSON artifact path for the streaming suite")
    p.add_argument("--out-error", default="BENCH_error.json",
                   help="JSON artifact path for the error suite")
    p.add_argument("--out-serving", default="BENCH_serving.json",
                   help="JSON artifact path for the serving suite")
    p.add_argument("--out-kernels", default="BENCH_kernels.json",
                   help="JSON artifact path for the kernel-perf suite")
    p.add_argument("--out-ingest", default="BENCH_ingest.json",
                   help="JSON artifact path for the multi-host ingest suite")
    args = p.parse_args()
    key = jax.random.PRNGKey(0)
    if args.suite in ("paper", "all"):
        run_paper_suite(key)
    if args.suite in ("estimation", "all"):
        run_estimation_suite(key, args.out, args.smoke)
    if args.suite in ("streaming", "all"):
        run_streaming_suite(key, args.out_streaming, args.smoke)
    if args.suite in ("error", "all"):
        run_error_suite(key, args.out_error, args.smoke)
    if args.suite in ("serving", "all"):
        run_serving_suite(key, args.out_serving, args.smoke)
    if args.suite in ("traffic", "all"):
        run_traffic_suite(args.out_serving, args.smoke)
    if args.suite in ("kernels", "all"):
        run_kernels_suite(key, args.out_kernels, args.smoke)
    if args.suite in ("ingest", "all"):
        run_ingest_suite(key, args.out_ingest, args.smoke)


if __name__ == "__main__":
    main()
