"""Batched serving example: prefill + jitted decode with preallocated caches.

    PYTHONPATH=src python examples/serve_lm.py --arch recurrentgemma-9b
(reduced configs; any of the 10 assigned archs works)
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.models import build
from repro.serve.engine import Engine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b", choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init_params(key)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    for name, sds in model.aux_input_shapes(args.batch).items():
        batch[name] = jnp.zeros(sds.shape, sds.dtype)

    eng = Engine(model, params, ServeConfig(max_new_tokens=args.new_tokens,
                                            temperature=args.temperature))
    out = eng.generate(batch)
    print(f"arch={cfg.name} generated {out.shape} tokens")
    print("row 0:", out[0, args.prompt_len:].tolist())


if __name__ == "__main__":
    main()
