"""Batched serving example: prefill + jitted decode with preallocated caches.

    PYTHONPATH=src python examples/serve_lm.py --arch recurrentgemma-9b
(reduced configs; any of the 10 assigned archs works)

The same serve layer also hosts sketch serving (``SketchService``): batched
one-shot requests (submit/flush_factors) and streaming accumulator sessions
(open_stream/append/query) for clients that feed row chunks over time —
``--sketch-demo`` shows a session next to the LM engine; see
docs/streaming.md for the lifecycle.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.models import build
from repro.serve.engine import Engine, ServeConfig, SketchService


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b", choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--sketch-demo", action="store_true",
                    help="also run a SketchService streaming session")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init_params(key)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    for name, sds in model.aux_input_shapes(args.batch).items():
        batch[name] = jnp.zeros(sds.shape, sds.dtype)

    eng = Engine(model, params, ServeConfig(max_new_tokens=args.new_tokens,
                                            temperature=args.temperature))
    out = eng.generate(batch)
    print(f"arch={cfg.name} generated {out.shape} tokens")
    print("row 0:", out[0, args.prompt_len:].tolist())

    if args.sketch_demo:
        # a client streams row chunks of an (A, B) pair over time and asks
        # the live accumulator for the top-r factors of A^T B
        svc = SketchService(k=64, backend="scan", block=256)
        d, n = 2048, 96
        A = jax.random.normal(key, (d, n))
        B = jax.random.normal(jax.random.fold_in(key, 1), (d, n))
        sid = svc.open_stream(key, d, n, n)
        for off in range(0, d, 256):
            svc.append(sid, A[off:off + 256], B[off:off + 256])
        est = svc.stream_factors(sid, r=4)
        print(f"sketch session: {int(svc.close_stream(sid).rows_seen)} rows "
              f"-> factors U{est.factors.U.shape} V{est.factors.V.shape}")


if __name__ == "__main__":
    main()
