"""The paper's co-occurrence use case: discover the top components of a
query x ad interaction matrix from a stream of rows arriving in ARBITRARY
order, without ever storing the data (abstract + §1 of the paper).

    PYTHONPATH=src python examples/streaming_cooccurrence.py

Uses the streaming API (``core.StreamingSummarizer``): chunks are absorbed
with ``update_rows`` (explicit global row ids — arrival order is
irrelevant), the pass is checkpointed mid-stream and resumed (the
fault-tolerance story for week-long ingestion jobs), and partial states
from independent workers merge associatively (``core.merge_states`` /
``core.tree_merge``). See docs/streaming.md for the full contract.
"""
import math
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro import core
from repro.ckpt import checkpoint
from repro.data.pipeline import cooccurrence_stream

key = jax.random.PRNGKey(0)
d, n1, n2, rank = 8192, 300, 200, 4

# --- one pass over a shuffled stream of (user row) observations ------------
# each chunk's contribution depends only on (key, global row ids), so
# arrival order is irrelevant and partial states merge exactly
# (StreamingSummarizer(k, method="srht") streams SRHT the same way)
summ = core.StreamingSummarizer(k=192)
state = summ.init(key, (d, n1, n2))
rows_seen = 0
ckpt_dir = tempfile.mkdtemp(prefix="smppca_stream_")
for row_ids, A_rows, B_rows in cooccurrence_stream(
        seed=0, d=d, n1=n1, n2=n2, rank=rank, chunk=1024):
    state = summ.update_rows(state, jnp.asarray(row_ids),
                             jnp.asarray(A_rows), jnp.asarray(B_rows))
    rows_seen += len(row_ids)
    if rows_seen == d // 2:
        # mid-pass checkpoint: a crashed ingestion job resumes exactly here
        checkpoint.save_stream_state(ckpt_dir, step=rows_seen, state=state)
        state = checkpoint.restore_stream_state(
            ckpt_dir, like=summ.init(key, (d, n1, n2)))
        print(f"checkpointed + restored at {int(state.rows_seen)} rows")

summary = summ.finalize(state)
print(f"streamed {rows_seen} rows in arbitrary order; "
      f"summary: sketches {summary.A_sketch.shape}/{summary.B_sketch.shape} "
      f"+ {n1 + n2} norms (vs {d * (n1 + n2)} raw values)")

# --- steps 2-3 on the summary only ------------------------------------------
m = int(10 * max(n1, n2) * rank * math.log(max(n1, n2)))
res = core.smppca_from_summary(key, summary, r=rank, m=m, T=8)

# ground truth for evaluation only (a real deployment never materializes it)
rng = np.random.default_rng(0)
UA = rng.normal(size=(d, rank)) / np.sqrt(rank)
VA = rng.normal(size=(rank, n1))
UB = 0.5 * UA + 0.5 * rng.normal(size=(d, rank)) / np.sqrt(rank)
VB = rng.normal(size=(rank, n2))
A = jnp.asarray(UA @ VA + 0.1 * rng.normal(size=(d, n1)), jnp.float32)
B = jnp.asarray(UB @ VB + 0.1 * rng.normal(size=(d, n2)), jnp.float32)
err, opt = core.spectral_error_vs_optimal(A, B, rank, res.factors)
print(f"spectral error {float(err):.4f} (optimal rank-{rank}: {float(opt):.4f})")
