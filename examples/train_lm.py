"""End-to-end LM training driver with SMP-PCA gradient compression.

Default: a ~20M-param phi3-family model for 300 steps on CPU (fits this
container). ``--preset 100m`` selects a ~100M config (same code path; slower
on CPU). ``--compression taps`` turns on the paper's single-pass gradient
sketches on every MLP matmul.

    PYTHONPATH=src python examples/train_lm.py --steps 300
    PYTHONPATH=src python examples/train_lm.py --compression taps --steps 100
"""
import argparse
import dataclasses
import json
import logging


from repro.configs import get_config
from repro.data.pipeline import SyntheticLM
from repro.models import build
from repro.optim import AdamW, warmup_cosine
from repro.train import TrainConfig, Trainer, TrainerConfig

PRESETS = {
    # (d_model, heads, kv, d_ff, layers, batch, seq) — ~params
    "20m": (256, 8, 8, 1024, 8, 8, 128),
    "100m": (512, 8, 8, 2048, 12, 8, 256),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="20m", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--compression", default="none",
                    choices=["none", "taps", "lowrank"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")
    d, h, kv, ff, L, batch, seq = PRESETS[args.preset]
    cfg = dataclasses.replace(
        get_config("phi3-mini-3.8b"),
        d_model=d, n_heads=h, n_kv_heads=kv, head_dim=d // h, d_ff=ff,
        groups=((("attn",), L),), n_layers=L, vocab_size=8192,
        loss_chunk=seq, remat=False,
        sketched_mlp=(args.compression == "taps"))
    model = build(cfg)
    print(f"model: {cfg.n_params()/1e6:.1f}M params, compression="
          f"{args.compression}")

    data = SyntheticLM(vocab_size=cfg.vocab_size, batch_size=batch,
                       seq_len=seq, seed=0)
    opt = AdamW(lr=warmup_cosine(args.lr, args.steps // 10, args.steps),
                weight_decay=0.01)
    tcfg = TrainConfig(microbatches=2, compression=args.compression)
    trainer = Trainer(model.loss, opt, data, tcfg,
                      TrainerConfig(num_steps=args.steps,
                                    ckpt_dir=args.ckpt_dir,
                                    ckpt_every=100, log_every=20),
                      init_params_fn=model.init_params)
    state = trainer.run()
    h0 = trainer.metrics_history[0]
    h1 = trainer.metrics_history[-1]
    print(json.dumps({"steps": int(state.step),
                      "loss_first": round(h0["loss"], 4),
                      "loss_last": round(h1["loss"], 4)}))


if __name__ == "__main__":
    main()
