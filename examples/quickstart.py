"""Quickstart: single-pass PCA of a matrix product in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py

Choosing a summary backend
--------------------------
Step 1 (the one pass over A, B) goes through one entry point,
``core.build_summary(key, A, B, k, method=..., backend=...)``, and every
backend produces the same summary for the same key (identical
(key, global_row_index) randomness — parity-tested in
tests/core/test_summary_engine.py):

* ``reference``   — materialize the (k, d) operator, one dense matmul.
      Simplest; fine whenever (k, d) fits in memory.
* ``scan``        — stream row blocks, regenerating each block's operator
      slice on the fly. Use when d is huge (the operator never exists).
* ``rows``        — arbitrary-order row streams: rows arrive as
      (global index, A row, B row) chunks in any order.
* ``pallas``      — fused TPU kernels (sketch + norms in one HBM pass;
      SRHT via the blocked-FWHT MXU kernel). Fastest on accelerators;
      runs interpreted on CPU so the same code path is CI-tested.
* ``distributed`` — rows sharded over a mesh axis (pass mesh=/axis=);
      one psum aggregates the shards (Spark treeAggregate as collectives).

``method`` is 'gaussian' (analyzed in the paper) or 'srht' (the paper's
Spark choice); both work on every backend. Pass stacked (L, d, n) inputs to
sketch L pairs in one vmapped dispatch, and ``precision='bf16'`` for
bf16-in/f32-accumulate on accelerators. ``core.smppca(...)`` forwards
``method``/``backend``/``precision`` straight through.

When the pair never fits in memory (or arrives over time), the same pass
runs chunked through ``core.StreamingSummarizer`` — ``init / update /
merge / finalize`` with any chunking or merge order, checkpointable
mid-pass (see docs/streaming.md and examples/streaming_cooccurrence.py;
the one-shot backends below are the it-fits-in-memory fast path).

Choosing an estimation method (step 2-3)
----------------------------------------
The summary then flows into ``core.estimate_product(key, summary, r,
method=..., backend=...)`` — the EstimationEngine:

* ``rescaled_jl``  — the paper: biased sampling + rescaled-JL entries +
      WAltMin. Best one-pass accuracy on correlated data (Fig 2b/4b).
* ``direct_svd``   — SVD of the sketch product; cheapest, keeps the plain-JL
      bias the paper removes (Fig 2a).
* ``lela_waltmin`` — exact entries from a second pass over (A, B)
      (``exact_pair=(A, B)``): the two-pass accuracy ceiling.

with ``backend`` in {'reference' (eager oracle), 'jit' (scan'd WAltMin),
'pallas' (gather-kernel entry extraction)} — see README.md.
"""
import math

import jax
import jax.numpy as jnp

from repro import core

key = jax.random.PRNGKey(0)

# two tall matrices whose product A^T B we want the top-5 components of
d, n, r = 20_000, 400, 5
D = jnp.diag(1.0 / jnp.arange(1.0, n + 1.0))
A = jax.random.normal(key, (d, n)) @ D
B = A + 0.3 * jax.random.normal(jax.random.fold_in(key, 1), (d, n)) @ D

# one pass: sketches + column norms; then sample, estimate, complete.
# backend="scan" streams row blocks — the (k, d) operator is never built
# (swap in "reference", "pallas", ... freely: same key -> same summary)
result = core.smppca(
    key, A, B,
    r=r,                                 # target rank
    k=256,                               # sketch size (Thm 3.1: eta ~ 1/sqrt k)
    m=int(10 * n * r * math.log(n)),     # samples (Fig 4a: >= nr log n)
    T=8,                                 # WAltMin iterations
    backend="scan",
)

# smppca is exactly the two engines composed — sketch once, estimate later
# (or many times, with different methods, from the same one-pass summary):
summary = core.build_summary(key, A, B, 256, backend="scan")
print(f"summary: sketches {summary.A_sketch.shape} + "
      f"{summary.n1 + summary.n2} norms")
est = core.estimate_product(
    jax.random.fold_in(key, 2), summary, r,
    method="rescaled_jl",                # or "direct_svd" / "lela_waltmin"
    backend="jit",                       # or "reference" / "pallas"
    m=int(10 * n * r * math.log(n)), T=8)
print(f"estimate_product factors: U {est.factors.U.shape}, "
      f"V {est.factors.V.shape}")

err, opt = core.spectral_error_vs_optimal(A, B, r, result.factors)
print(f"SMP-PCA spectral error : {float(err):.4f}")
print(f"optimal rank-{r} error   : {float(opt):.4f}")
print(f"factors: U {result.factors.U.shape}, V {result.factors.V.shape}")

# compare with the naive one-pass baseline the paper beats
sf = core.sketch_svd(key, A, B, r=r, k=256)
err_svd, _ = core.spectral_error_vs_optimal(A, B, r, sf)
print(f"SVD(sketch) error      : {float(err_svd):.4f}  "
      f"(paper Fig 3b: SMP-PCA wins)")
