"""Quickstart: single-pass PCA of a matrix product in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import math

import jax
import jax.numpy as jnp

from repro import core

key = jax.random.PRNGKey(0)

# two tall matrices whose product A^T B we want the top-5 components of
d, n, r = 20_000, 400, 5
D = jnp.diag(1.0 / jnp.arange(1.0, n + 1.0))
A = jax.random.normal(key, (d, n)) @ D
B = A + 0.3 * jax.random.normal(jax.random.fold_in(key, 1), (d, n)) @ D

# one pass: sketches + column norms; then sample, estimate, complete
result = core.smppca(
    key, A, B,
    r=r,                                 # target rank
    k=256,                               # sketch size (Thm 3.1: eta ~ 1/sqrt k)
    m=int(10 * n * r * math.log(n)),     # samples (Fig 4a: >= nr log n)
    T=8,                                 # WAltMin iterations
)

err, opt = core.spectral_error_vs_optimal(A, B, r, result.factors)
print(f"SMP-PCA spectral error : {float(err):.4f}")
print(f"optimal rank-{r} error   : {float(opt):.4f}")
print(f"factors: U {result.factors.U.shape}, V {result.factors.V.shape}")

# compare with the naive one-pass baseline the paper beats
sf = core.sketch_svd(key, A, B, r=r, k=256)
err_svd, _ = core.spectral_error_vs_optimal(A, B, r, sf)
print(f"SVD(sketch) error      : {float(err_svd):.4f}  "
      f"(paper Fig 3b: SMP-PCA wins)")
