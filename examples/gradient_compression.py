"""Ablation: SMP-PCA gradient compression in real training loops.

Trains the same tiny LM three ways — uncompressed, paper tap-path
(single-pass X/dY sketches on MLP matmuls), and the A=I grads-level
baseline with error feedback — and prints the loss trajectories. The tap
path tracks the uncompressed curve at ~1/3 of the gradient communication.

    PYTHONPATH=src python examples/gradient_compression.py --steps 60
"""
import argparse
import dataclasses


from repro.configs import get_config
from repro.data.pipeline import SyntheticLM
from repro.models import build
from repro.optim import AdamW, warmup_cosine
from repro.train import TrainConfig, Trainer, TrainerConfig


def run(compression: str, steps: int) -> list:
    cfg = dataclasses.replace(
        get_config("phi3-mini-3.8b").reduced(),
        d_model=128, d_ff=256, head_dim=32,
        sketched_mlp=(compression == "taps"))
    model = build(cfg)
    data = SyntheticLM(vocab_size=cfg.vocab_size, batch_size=8, seq_len=64)
    opt = AdamW(lr=warmup_cosine(3e-3, 5, steps), weight_decay=0.01)
    trainer = Trainer(model.loss, opt, data,
                      TrainConfig(microbatches=1, compression=compression),
                      TrainerConfig(num_steps=steps, log_every=10_000),
                      init_params_fn=model.init_params)
    trainer.run()
    return [h["loss"] for h in trainer.metrics_history]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()
    curves = {}
    for mode in ("none", "taps", "lowrank"):
        curves[mode] = run(mode, args.steps)
        print(f"{mode:8s} first={curves[mode][0]:.3f} "
              f"last={curves[mode][-1]:.3f}")
    base = curves["none"][-1]
    print(f"\nfinal-loss ratio vs uncompressed: "
          f"taps={curves['taps'][-1]/base:.3f} "
          f"lowrank={curves['lowrank'][-1]/base:.3f}")


if __name__ == "__main__":
    main()
