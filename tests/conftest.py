"""Shared pytest fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches
must see the single real CPU device; multi-device tests spawn subprocesses
with their own flags (tests/dist/)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def key():
    return jax.random.PRNGKey(0)


def planted_pair(key, d, n, decay=1.0, corr=None):
    """Synthetic (A, B) = G @ D with D_ii = 1/i^decay (the paper's generator).

    corr=None -> independent A, B; corr=sigma -> B = A + sigma * noise
    (columns drawn from a cone, the paper's favourable regime)."""
    kA, kB = jax.random.split(key)
    D = jnp.diag(1.0 / jnp.arange(1.0, n + 1.0) ** decay)
    A = jax.random.normal(kA, (d, n)) @ D
    if corr is None:
        B = jax.random.normal(kB, (d, n)) @ D
    else:
        B = A + corr * jax.random.normal(kB, (d, n)) @ D
    return A, B
