"""Shared pytest fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches
must see the single real CPU device; multi-device tests spawn subprocesses
with their own flags (tests/dist/)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def key():
    return jax.random.PRNGKey(0)


def planted_pair(key, d, n, decay=1.0, corr=None):
    """Synthetic (A, B) = G @ D with D_ii = 1/i^decay (the paper's generator).

    corr=None -> independent A, B; corr=sigma -> B = A + sigma * noise
    (columns drawn from a cone, the paper's favourable regime)."""
    kA, kB = jax.random.split(key)
    D = jnp.diag(1.0 / jnp.arange(1.0, n + 1.0) ** decay)
    A = jax.random.normal(kA, (d, n)) @ D
    if corr is None:
        B = jax.random.normal(kB, (d, n)) @ D
    else:
        B = A + corr * jax.random.normal(kB, (d, n)) @ D
    return A, B


def gaussian_pair(key, d=192, n1=11, n2=7):
    """Plain iid-normal (A, B) — the generic parity/monoid test input
    (shared here; previously inlined per test module)."""
    kA, kB = jax.random.split(key)
    return (jax.random.normal(kA, (d, n1)), jax.random.normal(kB, (d, n2)))


def spectrum_values(kind, q=10):
    """Named singular-value profiles for the known-spectrum fixtures."""
    i = np.arange(q, dtype=np.float64)
    if kind == "fast":                 # geometric decay: clear rank gaps
        s = 2.0 ** -i
    elif kind == "slow":               # polynomial decay: heavy tail
        s = 1.0 / np.sqrt(1.0 + i)
    elif kind == "rank_deficient":     # exact rank q//2: zero tail
        s = np.where(i < q // 2, 2.0 ** -i, 0.0)
    else:
        raise ValueError(f"unknown spectrum kind {kind!r}")
    return jnp.asarray(s, jnp.float32)


def known_spectrum_pair(key, d, n1, n2, spectrum):
    """(A, B, M) with A^T B == M == U0 diag(spectrum) V0^T *exactly*.

    A = W (orthonormal columns), B = W @ M, so A^T B = M and M's singular
    values are the given spectrum — the ground truth every ErrorEngine /
    adaptive-rank assertion compares against.
    """
    q = spectrum.shape[0]
    assert q <= min(n1, n2), (q, n1, n2)
    kW, kU, kV = jax.random.split(key, 3)
    W, _ = jnp.linalg.qr(jax.random.normal(kW, (d, n1)))
    U0, _ = jnp.linalg.qr(jax.random.normal(kU, (n1, q)))
    V0, _ = jnp.linalg.qr(jax.random.normal(kV, (n2, q)))
    M = (U0 * spectrum[None, :]) @ V0.T
    return W, W @ M, M


def drifting_spectrum_pair(key, d=256, n1=14, n2=12, q=3):
    """Two-phase piecewise-stationary stream with disjoint top subspaces.

    Returns ``((A1, B1, M1, U1), (A2, B2, M2, U2))``: phase i satisfies
    ``Ai^T Bi == Mi`` exactly with top-q left singular subspace ``Ui``, and
    ``U1 ⟂ U2`` (drawn as disjoint column blocks of one orthonormal basis).
    Phase 1 carries 4x the singular mass, so after the flip a cumulative
    (vanilla) summary keeps answering ``U1`` while a decayed/windowed
    summary recovers ``U2`` — drift tests assert subspace recovery instead
    of eyeballing error curves.
    """
    kW1, kW2, kU, kV1, kV2 = jax.random.split(key, 5)
    U_all, _ = jnp.linalg.qr(jax.random.normal(kU, (n1, 2 * q)))
    U1, U2 = U_all[:, :q], U_all[:, q:]
    V1, _ = jnp.linalg.qr(jax.random.normal(kV1, (n2, q)))
    V2, _ = jnp.linalg.qr(jax.random.normal(kV2, (n2, q)))
    # flat within-phase spectrum: the drift IS the subspace flip, and a
    # clean top-q gap keeps recovery assertions well above the sketch noise
    M1 = 8.0 * U1 @ V1.T
    M2 = 4.0 * U2 @ V2.T
    W1, _ = jnp.linalg.qr(jax.random.normal(kW1, (d, n1)))
    W2, _ = jnp.linalg.qr(jax.random.normal(kW2, (d, n1)))
    return (W1, W1 @ M1, M1, U1), (W2, W2 @ M2, M2, U2)


@pytest.fixture()
def drifting_pair(key):
    """The two-phase drifting stream at the default test geometry."""
    return drifting_spectrum_pair(key)


@pytest.fixture(params=["fast", "slow", "rank_deficient"])
def spectrum_case(request, key):
    """(kind, A, B, M, spectrum) across the three known-spectrum profiles:
    fast decay, slow decay, and exactly rank-deficient."""
    kind = request.param
    s = spectrum_values(kind)
    A, B, M = known_spectrum_pair(key, 384, 14, 12, s)
    return kind, A, B, M, s
