"""Roofline machinery unit tests: HLO analyzer (trip counts, dot flops,
fusion io), collective parser, sharding rules, shapes/applicability."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.shapes import SHAPES, cell_applicable
from repro.roofline import analysis as roof
from repro.roofline import hlo_analyzer as ha


# ---------------------------------------------------------------------------
# HLO analyzer
# ---------------------------------------------------------------------------

def test_analyzer_scan_trip_count():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = jax.jit(f).lower(x, x).compile()
    cost = ha.analyze(c.as_text())
    assert abs(cost.flops - 2 * 128 ** 3 * 10) / (2 * 128 ** 3 * 10) < 0.01


def test_analyzer_nested_scans_multiply():
    def f(x, w):
        def outer(c, _):
            def inner(cc, _):
                return cc @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = jax.jit(f).lower(x, x).compile()
    cost = ha.analyze(c.as_text())
    want = 2 * 64 ** 3 * 12
    assert abs(cost.flops - want) / want < 0.01


def test_analyzer_matches_xla_on_loop_free():
    def g(a, b):
        return jnp.tanh(a @ b) @ b
    x = jax.ShapeDtypeStruct((96, 96), jnp.float32)
    c = jax.jit(g).lower(x, x).compile()
    cost = ha.analyze(c.as_text())
    xla_cost = c.cost_analysis()
    if isinstance(xla_cost, (list, tuple)):   # jax <= 0.4.x wraps in a list
        xla_cost = xla_cost[0]
    xla = xla_cost["flops"]
    assert abs(cost.flops - xla) / xla < 0.05


def test_analyzer_rectangular_dot_contract_dims():
    def f(a, b):
        return jnp.einsum("ij,kj->ik", a, b)   # contract dim 1 of both
    a = jax.ShapeDtypeStruct((32, 100), jnp.float32)
    b = jax.ShapeDtypeStruct((48, 100), jnp.float32)
    c = jax.jit(f).lower(a, b).compile()
    cost = ha.analyze(c.as_text())
    want = 2 * 32 * 48 * 100
    assert abs(cost.flops - want) / want < 0.05


def test_collective_parser_on_synthetic_hlo():
    hlo = """
HloModule m

ENTRY %main (p: f32[16,16]) -> f32[16,16] {
  %p = f32[16,16]{1,0} parameter(0)
  %ar = f32[16,16]{1,0} all-reduce(%p), replica_groups={}
  %ag = f32[32,16]{1,0} all-gather(%ar), dimensions={0}
  ROOT %out = f32[16,16]{1,0} slice(%ag), slice={[0:16], [0:16]}
}
"""
    stats = roof.collective_bytes(hlo)
    assert stats.by_op["all-reduce"] == 16 * 16 * 4
    assert stats.by_op["all-gather"] == 32 * 16 * 4
    assert stats.count == 2


def test_roofline_terms_and_bottleneck():
    rl = roof.Roofline(flops=197e12, bytes_accessed=819e9 * 2,
                       coll_bytes=50e9 * 0.5,
                       model_flops_per_device=197e12 / 2, chips=256)
    assert abs(rl.t_compute - 1.0) < 1e-9
    assert abs(rl.t_memory - 2.0) < 1e-9
    assert abs(rl.t_collective - 0.5) < 1e-9
    assert rl.bottleneck == "memory"
    assert abs(rl.roofline_fraction - 0.25) < 1e-9


# ---------------------------------------------------------------------------
# shapes / applicability / sharding rules
# ---------------------------------------------------------------------------

def test_cell_applicability_long_context():
    ok, _ = cell_applicable("hybrid", "long_500k")
    assert ok
    ok, reason = cell_applicable("dense", "long_500k")
    assert not ok and "quadratic" in reason


def test_shapes_registry_complete():
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                           "long_500k"}
    assert SHAPES["train_4k"].kind == "train"
    assert SHAPES["decode_32k"].kind == "decode"
    assert SHAPES["long_500k"].global_batch == 1


@pytest.mark.dist
def test_sharding_divisibility_fallback():
    """12 heads / 16-way model axis -> replicate (whisper case)."""
    from tests.dist.helpers import run_with_devices
    out = run_with_devices("""
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.dist import sharding as shr
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    # divisible: sharded; non-divisible: replicated on that dim
    spec = shr.param_spec(mesh, "/mlp/up/w", (64, 128))
    assert spec == P(("data",), "model"), spec
    spec = shr.param_spec(mesh, "/mlp/up/w", (64, 126))   # 126 % 4 != 0
    assert spec == P(("data",), None), spec
    spec = shr.param_spec(mesh, "/embed/table", (512, 64))
    assert spec == P("model", ("data",)), spec
    spec = shr.param_spec(mesh, "/groups/0/0/attn/wo/w", (5, 64, 64))
    assert spec[0] is None, spec   # stacked leading dim never sharded
    print("SHARDING_OK")
    """)
    assert "SHARDING_OK" in out


@pytest.mark.dist
def test_cache_spec_kv_fallbacks():
    from tests.dist.helpers import run_with_devices
    out = run_with_devices("""
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.dist import sharding as shr
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    # kv=4 divides model axis 4 -> shard kv
    spec = shr.cache_spec(mesh, "/k", (8, 16, 128, 4, 64))
    assert spec == P(None, ("data",), None, "model", None), spec
    # kv=2 does not divide -> fall through to head_dim
    spec = shr.cache_spec(mesh, "/k", (8, 16, 128, 2, 64))
    assert spec == P(None, ("data",), None, None, "model"), spec
    print("CACHE_OK")
    """)
    assert "CACHE_OK" in out


@pytest.mark.dist
def test_mesh_factories():
    from tests.dist.helpers import run_with_devices
    out = run_with_devices("""
    from repro.launch.mesh import make_production_mesh, dp_axes
    m1 = make_production_mesh()
    assert dict(m1.shape) == {"data": 16, "model": 16}
    m2 = make_production_mesh(multi_pod=True)
    assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}
    assert dp_axes(m2) == ("pod", "data")
    print("MESH_OK")
    """, n_devices=512)
    assert "MESH_OK" in out
