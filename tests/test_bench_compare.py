"""tools/bench_compare.py: the annotate-only perf-trajectory gate.

Regressions past the threshold become ``::warning::`` lines (never a
failure), improvements and small noise stay silent, and comparisons are
refused — not faked — when the ``meta`` provenance blocks are missing or
describe different backends/smoke settings."""
import importlib.util
import json
import pathlib

REPO = pathlib.Path(__file__).resolve().parents[1]


def _load():
    spec = importlib.util.spec_from_file_location(
        "bench_compare", REPO / "tools" / "bench_compare.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


META = {"git_sha": "a" * 40, "jax_version": "0.4.30", "backend": "cpu",
        "smoke": True}


def _report(**cells):
    return {"suite": "serving", "meta": dict(META),
            "results": [dict(name=name, **metrics)
                        for name, metrics in cells.items()]}


def test_regression_is_annotated_in_both_directions():
    mod = _load()
    base = _report(warm=dict(warm_us_per_request=100.0, measured_rps=50.0))
    cur = _report(warm=dict(warm_us_per_request=130.0, measured_rps=30.0))
    warnings, _ = mod.compare(base, cur, 0.2)
    assert len(warnings) == 2
    assert any("warm_us_per_request rose 30%" in w for w in warnings)
    assert any("measured_rps fell" in w for w in warnings)


def test_improvements_and_noise_stay_silent():
    mod = _load()
    base = _report(warm=dict(warm_us_per_request=100.0, measured_rps=50.0,
                             spectral_error=0.5, config_k=64))
    cur = _report(warm=dict(warm_us_per_request=85.0,    # improved
                            measured_rps=52.0,           # improved
                            spectral_error=0.4,          # improved (tracked)
                            config_k=512))               # untracked metric
    warnings, _ = mod.compare(base, cur, 0.2)
    assert warnings == []


def test_spectral_error_regression_is_tracked():
    mod = _load()
    base = _report(cell=dict(spectral_error=0.1))
    cur = _report(cell=dict(spectral_error=0.2))
    warnings, _ = mod.compare(base, cur, 0.2)
    assert len(warnings) == 1
    assert "spectral_error rose" in warnings[0]


def test_cells_on_one_side_are_informational():
    mod = _load()
    base = _report(old_cell=dict(us_per_call=10.0))
    cur = _report(new_cell=dict(us_per_call=10.0))
    warnings, infos = mod.compare(base, cur, 0.2)
    assert warnings == []
    assert {"cell new_cell only in current",
            "cell old_cell only in baseline"} == set(infos)


def test_nested_traffic_report_is_compared():
    mod = _load()
    base = _report(warm=dict(us_per_call=10.0))
    base["traffic"] = _report(steady=dict(p99_ms=100.0))
    cur = _report(warm=dict(us_per_call=10.0))
    cur["traffic"] = _report(steady=dict(p99_ms=200.0))
    warnings, _ = mod.compare(base, cur, 0.2)
    assert len(warnings) == 1 and "traffic/steady.p99_ms" in warnings[0]


def test_refuses_cross_backend_and_missing_meta():
    mod = _load()
    base, cur = _report(), _report()
    assert mod.check_meta(base, cur) is None
    cur["meta"]["backend"] = "gpu"
    assert "backend mismatch" in mod.check_meta(base, cur)
    cur["meta"]["backend"] = "cpu"
    cur["meta"]["smoke"] = False
    assert "smoke mismatch" in mod.check_meta(base, cur)
    del base["meta"]
    assert "missing meta" in mod.check_meta(_report(), {"results": []})


def test_cli_always_exits_zero(tmp_path, capsys):
    mod = _load()
    base = _report(warm=dict(us_per_call=10.0))
    cur = _report(warm=dict(us_per_call=20.0))
    bp, cp = tmp_path / "base.json", tmp_path / "cur.json"
    bp.write_text(json.dumps(base))
    cp.write_text(json.dumps(cur))
    assert mod.main([str(bp), str(cp)]) == 0
    out = capsys.readouterr().out
    assert "::warning" in out and "us_per_call rose 100%" in out
    # refusal path: cross-backend baseline
    base["meta"]["backend"] = "tpu"
    bp.write_text(json.dumps(base))
    assert mod.main([str(bp), str(cp)]) == 0
    assert "SKIP: refusing comparison" in capsys.readouterr().out
    # unreadable artifact path
    assert mod.main([str(tmp_path / "missing.json"), str(cp)]) == 0
    assert "SKIP: unreadable artifact" in capsys.readouterr().out


def test_ci_runs_traffic_smoke_and_bench_compare():
    ci = (REPO / ".github" / "workflows" / "ci.yml").read_text()
    assert "--suite traffic --smoke" in ci
    assert "tools/bench_compare.py" in ci
    assert "--cov=repro.serve.scheduler" in ci
    assert "--cov=repro.ckpt" in ci


def test_ci_runs_ingest_smoke_and_dist_lane():
    ci = (REPO / ".github" / "workflows" / "ci.yml").read_text()
    assert "--suite ingest --smoke" in ci
    assert "BENCH_ingest.json" in ci
    assert "--cov=repro.dist" in ci
    # the dist lane emulates 4 devices and selects only dist-marked tests
    assert "--xla_force_host_platform_device_count=4" in ci
    assert "-m dist" in ci
    # pytest's default norecursedirs hides tests/dist/ — the override that
    # keeps the multi-host suite collectable from the repo root must stay
    import re
    toml = (REPO / "pyproject.toml").read_text()
    m = re.search(r"^norecursedirs\s*=\s*(\[.*?\])", toml, re.M)
    assert m, "pyproject must override pytest's default norecursedirs"
    assert '"dist"' not in m.group(1)


def test_drift_tracking_error_is_gated_lower_is_better():
    # the streaming suite's drift cells report tracking_error; a rise past
    # the threshold must annotate, a drop must stay silent
    mod = _load()
    assert mod.TRACKED["tracking_error"] is True
    base = _report(**{"drift/window2": dict(tracking_error=0.4)})
    cur = _report(**{"drift/window2": dict(tracking_error=0.6)})
    warnings, _ = mod.compare(base, cur, 0.2)
    assert len(warnings) == 1 and "tracking_error rose 50%" in warnings[0]
    warnings, _ = mod.compare(cur, base, 0.2)   # improvement: silent
    assert warnings == []


def test_ingest_throughput_is_gated_higher_is_better():
    # the ingest suite's overlap cells report chunks_per_sec; a drop past
    # the threshold must annotate, a rise must stay silent
    mod = _load()
    assert mod.TRACKED["chunks_per_sec"] is False
    assert mod.TRACKED["achieved_gbps"] is False
    base = _report(**{"ingest/prefetch2": dict(chunks_per_sec=200.0)})
    cur = _report(**{"ingest/prefetch2": dict(chunks_per_sec=120.0)})
    warnings, _ = mod.compare(base, cur, 0.2)
    assert len(warnings) == 1 and "chunks_per_sec fell" in warnings[0]
    warnings, _ = mod.compare(cur, base, 0.2)   # improvement: silent
    assert warnings == []


def test_wire_bytes_per_state_is_gated_lower_is_better():
    # compressed-wire cells report wire_bytes_per_state; growth past the
    # threshold (a fatter wire format) must annotate, shrinkage is silent
    mod = _load()
    assert mod.TRACKED["wire_bytes_per_state"] is True
    base = _report(**{"wire/bf16": dict(wire_bytes_per_state=1000.0)})
    cur = _report(**{"wire/bf16": dict(wire_bytes_per_state=1500.0)})
    warnings, _ = mod.compare(base, cur, 0.2)
    assert len(warnings) == 1 and "wire_bytes_per_state rose 50%" in warnings[0]
    warnings, _ = mod.compare(cur, base, 0.2)   # improvement: silent
    assert warnings == []
