"""SketchService request validation + compiled-path behavior.

The submit() guards must be real ``ValueError``s (a bare ``assert`` is
stripped under ``python -O``, letting malformed requests corrupt a whole
bucket at stack time), and streaming sessions must ride the same warm
executable cache as one-shot flushes."""
import jax
import numpy as np
import pytest

from repro.core.pipeline import PipelineEngine
from repro.serve.engine import SketchService

from tests.conftest import gaussian_pair


def test_submit_rejects_non_2d_inputs(key):
    svc = SketchService(k=8, backend="scan", block=32)
    A, B = gaussian_pair(key, d=64, n1=6, n2=5)
    with pytest.raises(ValueError, match=r"2-D.*\(64, 6, 1\)"):
        svc.submit(key, A[..., None], B)          # 3-D A
    with pytest.raises(ValueError, match="2-D"):
        svc.submit(key, A, B[:, 0])               # 1-D B
    assert svc.pending == 0                       # nothing was queued


def test_submit_rejects_mismatched_row_dimension(key):
    svc = SketchService(k=8, backend="scan", block=32)
    A, B = gaussian_pair(key, d=64, n1=6, n2=5)
    with pytest.raises(ValueError,
                       match=r"row dimension.*\(64, 6\).*\(32, 5\)"):
        svc.submit(key, A, B[:32])
    assert svc.pending == 0
    assert isinstance(svc.submit(key, A, B), int)  # valid request still works


def test_stream_factors_shares_warm_executables(key):
    """Two sessions with the same shapes/args share one compiled from-summary
    executable: the second stream_factors call traces nothing."""
    eng = PipelineEngine()
    svc = SketchService(k=8, backend="scan", block=32, engine=eng)
    A, B = gaussian_pair(key, d=64, n1=6, n2=5)
    sid = svc.open_stream(key, 64, 6, 5)
    svc.append(sid, A, B)
    first = svc.stream_factors(sid, r=2, m=100, T=2)
    traces0 = eng.stats.traces
    sid2 = svc.open_stream(jax.random.fold_in(key, 1), 64, 6, 5)
    svc.append(sid2, A, B)
    second = svc.stream_factors(sid2, r=2, m=100, T=2)
    assert eng.stats.traces == traces0            # warm: zero new traces
    assert eng.stats.hits >= 1
    assert first.factors.U.shape == second.factors.U.shape
    # different keys -> different sampled completions (sanity, not parity)
    assert not np.array_equal(np.asarray(first.factors.U),
                              np.asarray(second.factors.U))


def test_flush_and_flush_factors_share_summary_randomness(key):
    """flush() (summary-only executable) and flush_factors() (fused
    executable) agree bit-for-bit on the summary for the same request."""
    eng = PipelineEngine()
    svc = SketchService(k=8, backend="scan", block=32, engine=eng)
    A, B = gaussian_pair(key, d=64, n1=6, n2=5)
    t0 = svc.submit(key, A, B)
    summary = svc.flush()[t0]
    t1 = svc.submit(key, A, B)
    served = svc.flush_factors(r=2, m=100, T=2)[t1]
    for name in ("A_sketch", "B_sketch", "norm_A", "norm_B"):
        np.testing.assert_array_equal(
            np.asarray(getattr(summary, name)),
            np.asarray(getattr(served.summary, name)))


def test_unknown_stream_id_raises_keyerror_with_id(key):
    """Every stream entry point names the offending id in a KeyError —
    never a bare dict miss — for unknown AND already-closed streams."""
    svc = SketchService(k=8, backend="scan", block=32)
    A, B = gaussian_pair(key, d=64, n1=6, n2=5)
    for call in (lambda: svc.append("nope", A, B),
                 lambda: svc.stream_factors("nope", r=2, m=100, T=2),
                 lambda: svc.close_stream("nope")):
        with pytest.raises(KeyError, match="'nope'"):
            call()
    sid = svc.open_stream(key, 64, 6, 5)
    svc.append(sid, A, B)
    svc.close_stream(sid)
    with pytest.raises(KeyError, match=str(sid)):
        svc.append(sid, A, B)
    with pytest.raises(KeyError, match=str(sid)):
        svc.stream_factors(sid, r=2, m=100, T=2)
    with pytest.raises(KeyError, match=str(sid)):
        svc.close_stream(sid)


def test_empty_flush_returns_empty_without_dispatch(key):
    """flush()/flush_factors() with nothing queued return {} and never
    touch the engine — no dispatch, no trace, no cache lookup."""
    eng = PipelineEngine()
    svc = SketchService(k=8, backend="scan", block=32, engine=eng)
    assert svc.flush() == {}
    assert svc.flush_factors(r=2, m=100, T=2) == {}
    assert eng.stats.traces == 0
    assert eng.stats.hits == 0 and eng.stats.misses == 0
    assert svc.loop.stats.dispatches == 0
    # flush_factors still validates its own arguments on the empty path
    with pytest.raises(ValueError):
        svc.flush_factors(r="auto")               # auto rank needs tol


def test_default_engine_is_shared_across_services(key):
    """Unpinned services share the process-default engine, so one service's
    warm plans serve another's identical traffic."""
    from repro.core import pipeline
    a = SketchService(k=8, backend="scan", block=32)
    b = SketchService(k=8, backend="scan", block=32)
    assert a.engine is b.engine is pipeline.get_engine()
    c = SketchService(k=8, backend="scan", block=32,
                      engine=PipelineEngine(max_entries=4))
    assert c.engine is not a.engine
