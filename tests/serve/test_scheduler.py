"""Scheduler/dispatcher edge cases: the continuous-batching contract.

Everything host-side runs under a virtual clock (``clock=lambda: now[0]``)
so deadline forcing, wait-time shedding, and EDF ordering are deterministic;
the dispatch tests use tiny shapes so each executable compiles once and the
warm-path assertions read real ``PipelineEngine`` trace counters."""
import jax
import numpy as np
import pytest

from repro.core import pipeline
from repro.core.pipeline import PipelineEngine
from repro.serve.scheduler import (
    DISPATCH_DEADLINE,
    DISPATCH_DRAIN,
    DISPATCH_FULL,
    SHED_QUEUE_FULL,
    SHED_WAIT_EXCEEDED,
    LoopConfig,
    PipelineWork,
    Rejected,
    ServingLoop,
    SummaryWork,
)

from tests.conftest import gaussian_pair

SPEC = pipeline.SketchSpec(k=8, backend="scan", block=32)
PLAN = pipeline.PipelinePlan(
    sketch=SPEC,
    estimation=pipeline.EstimationSpec(m=64, T=2),
    rank=pipeline.RankPolicy(r=2), key_layout="service")


def _loop(now, **kw):
    return ServingLoop(engine=PipelineEngine(),
                       config=LoopConfig(**kw), clock=lambda: now[0])


def test_full_batch_dispatches_on_poll(key):
    """A bucket's open batch dispatches the moment it holds max_batch
    requests — continuous batching, no flush call anywhere."""
    now = [0.0]
    loop = _loop(now, max_batch=2)
    A, B = gaussian_pair(key, d=64, n1=6, n2=5)
    f1 = loop.submit(key, A, B, work=SummaryWork(SPEC))
    assert loop.poll() == 0                        # 1/2: stays open
    f2 = loop.submit(jax.random.fold_in(key, 1), A, B, work=SummaryWork(SPEC))
    assert loop.poll() == 1                        # 2/2: ONE fused dispatch
    assert f1.done and f2.done
    assert f1.result().A_sketch.shape == (8, 6)
    assert loop.stats.occupancy == 2.0
    assert loop.stats.dispatched[DISPATCH_FULL] == 1


def test_deadline_forces_partial_batch(key):
    """A lone request cannot wait forever for batch-mates: when its SLO
    budget runs out the scheduler dispatches the partial batch."""
    now = [0.0]
    loop = _loop(now, max_batch=4, dispatch_margin=0.1)
    A, B = gaussian_pair(key, d=64, n1=6, n2=5)
    f = loop.submit(key, A, B, work=SummaryWork(SPEC), deadline=1.0)
    assert loop.poll() == 0                        # budget remains: hold
    now[0] = 0.85
    assert loop.poll() == 0                        # 1.0 - 0.85 > margin
    now[0] = 0.95
    assert loop.poll() == 1                        # forced, 1/4 occupancy
    assert f.done and f.shed_reason is None
    assert loop.stats.dispatched[DISPATCH_DEADLINE] == 1
    assert loop.stats.batched_requests == 1


def test_shed_on_full_queue(key):
    """Admission past max_queue raises Rejected(SHED_QUEUE_FULL) — the
    backpressure signal — and queues nothing."""
    now = [0.0]
    loop = _loop(now, max_queue=2)
    A, B = gaussian_pair(key, d=64, n1=6, n2=5)
    loop.submit(key, A, B, work=SummaryWork(SPEC))
    loop.submit(jax.random.fold_in(key, 1), A, B, work=SummaryWork(SPEC))
    with pytest.raises(Rejected, match="depth limit") as exc:
        loop.submit(jax.random.fold_in(key, 2), A, B, work=SummaryWork(SPEC))
    assert exc.value.reason == SHED_QUEUE_FULL
    assert loop.depth == 2
    assert loop.stats.shed[SHED_QUEUE_FULL] == 1
    assert loop.stats.admitted == 2


def test_wait_time_shed(key):
    """Requests queued past max_wait are shed at the next poll: the future
    resolves with the shed reason and result() raises Rejected."""
    now = [0.0]
    loop = _loop(now, max_wait=0.5)
    A, B = gaussian_pair(key, d=64, n1=6, n2=5)
    f = loop.submit(key, A, B, work=SummaryWork(SPEC))
    now[0] = 0.6
    assert loop.poll() == 0                        # shed, not dispatched
    assert f.done and f.shed_reason == SHED_WAIT_EXCEEDED
    with pytest.raises(Rejected, match="max_wait"):
        f.result()
    assert loop.depth == 0
    assert loop.stats.shed[SHED_WAIT_EXCEEDED] == 1


def test_no_priority_inversion_across_buckets(key):
    """When several batches are ready, they dispatch earliest-deadline
    first — a late-deadline pile-up in one shape bucket cannot starve an
    earlier deadline in another."""
    now = [0.0]
    loop = _loop(now, max_batch=4, dispatch_margin=0.0)
    A1, B1 = gaussian_pair(key, d=64, n1=6, n2=5)
    A2, B2 = gaussian_pair(jax.random.fold_in(key, 9), d=64, n1=4, n2=3)
    late = loop.submit(key, A1, B1, work=SummaryWork(SPEC), deadline=10.0)
    early = loop.submit(key, A2, B2, work=SummaryWork(SPEC), deadline=1.0)
    now[0] = 10.0                                  # both deadlines due
    assert loop.poll() == 2
    assert early.dispatch_seq < late.dispatch_seq


def test_edf_within_an_overfull_bucket(key):
    """An overfull bucket serves its most urgent members in the first
    (full) batch; the late-deadline straggler waits for its own budget."""
    now = [0.0]
    loop = _loop(now, max_batch=2)
    A, B = gaussian_pair(key, d=64, n1=6, n2=5)
    f_late = loop.submit(key, A, B, work=SummaryWork(SPEC), deadline=9.0)
    f_mid = loop.submit(jax.random.fold_in(key, 1), A, B,
                        work=SummaryWork(SPEC), deadline=5.0)
    f_soon = loop.submit(jax.random.fold_in(key, 2), A, B,
                         work=SummaryWork(SPEC), deadline=1.0)
    assert loop.poll() == 1                        # full batch: soon + mid
    assert f_soon.done and f_mid.done and not f_late.done
    assert f_soon.dispatch_seq == f_mid.dispatch_seq
    now[0] = 9.0
    assert loop.poll() == 1                        # straggler's own deadline
    assert f_late.done
    assert loop.stats.dispatched == {DISPATCH_FULL: 1, DISPATCH_DEADLINE: 1}


def test_tenant_isolation_same_key_bit_different(key):
    """Two tenants submitting the SAME user key batch together (one fused
    dispatch — tenancy is not in the batch signature) yet get bit-different
    sketches; tenant=None reproduces the un-namespaced baseline exactly."""
    now = [0.0]
    loop = _loop(now)
    A, B = gaussian_pair(key, d=64, n1=6, n2=5)
    f_acme = loop.submit(key, A, B, work=SummaryWork(SPEC), tenant="acme")
    f_glob = loop.submit(key, A, B, work=SummaryWork(SPEC), tenant="globex")
    f_none = loop.submit(key, A, B, work=SummaryWork(SPEC))
    assert loop.drain() == 1                       # mixed tenants, ONE batch
    s_acme, s_glob, s_none = (f.result() for f in (f_acme, f_glob, f_none))
    assert not np.array_equal(np.asarray(s_acme.A_sketch),
                              np.asarray(s_glob.A_sketch))
    assert not np.array_equal(np.asarray(s_acme.A_sketch),
                              np.asarray(s_none.A_sketch))
    from repro.core import summary_engine
    baseline = summary_engine.build_summary(key, A, B, 8, backend="scan",
                                            block=32)
    np.testing.assert_array_equal(np.asarray(s_none.A_sketch),
                                  np.asarray(baseline.A_sketch))
    manual = summary_engine.build_summary(
        pipeline.tenant_key(key, "acme"), A, B, 8, backend="scan", block=32)
    np.testing.assert_array_equal(np.asarray(s_acme.A_sketch),
                                  np.asarray(manual.A_sketch))


def test_warm_cache_mixed_shape_traffic_zero_retraces(key):
    """After one cold pass per (shape bucket, batch width), sustained
    mixed-shape traffic is pure cache hits: zero new traces, occupancy > 1.
    pad='pow2' maps variable batch sizes onto the already-warm widths."""
    now = [0.0]
    loop = _loop(now, max_batch=2, pad="pow2", dispatch_margin=0.0)
    engine = loop.engine
    pairs = [gaussian_pair(key, d=64, n1=6, n2=5),
             gaussian_pair(jax.random.fold_in(key, 9), d=64, n1=4, n2=3)]
    # cold pass: widths 1 and 2 per shape bucket
    for i, (A, B) in enumerate(pairs):
        loop.submit(jax.random.fold_in(key, i), A, B,
                    work=SummaryWork(SPEC), deadline=0.0)
        loop.poll()                                # width 1 (deadline-forced)
        loop.submit(jax.random.fold_in(key, i + 2), A, B,
                    work=SummaryWork(SPEC))
        loop.submit(jax.random.fold_in(key, i + 4), A, B,
                    work=SummaryWork(SPEC))
        loop.poll()                                # width 2 (full)
    traces_cold = engine.stats.traces
    dispatches_cold = loop.stats.dispatches
    # steady state: interleaved mixed-shape traffic, full and partial batches
    for rep in range(3):
        fs = []
        for i, (A, B) in enumerate(pairs):
            fs.append(loop.submit(
                jax.random.fold_in(key, 10 + rep * 4 + i), A, B,
                work=SummaryWork(SPEC)))
            fs.append(loop.submit(
                jax.random.fold_in(key, 20 + rep * 4 + i), A, B,
                work=SummaryWork(SPEC)))
        loop.poll()
        # and a deadline-forced partial (width 1 -> already-warm executable)
        f = loop.submit(jax.random.fold_in(key, 30 + rep), pairs[0][0],
                        pairs[0][1], work=SummaryWork(SPEC), deadline=0.0)
        loop.poll()
        assert all(x.done for x in fs) and f.done
    assert engine.stats.traces == traces_cold      # zero new traces, warm
    assert loop.stats.dispatches > dispatches_cold
    assert loop.stats.occupancy > 1.0


def test_pow2_padding_is_bit_exact_and_bounds_traces(key):
    """A padded partial batch returns bit-identical per-request results to
    an unpadded loop, and shares the padded width's executable (no new
    trace when a genuinely full batch of that width arrives later)."""
    A, B = gaussian_pair(key, d=64, n1=6, n2=5)
    keys = [jax.random.fold_in(key, i) for i in range(7)]

    def run(pad):
        now = [0.0]
        loop = _loop(now, max_batch=4, pad=pad)
        fs = [loop.submit(k, A, B, work=SummaryWork(SPEC)) for k in keys[:3]]
        loop.drain()                               # batch of 3
        return loop, [f.result() for f in fs]

    loop_p, padded = run("pow2")
    loop_n, plain = run("none")
    for sp, sn in zip(padded, plain):
        np.testing.assert_array_equal(np.asarray(sp.A_sketch),
                                      np.asarray(sn.A_sketch))
    # the 3-request batch compiled the width-4 executable: a real full batch
    # of 4 is now a cache hit
    traces = loop_p.engine.stats.traces
    fs = [loop_p.submit(k, A, B, work=SummaryWork(SPEC)) for k in keys[:4]]
    assert loop_p.poll() == 1
    assert loop_p.engine.stats.traces == traces
    assert all(f.done for f in fs)


def test_drain_dispatches_whole_buckets(key):
    """drain() (the flush path) ignores max_batch: one fused dispatch per
    shape bucket, preserving the historical SketchService parity."""
    now = [0.0]
    loop = _loop(now, max_batch=2)
    A, B = gaussian_pair(key, d=64, n1=6, n2=5)
    fs = [loop.submit(jax.random.fold_in(key, i), A, B,
                      work=SummaryWork(SPEC), deadline=100.0 + i)
          for i in range(5)]
    # 2 full batches pop on poll; drain takes the remaining 3 as ONE batch
    assert loop.poll() == 2
    assert loop.drain() == 1
    assert all(f.done for f in fs)
    assert loop.stats.dispatched[DISPATCH_DRAIN] == 1
    assert loop.stats.batched_requests == 5


def test_background_pump_resolves_futures(key):
    """start()/stop(): callers just submit and block on futures; batching,
    deadline forcing, and dispatch all happen on the loop thread."""
    loop = ServingLoop(engine=PipelineEngine(),
                       config=LoopConfig(max_batch=2,
                                         default_deadline=0.05))
    A, B = gaussian_pair(key, d=64, n1=6, n2=5)
    loop.start(interval=1e-3)
    try:
        fs = [loop.submit(jax.random.fold_in(key, i), A, B,
                          work=PipelineWork(PLAN)) for i in range(3)]
        outs = [f.result(timeout=120.0) for f in fs]
    finally:
        loop.stop()
    assert all(o.estimate.factors.U.shape == (6, 2) for o in outs)
    assert loop.stats.completed == 3
    # 2 went as a full batch; the straggler was deadline-forced
    assert loop.stats.dispatches == 2


def test_loop_config_validation():
    with pytest.raises(ValueError, match="max_batch"):
        ServingLoop(engine=PipelineEngine(),
                    config=LoopConfig(max_batch=0))
    with pytest.raises(ValueError, match="max_queue"):
        ServingLoop(engine=PipelineEngine(),
                    config=LoopConfig(max_queue=0))
    with pytest.raises(ValueError, match="pad"):
        ServingLoop(engine=PipelineEngine(),
                    config=LoopConfig(pad="pow3"))
