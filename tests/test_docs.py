"""Docs gate in tier-1: the docs/ subsystem exists, README links to it,
every relative markdown link resolves, and the public-API doctest examples
execute (the same checks the `docs` CI job runs via tools/check_docs.py)."""
import importlib.util
import os
import pathlib

REPO = pathlib.Path(__file__).resolve().parents[1]


def _load_check_docs():
    spec = importlib.util.spec_from_file_location(
        "check_docs", REPO / "tools" / "check_docs.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_docs_exist_and_linked_from_readme():
    readme = (REPO / "README.md").read_text()
    for doc in ("docs/architecture.md", "docs/paper_map.md",
                "docs/streaming.md", "docs/pipeline.md",
                "docs/serving.md", "docs/kernels.md"):
        assert (REPO / doc).exists(), doc
        assert doc in readme, f"README does not link {doc}"


def test_markdown_links_resolve():
    mod = _load_check_docs()
    assert mod.check_links() == []


def test_public_api_doctests():
    mod = _load_check_docs()
    assert mod.run_doctests() == 0


def test_ci_has_docs_and_streaming_jobs():
    ci = (REPO / ".github" / "workflows" / "ci.yml").read_text()
    assert "tools/check_docs.py" in ci
    assert "--suite streaming" in ci
    assert "--suite traffic" in ci
    assert "--suite kernels" in ci
    assert "cancel-in-progress: true" in ci
    assert os.path.exists(REPO / "benchmarks" / "run.py")


def test_scheduler_doctests_are_wired_into_docs_gate():
    mod = _load_check_docs()
    assert "repro.serve.scheduler" in mod.DOCTEST_MODULES
    assert "repro.kernels.tuning" in mod.DOCTEST_MODULES
    assert "repro.dist.multihost" in mod.DOCTEST_MODULES


def test_streaming_doc_covers_scale_out_ingest():
    doc = (REPO / "docs" / "streaming.md").read_text()
    assert "## Scale-out ingest" in doc
    assert "cross_host_merge" in doc
    assert "choose_wire_spec" in doc
    arch = (REPO / "docs" / "architecture.md").read_text()
    assert "streaming.md#scale-out-ingest" in arch
