"""Training substrate tests: optimizer, trainer loop, fault recovery,
checkpointing, gradient compression (both paths), data pipeline."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from repro.ckpt import checkpoint
from repro.configs import get_config
from repro.data.pipeline import SyntheticLM, cooccurrence_stream
from repro.models import build
from repro.optim import AdamW, warmup_cosine
from repro.optim import grad_compression as gc
from repro.train import TrainConfig, Trainer, TrainerConfig
from repro.train import sketched_dense as sd


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_converges_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"x": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"x": 2.0 * params["x"]}
        params, state = opt.update(grads, state, params)
    assert float(jnp.abs(params["x"]).max()) < 1e-2


def test_adamw_bf16_moments():
    opt = AdamW(lr=1e-2, moment_dtype=jnp.bfloat16)
    params = {"w": jnp.ones((8, 8))}
    state = opt.init(params)
    assert state.mu["w"].dtype == jnp.bfloat16
    params2, _ = opt.update({"w": jnp.ones((8, 8))}, state, params)
    assert jnp.isfinite(params2["w"]).all()


def test_warmup_cosine_shape():
    s = warmup_cosine(1.0, 10, 100)
    assert float(s(jnp.int32(0))) == 0.0
    assert abs(float(s(jnp.int32(10))) - 1.0) < 0.11
    assert float(s(jnp.int32(100))) < 0.2


# ---------------------------------------------------------------------------
# trainer: loss decreases, checkpoint/restart, fault recovery, determinism
# ---------------------------------------------------------------------------

def _tiny_setup(td, steps=30, compression="none"):
    cfg = get_config("phi3-mini-3.8b").reduced()
    m = build(cfg)
    data = SyntheticLM(vocab_size=cfg.vocab_size, batch_size=4, seq_len=64)
    opt = AdamW(lr=warmup_cosine(3e-3, 5, steps), weight_decay=0.01)
    tcfg = TrainConfig(microbatches=2, compression=compression)
    tr = Trainer(m.loss, opt, data, tcfg,
                 TrainerConfig(num_steps=steps, ckpt_dir=td, ckpt_every=10,
                               log_every=1000),
                 init_params_fn=m.init_params)
    return tr


def test_loss_decreases():
    with tempfile.TemporaryDirectory() as td:
        tr = _tiny_setup(td)
        tr.run()
        losses = [h["loss"] for h in tr.metrics_history]
        assert losses[-1] < losses[0] * 0.9, (losses[0], losses[-1])


def test_fault_recovery_resumes_from_checkpoint():
    with tempfile.TemporaryDirectory() as td:
        tr = _tiny_setup(td, steps=25)
        fired = {"n": 0}

        def hook(step):
            if step == 15 and fired["n"] == 0:
                fired["n"] = 1
                raise RuntimeError("simulated preemption")

        state = tr.run(fault_hook=hook)
        assert int(state.step) == 25
        assert fired["n"] == 1


def test_restart_continues_training():
    """Kill after 20 steps; a fresh Trainer resumes at the checkpoint."""
    with tempfile.TemporaryDirectory() as td:
        tr1 = _tiny_setup(td, steps=20)
        tr1.run()
        tr2 = _tiny_setup(td, steps=30)
        state = tr2.run()
        assert int(state.step) == 30
        # resumed run starts at step 20 (skip-ahead)
        assert tr2.metrics_history[0]["step"] == 20


def test_data_pipeline_deterministic_skip_ahead():
    d1 = SyntheticLM(vocab_size=100, batch_size=2, seq_len=16, seed=3)
    d2 = SyntheticLM(vocab_size=100, batch_size=2, seq_len=16, seed=3)
    b1 = d1.batch(17)
    b2 = d2.batch(17)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = d1.batch(18)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))


def test_data_pipeline_host_sharding_disjoint():
    a = SyntheticLM(vocab_size=100, batch_size=2, seq_len=16, n_hosts=2,
                    host_id=0).batch(0)
    b = SyntheticLM(vocab_size=100, batch_size=2, seq_len=16, n_hosts=2,
                    host_id=1).batch(0)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))


# ---------------------------------------------------------------------------
# checkpoint unit tests
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_exact():
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.int32(7), "c": (jnp.ones(2), jnp.zeros(3))}}
    with tempfile.TemporaryDirectory() as td:
        checkpoint.save(td, 5, tree)
        out = checkpoint.restore(td, tree)
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_keep_n_and_latest():
    tree = {"a": jnp.ones(3)}
    with tempfile.TemporaryDirectory() as td:
        for s in (1, 2, 3, 4):
            checkpoint.save(td, s, tree, keep=2)
        assert checkpoint.latest_step(td) == 4
        assert sorted(os.listdir(td)) == ["step_00000003", "step_00000004"]


def test_checkpoint_atomicity_partial_write_ignored():
    """A stale .tmp dir (crash mid-write) must not be visible as a ckpt."""
    tree = {"a": jnp.ones(3)}
    with tempfile.TemporaryDirectory() as td:
        checkpoint.save(td, 1, tree)
        os.makedirs(os.path.join(td, "step_00000002.tmp"))
        assert checkpoint.latest_step(td) == 1
        out = checkpoint.restore(td, tree)
        np.testing.assert_array_equal(np.asarray(out["a"]), np.ones(3))


def test_checkpoint_async():
    tree = {"a": jnp.ones((64, 64))}
    with tempfile.TemporaryDirectory() as td:
        t = checkpoint.save_async(td, 3, tree)
        t.join(timeout=30)
        assert checkpoint.latest_step(td) == 3


# ---------------------------------------------------------------------------
# gradient compression paths
# ---------------------------------------------------------------------------

def test_training_with_lowrank_compression_converges():
    with tempfile.TemporaryDirectory() as td:
        tr = _tiny_setup(td, steps=25, compression="lowrank")
        tr.run()
        losses = [h["loss"] for h in tr.metrics_history]
        assert np.isfinite(losses).all()
        assert losses[-1] < losses[0], (losses[0], losses[-1])


def test_error_feedback_accumulates_residual():
    key = jax.random.PRNGKey(0)
    G = jax.random.normal(key, (96, 128))
    grads = {"w": G}
    st0 = gc.init_state(grads)
    out, st1, _ = gc.compress_grads(key, grads, st0,
                                    gc.CompressionConfig(rank=4, sketch_k=256))
    # residual = input - reconstruction
    np.testing.assert_allclose(np.asarray(st1.err["w"]),
                               np.asarray(G - out["w"]), rtol=1e-4, atol=1e-4)
    # next step feeds residual back: compress(G2 + err)
    G2 = jax.random.normal(jax.random.fold_in(key, 1), (96, 128))
    out2, st2, _ = gc.compress_grads(key, {"w": G2}, st1,
                                     gc.CompressionConfig(rank=4, sketch_k=256))
    np.testing.assert_allclose(
        np.asarray(st2.err["w"]),
        np.asarray(G2 + st1.err["w"] - out2["w"]), rtol=1e-4, atol=1e-4)


def test_sketched_dense_taps_ride_grads():
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (64, 96)) * 0.1
    taps = sd.tap_init(64, 96, 16)
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 32, 64))

    def loss(w, taps, x):
        y = sd.sketched_dense(w, taps, x, key, 16, 32)
        return jnp.mean(y ** 2)

    dw, dtaps, dx = jax.grad(loss, argnums=(0, 1, 2))(w, taps, x)
    assert bool((dw == 0).all())                 # dW never materialized
    assert float(jnp.abs(dtaps["a"]).sum()) > 0  # sketches present
    assert dx.shape == x.shape
    # dx must equal the uncompressed layer's dx (fwd/dx path untouched)
    dx_ref = jax.grad(lambda x: jnp.mean((x @ w) ** 2))(x)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(dx_ref),
                               rtol=1e-4, atol=1e-5)


def test_decompress_tapped_grads_walks_stacked_layers():
    key = jax.random.PRNGKey(0)
    k = 16
    grads = {"groups": [{"w": jnp.zeros((3, 32, 48)),
                         "taps": {"a": jnp.ones((3, k, 32)),
                                  "b": jnp.ones((3, k, 48)),
                                  "na2": jnp.ones((3, 32)),
                                  "nb2": jnp.ones((3, 48))}}]}
    out = sd.decompress_tapped_grads(key, grads, sd.TapConfig(sketch_k=k,
                                                              rank=2))
    assert out["groups"][0]["w"].shape == (3, 32, 48)
    assert float(jnp.abs(out["groups"][0]["taps"]["a"]).sum()) == 0.0


def test_cooccurrence_stream_order_independent_summary():
    """The examples' streaming source + arbitrary-order one-pass summary."""
    from repro import core
    key = jax.random.PRNGKey(0)
    d, n1, n2 = 256, 12, 10
    chunks = list(cooccurrence_stream(0, d, n1, n2, rank=3, chunk=64))
    summaries = []
    for rows, Ar, Br in chunks:
        summaries.append(core.streamed_rows_summary(
            key, jnp.asarray(rows), jnp.asarray(Ar), jnp.asarray(Br), k=16))
    merged = summaries[0]
    for s in summaries[1:]:
        merged = core.merge_summaries(merged, s)
    # reassemble in-order reference
    import numpy as onp
    A = onp.zeros((d, n1), onp.float32)
    B = onp.zeros((d, n2), onp.float32)
    for rows, Ar, Br in chunks:
        A[rows] = Ar
        B[rows] = Br
    ref = core.streamed_rows_summary(key, jnp.arange(d), jnp.asarray(A),
                                     jnp.asarray(B), k=16)
    np.testing.assert_allclose(np.asarray(merged.A_sketch),
                               np.asarray(ref.A_sketch), rtol=2e-4, atol=2e-4)
