"""ErrorEngine tests: a-posteriori estimates, adaptive rank, probe monoid.

The contract: the probe block ``(A^T B) @ Omega`` rides the existing
single-pass/streaming/merge monoid bit-for-bit; ``estimate_error`` is an
unbiased Frobenius-residual estimator (within 2x of the truth on every
method x backend cell on the known-spectrum fixtures); ``adaptive_rank``
returns the smallest rank whose estimated error meets the tolerance from
ONE factorization.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    from tests._hyp import given, settings
    from tests._hyp import strategies as st

from repro import core
from repro.core import error_engine as ee
from repro.core.estimation_engine import estimate_product, estimators
from repro.core.summary_engine import build_summary
from tests.conftest import gaussian_pair, known_spectrum_pair, spectrum_values


# ---------------------------------------------------------------------------
# Probe block: single-pass accumulation + monoid laws
# ---------------------------------------------------------------------------

def test_probe_block_exact_and_backend_invariant(key):
    """probes == (A^T B) @ Omega to float tolerance, and the probe stage is
    bit-identical across every in-process backend (it is backend-free)."""
    A, B = gaussian_pair(key, d=256, n1=20, n2=16)
    ss = {b: build_summary(key, A, B, 32, backend=b, probes=8, block=64)
          for b in ("reference", "scan", "rows", "pallas")}
    ref = ss["reference"]
    want = np.asarray(A.T @ B @ ref.probe_omega)
    np.testing.assert_allclose(np.asarray(ref.probes), want, rtol=1e-4,
                               atol=1e-4 * np.abs(want).max())
    for b, s in ss.items():
        assert s.n_probes == 8
        np.testing.assert_array_equal(np.asarray(s.probes),
                                      np.asarray(ref.probes), err_msg=b)
        np.testing.assert_array_equal(np.asarray(s.probe_omega),
                                      np.asarray(ref.probe_omega), err_msg=b)


@pytest.mark.parametrize("method", ["gaussian", "srht"])
def test_streamed_probes_bit_identical_to_scan(key, method):
    """Sequential chunked ingestion with probes retained == the scan-backend
    one-shot summary bit-for-bit, probe block included (the acceptance
    criterion)."""
    A, B = gaussian_pair(key, d=256, n1=20, n2=16)
    summ = core.StreamingSummarizer(16, method=method, probes=8)
    state = summ.init(key, (256, 20, 16))
    for off in range(0, 256, 64):
        state = summ.update(state, A[off:off + 64], B[off:off + 64], off)
    s = summ.finalize(state)
    scan = build_summary(key, A, B, 16, method=method, backend="scan",
                         block=64, probes=8)
    for name in ("A_sketch", "B_sketch", "norm_A", "norm_B", "probes",
                 "probe_omega"):
        np.testing.assert_array_equal(np.asarray(getattr(s, name)),
                                      np.asarray(getattr(scan, name)),
                                      err_msg=f"{method}/{name}")


def test_probe_merge_commutative_bitwise(key):
    """Probe accumulators merge as a plain sum: commutative bit-for-bit,
    through both merge_states and merge_summaries."""
    A, B = gaussian_pair(key)
    summ = core.StreamingSummarizer(8, probes=4)
    empty = summ.init(key, (192, 11, 7))
    s1 = summ.update(empty, A[:96], B[:96], 0)
    s2 = summ.update(empty, A[96:], B[96:], 96)
    m12, m21 = summ.merge(s1, s2), summ.merge(s2, s1)
    np.testing.assert_array_equal(np.asarray(m12.probe_acc),
                                  np.asarray(m21.probe_acc))
    f12 = core.merge_summaries(summ.finalize(s1), summ.finalize(s2))
    f21 = core.merge_summaries(summ.finalize(s2), summ.finalize(s1))
    np.testing.assert_array_equal(np.asarray(f12.probes),
                                  np.asarray(f21.probes))


@settings(deadline=None, max_examples=8)
@given(i=st.sampled_from([32, 64, 96]), j=st.sampled_from([128, 160]))
def test_probe_merge_associative_property(i, j):
    """Any three-way split/merge of the rows reproduces the one-shot probe
    block to float-reassociation tolerance (monoid law property test)."""
    key = jax.random.PRNGKey(3)
    A, B = gaussian_pair(key)
    summ = core.StreamingSummarizer(8, probes=6)
    empty = summ.init(key, (192, 11, 7))
    a = summ.update(empty, A[:i], B[:i], 0)
    b = summ.update(empty, A[i:j], B[i:j], i)
    c = summ.update(empty, A[j:], B[j:], j)
    left = summ.finalize(summ.merge(summ.merge(a, b), c))
    right = summ.finalize(summ.merge(a, summ.merge(b, c)))
    np.testing.assert_allclose(np.asarray(left.probes),
                               np.asarray(right.probes), rtol=2e-5,
                               atol=1e-5)
    one_shot = build_summary(key, A, B, 8, probes=6)
    scale = float(np.abs(np.asarray(one_shot.probes)).max())
    np.testing.assert_allclose(np.asarray(left.probes),
                               np.asarray(one_shot.probes), rtol=2e-4,
                               atol=1e-5 * scale)


def test_update_rows_probes_order_independent(key):
    """Arbitrary-order row arrival accumulates the same probe block."""
    A, B = gaussian_pair(key)
    summ = core.StreamingSummarizer(8, probes=6)
    ref = build_summary(key, A, B, 8, probes=6)
    perm = np.random.default_rng(0).permutation(192)
    state = summ.init(key, (192, 11, 7))
    for off in range(0, 192, 48):
        ids = jnp.asarray(perm[off:off + 48])
        state = summ.update_rows(state, ids, A[ids], B[ids])
    got = summ.finalize(state)
    scale = float(np.abs(np.asarray(ref.probes)).max())
    np.testing.assert_allclose(np.asarray(got.probes),
                               np.asarray(ref.probes), rtol=2e-4,
                               atol=1e-5 * scale)


def test_probe_presence_mismatch_rejected(key):
    summ_p = core.StreamingSummarizer(8, probes=4)
    summ_0 = core.StreamingSummarizer(8)
    s_p = summ_p.init(key, (64, 4, 3))
    s_0 = summ_0.init(key, (64, 4, 3))
    with pytest.raises(ValueError, match="probe"):
        core.merge_states(s_p, s_0)
    with pytest.raises(ValueError, match="probe"):
        core.merge_summaries(summ_p.finalize(s_p), summ_0.finalize(s_0))


def test_checkpoint_roundtrip_with_probes(key, tmp_path):
    """StreamState probe fields checkpoint bit-exactly; the manifest records
    the probe count."""
    from repro.ckpt import checkpoint
    A, B = gaussian_pair(key)
    summ = core.StreamingSummarizer(8, probes=4)
    half = summ.update(summ.init(key, (192, 11, 7)), A[:96], B[:96], 0)
    checkpoint.save_stream_state(str(tmp_path), 96, half)
    assert checkpoint.read_manifest(str(tmp_path))["extra"]["probes"] == 4
    restored = checkpoint.restore_stream_state(
        str(tmp_path), like=summ.init(key, (192, 11, 7)))
    resumed = summ.finalize(summ.update(restored, A[96:], B[96:], 96))
    direct = summ.finalize(summ.update(half, A[96:], B[96:], 96))
    np.testing.assert_array_equal(np.asarray(resumed.probes),
                                  np.asarray(direct.probes))


@pytest.mark.dist
def test_distributed_streaming_probes():
    """2-shard psum-merged probe block matches the reference (the probe
    delta rides the same all-reduce as the sketches)."""
    from tests.dist.helpers import run_with_devices
    out = run_with_devices("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro import core
    mesh = Mesh(np.array(jax.devices()), ("shard",))
    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (256, 20))
    B = jax.random.normal(jax.random.fold_in(key, 1), (256, 14))
    ref = core.build_summary(key, A, B, 32, backend="reference", probes=8)
    got = core.distributed_streaming_summary(
        mesh, "shard", key, A, B, 32, slab=96, probes=8)
    np.testing.assert_array_equal(np.asarray(got.probe_omega),
                                  np.asarray(ref.probe_omega))
    scale = float(jnp.abs(ref.probes).max())
    np.testing.assert_allclose(np.asarray(got.probes),
                               np.asarray(ref.probes),
                               rtol=2e-4, atol=1e-5 * scale)
    print("DIST_PROBES_OK")
    """, n_devices=2)
    assert "DIST_PROBES_OK" in out


# ---------------------------------------------------------------------------
# estimate_error: the acceptance matrix + unbiasedness
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method,backend", estimators())
def test_estimate_error_within_2x_every_cell(key, method, backend):
    """On a known-spectrum fixture the a-posteriori Frobenius estimate is
    within 2x of the true residual for EVERY registered method x backend
    cell (the acceptance criterion)."""
    A, B, M = known_spectrum_pair(key, 384, 14, 12, spectrum_values("slow"))
    # the power cell reconstructs from the retained co-sketch block, so its
    # summaries carry one; every other cell runs on the vanilla summary
    cosketch = 8 if method == "power" else 0
    summary = build_summary(key, A, B, 64, probes=32, cosketch=cosketch)
    exact = (A, B) if method == "lela_waltmin" else None
    res = estimate_product(jax.random.fold_in(key, 1), summary, 3, m=1200,
                           T=4, method=method, backend=backend,
                           exact_pair=exact, with_error=True)
    true = float(jnp.linalg.norm(M - res.factors.dense()))
    est = float(res.error.frob_est)
    assert 0.5 * true < est < 2.0 * true, (method, backend, est, true)
    assert float(res.error.frob_lo) <= est <= float(res.error.frob_hi)
    # the spectral proxy lower-bounds the Frobenius estimate by construction
    assert float(res.error.spectral_est) <= est + 1e-5


def test_estimate_error_tracks_truth_across_spectra(key, spectrum_case):
    """Fast/slow/rank-deficient fixtures: estimate within 2x of truth, and
    the rank-deficient case detects a (near-)exact fit at the true rank."""
    kind, A, B, M, s = spectrum_case
    summary = build_summary(key, A, B, 256, probes=32)
    r = 4 if kind != "rank_deficient" else int(np.sum(np.asarray(s) > 0))
    res = estimate_product(jax.random.fold_in(key, 1), summary, r,
                           method="direct_svd", with_error=True)
    true = float(jnp.linalg.norm(M - res.factors.dense()))
    est = float(res.error.frob_est)
    if kind == "rank_deficient":
        # truncation error is exactly zero; what remains is sketch noise
        assert float(res.error.rel_est) < 0.5, est
    else:
        assert 0.5 * true < est < 2.0 * true, (kind, est, true)


@settings(deadline=None, max_examples=5)
@given(seed=st.integers(0, 999))
def test_property_frobenius_estimator_unbiased(seed):
    """Mean of the per-probe squared-residual samples over many independent
    probe keys concentrates around the TRUE squared residual (unbiasedness;
    the probes here are exact (M - M_hat)-independent Gaussians)."""
    key = jax.random.PRNGKey(seed)
    A, B, M = known_spectrum_pair(key, 128, 10, 8, spectrum_values("fast", 8))
    U, sv, Vt = jnp.linalg.svd(np.asarray(M), full_matrices=False)
    factors = core.LowRankFactors(U[:, :3] * sv[:3], Vt[:3].T)
    true_sq = float(jnp.linalg.norm(M - factors.dense()) ** 2)
    ests = []
    for trial in range(16):
        omega = ee.probe_omega(jax.random.fold_in(key, trial), 8, 16)
        probes = ee.probe_pass(omega, A, B, block=64)
        s = core.SketchSummary(jnp.zeros((0, 10)), jnp.zeros((0, 8)),
                               jnp.ones((10,)), jnp.ones((8,)),
                               probes=probes, probe_omega=omega)
        ests.append(float(ee.estimate_error(s, factors).frob_sq_est))
    mean = float(np.mean(ests))
    # 256 probe samples total: the mean must concentrate tightly
    assert 0.7 * true_sq < mean < 1.4 * true_sq, (mean, true_sq)


def test_estimate_error_single_probe_ci_is_honest(key):
    """p=1 carries no spread information: the CI must be [0, inf), never a
    spuriously zero-width interval around one noisy sample."""
    A, B = gaussian_pair(key, d=128, n1=8, n2=6)
    s = build_summary(key, A, B, 16, probes=1)
    factors = core.LowRankFactors(jnp.zeros((8, 2)), jnp.zeros((6, 2)))
    err = ee.estimate_error(s, factors)
    assert float(err.frob_lo) == 0.0
    assert np.isinf(float(err.frob_hi))
    assert np.isfinite(float(err.frob_est))


def test_estimate_error_requires_probes(key):
    A, B = gaussian_pair(key, d=64, n1=6, n2=5)
    s = build_summary(key, A, B, 8)
    factors = core.LowRankFactors(jnp.zeros((6, 2)), jnp.zeros((5, 2)))
    with pytest.raises(ValueError, match="probe"):
        ee.estimate_error(s, factors)
    with pytest.raises(ValueError, match="probe"):
        estimate_product(key, s, 2, m=50, T=2, with_error=True)
    with pytest.raises(ValueError, match="probe"):
        ee.adaptive_rank(s, tol=0.1)


def test_with_error_batched_matches_solo(key):
    """Batched (L, ...) with_error attaches per-pair estimates identical to
    solo dispatches."""
    L = 3
    A = jax.random.normal(key, (L, 128, 10))
    B = jax.random.normal(jax.random.fold_in(key, 1), (L, 128, 8))
    keys = jax.random.split(key, L)
    batched_s = build_summary(keys, A, B, 16, probes=8)
    res = estimate_product(keys, batched_s, 2, m=300, T=2, with_error=True)
    assert res.error.frob_est.shape == (L,)
    for i in range(L):
        solo_s = jax.tree.map(lambda x: x[i], batched_s)
        solo = estimate_product(keys[i], solo_s, 2, m=300, T=2,
                                with_error=True)
        np.testing.assert_allclose(float(res.error.frob_est[i]),
                                   float(solo.error.frob_est), rtol=1e-4)


# ---------------------------------------------------------------------------
# adaptive_rank
# ---------------------------------------------------------------------------

def test_adaptive_rank_smallest_rank_meeting_tol(key):
    """The chosen rank meets tol, the next-smaller rank does not, and the
    choice agrees with the true residual curve on a gapped spectrum."""
    A, B, M = known_spectrum_pair(key, 512, 14, 12,
                                  jnp.array([16.0, 8.0, 4.0, 0.05, 0.02,
                                             0.01, 0.005, 0.002]))
    summary = build_summary(key, A, B, 256, probes=32)
    m_frob = float(jnp.linalg.norm(M))
    res = ee.adaptive_rank(summary, tol=0.25, r_max=8)
    assert res.curve.shape == (8,)
    assert float(res.curve[res.r - 1]) <= 0.25
    if res.r > 1:
        assert float(res.curve[res.r - 2]) > 0.25
    # the estimated decision matches ground truth within the 2x contract
    true_rel = float(jnp.linalg.norm(M - res.factors.dense())) / m_frob
    assert true_rel <= 2 * 0.25
    # on this spectrum the gap sits after rank 3: sqrt(sum tail^2)/||M|| ~
    # 0.003 but rank-2 truncation leaves 4/18.6 ~ 0.21... rank search must
    # land in {2, 3} depending on the sketch-noise floor, never 1 or >3
    assert 2 <= res.r <= 3, res.r


def test_adaptive_rank_unreachable_tol_returns_r_max(key):
    A, B, _ = known_spectrum_pair(key, 256, 12, 10, spectrum_values("slow"))
    summary = build_summary(key, A, B, 64, probes=16)
    res = ee.adaptive_rank(summary, tol=1e-9, r_max=6)
    assert res.r == 6
    assert float(res.error.rel_est) > 1e-9          # gate visibly missed
    with pytest.raises(ValueError, match="r_max"):
        ee.adaptive_rank(summary, tol=0.5, r_max=0)


def test_adaptive_rank_one_factorization(key, monkeypatch):
    """The search reuses ONE factorization: jnp.linalg.svd runs exactly once
    regardless of how many candidate ranks the curve spans."""
    A, B, _ = known_spectrum_pair(key, 256, 12, 10, spectrum_values("fast"))
    summary = build_summary(key, A, B, 64, probes=16)
    calls = {"n": 0}
    real_svd = jnp.linalg.svd

    def counting_svd(*a, **k):
        calls["n"] += 1
        return real_svd(*a, **k)

    monkeypatch.setattr(jnp.linalg, "svd", counting_svd)
    ee._rank_curve.clear_cache()        # drop the jitted trace so svd traces
    res = ee.adaptive_rank(summary, tol=0.3, r_max=10)
    assert calls["n"] == 1, calls
    assert 1 <= res.r <= 10
    ee._rank_curve.clear_cache()        # don't leak the counting closure


# ---------------------------------------------------------------------------
# Quality-gated serving
# ---------------------------------------------------------------------------

def test_quality_gated_flush_escalates_until_pass(key):
    """r='auto' escalates the bucket's rank until every request's estimate
    meets tol; the served error is the gate's estimate."""
    A, B, M = known_spectrum_pair(key, 384, 14, 12,
                                  jnp.array([16.0, 12.0, 8.0, 6.0, 4.0,
                                             3.0, 0.05, 0.02]))
    svc = core_service(k=512, probes=24)
    t0 = svc.submit(key, A, B)
    t1 = svc.submit(jax.random.fold_in(key, 7), A, B)
    out = svc.flush_factors(r="auto", tol=0.2, m=1500, T=4,
                            est_method="direct_svd")
    for t in (t0, t1):
        assert out[t].error is not None
        assert float(out[t].error.rel_est) <= 0.2
        assert 8 <= out[t].factors.r <= 12    # escalated past the start rank
    # a loose tolerance stops at the start rank (rel_est ~0.26 there)
    t2 = svc.submit(key, A, B)
    loose = svc.flush_factors(r="auto", tol=0.3, m=1500, T=4,
                              est_method="direct_svd")
    assert loose[t2].factors.r == 4


def test_quality_gated_stream_matches_flush(key):
    """Gated stream_factors == gated flush_factors for the same key/pair
    (same escalation path, same per-request key derivation)."""
    A, B = gaussian_pair(key, d=128, n1=10, n2=8)
    svc = core_service(k=16, probes=8)
    sid = svc.open_stream(key, 128, 10, 8)
    for off in range(0, 128, 32):
        svc.append(sid, A[off:off + 32], B[off:off + 32])
    sf = svc.stream_factors(sid, r="auto", tol=0.5, m=300, T=2)
    ticket = svc.submit(key, A, B)
    ff = svc.flush_factors(r="auto", tol=0.5, m=300, T=2)[ticket]
    np.testing.assert_array_equal(np.asarray(sf.factors.U),
                                  np.asarray(ff.factors.U))
    np.testing.assert_array_equal(np.asarray(sf.summary.probes),
                                  np.asarray(ff.summary.probes))


def test_quality_gated_guards(key):
    svc = core_service(k=8, probes=0)
    A, B = gaussian_pair(key, d=64, n1=6, n2=5)
    svc.submit(key, A, B)
    with pytest.raises(ValueError, match="probe"):
        svc.flush_factors(r="auto", tol=0.5)
    with pytest.raises(ValueError, match="tol"):
        core_service(k=8, probes=4).flush_factors(r="auto")
    with pytest.raises(ValueError, match="int or 'auto'"):
        core_service(k=8, probes=4).flush_factors(r=2.5)
    svc_p = core_service(k=8, probes=4)
    state = core.StreamingSummarizer(8).init(key, (64, 4, 3))
    with pytest.raises(ValueError, match="probe"):
        svc_p.open_stream(key, 64, 4, 3, state=state)


def core_service(k, probes):
    from repro.serve.engine import SketchService
    return SketchService(k=k, backend="scan", block=32, probes=probes)


def test_rank_curve_mixed_dtype_forced_to_f32(key):
    """Regression: a reduced-precision summary (bf16 sketches/probes) must
    not leak its dtype into the gate — the curve is float32, and on an
    all-float32 summary the internal casts are bitwise no-ops."""
    A, B = gaussian_pair(key)
    summary = core.build_summary(key, A, B, 16, probes=4)
    f32_curve = core.rank_curve(summary, 5)
    assert f32_curve.dtype == jnp.float32
    # bit-parity: casting an f32 summary through the forced-f32 path is
    # the identity
    recast = jax.tree.map(
        lambda x: x.astype(jnp.float32) if x.dtype == jnp.float32 else x,
        summary)
    np.testing.assert_array_equal(np.asarray(core.rank_curve(recast, 5)),
                                  np.asarray(f32_curve))
    # a bf16 summary yields a finite float32 curve close to the f32 one
    bf16 = jax.tree.map(lambda x: x.astype(jnp.bfloat16), summary)
    curve = core.rank_curve(bf16, 5)
    assert curve.dtype == jnp.float32
    got = np.asarray(curve)
    assert np.all(np.isfinite(got))
    np.testing.assert_allclose(got, np.asarray(f32_curve), rtol=0.1,
                               atol=0.05)
