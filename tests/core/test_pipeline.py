"""PipelineEngine: plan hashing/validation, executable-cache behavior
(hits, zero-retrace warm paths, LRU eviction), plan-path parity with the
stage-by-stage composition, and the single-sweep quality gate's dispatch
accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.core import estimation_engine, pipeline, summary_engine
from repro.core.pipeline import (
    EstimationSpec, PipelineEngine, PipelinePlan, RankPolicy, SketchSpec)
from repro.serve.engine import SketchService

from tests.conftest import gaussian_pair, known_spectrum_pair


def _service(k=8, probes=0, engine=None):
    return SketchService(k=k, backend="scan", block=32, probes=probes,
                         engine=engine)


def _submit_bucketed(svc, key, shapes):
    """One request per (d, n) shape; same-shape entries share a bucket."""
    tickets = []
    for i, (d, n) in enumerate(shapes):
        kk = jax.random.fold_in(key, i)
        A = jax.random.normal(kk, (d, n))
        B = jax.random.normal(jax.random.fold_in(kk, 99), (d, n))
        tickets.append(svc.submit(kk, A, B))
    return tickets


# ---------------------------------------------------------------------------
# Plans
# ---------------------------------------------------------------------------

def test_plans_are_hashable_and_value_keyed():
    p1 = pipeline.smppca_plan(r=2, k=16, m=200, T=2)
    p2 = pipeline.smppca_plan(r=2, k=16, m=200, T=2)
    p3 = pipeline.smppca_plan(r=2, k=16, m=200, T=3)
    assert hash(p1) == hash(p2) and p1 == p2
    assert p1 != p3
    assert len({p1, p2, p3}) == 2


def test_plan_validation_errors(key):
    eng = PipelineEngine()
    A, B = gaussian_pair(key, d=32, n1=4, n2=3)
    bad = [
        (PipelinePlan(key_layout="nope", rank=RankPolicy(r=2)), "layout"),
        (PipelinePlan(sketch=SketchSpec(method="nope"),
                      rank=RankPolicy(r=2)), "sketch method"),
        (PipelinePlan(sketch=SketchSpec(backend="nope"),
                      rank=RankPolicy(r=2)), "summary backend"),
        (PipelinePlan(sketch=SketchSpec(backend="distributed"),
                      rank=RankPolicy(r=2)), "distributed"),
        (PipelinePlan(estimation=EstimationSpec(method="nope"),
                      rank=RankPolicy(r=2)), "estimation method"),
        (PipelinePlan(estimation=EstimationSpec(backend="nope"),
                      rank=RankPolicy(r=2)), "estimation backend"),
        (PipelinePlan(rank=RankPolicy(r=None, tol=None)), "tol"),
        (PipelinePlan(rank=RankPolicy(r=None, tol=0.5)), "probe"),
        (PipelinePlan(rank=RankPolicy(r=2.5)), "int"),
        (PipelinePlan(rank=RankPolicy(r=2), with_error=True), "probes"),
    ]
    for plan, match in bad:
        with pytest.raises(ValueError, match=match):
            eng.run(plan, key, A, B)
    with pytest.raises(TypeError, match="PipelinePlan"):
        eng.run("not a plan", key, A, B)
    with pytest.raises(ValueError, match="max_entries"):
        PipelineEngine(max_entries=0)


# ---------------------------------------------------------------------------
# Plan-path parity with the stage-by-stage composition
# ---------------------------------------------------------------------------

def test_run_matches_stagewise_composition_bitwise(key):
    """engine.run(smppca preset) == build_summary + estimate_product with
    smppca's historical key fan-out, bit-for-bit."""
    A, B = gaussian_pair(key, d=96, n1=10, n2=8)
    eng = PipelineEngine()
    res = eng.run(pipeline.smppca_plan(r=2, k=16, m=200, T=2), key, A, B)
    k_sketch, k_sample, _ = jax.random.split(key, 3)
    summary = summary_engine.build_summary(k_sketch, A, B, 16)
    manual = estimation_engine.estimate_product(
        jax.random.fold_in(k_sample, 0), summary, 2, m=200, T=2)
    np.testing.assert_array_equal(np.asarray(res.estimate.factors.U),
                                  np.asarray(manual.factors.U))
    np.testing.assert_array_equal(np.asarray(res.estimate.factors.V),
                                  np.asarray(manual.factors.V))
    np.testing.assert_array_equal(np.asarray(res.summary.A_sketch),
                                  np.asarray(summary.A_sketch))


def test_run_from_summary_matches_estimate_product_bitwise(key):
    """The compiled from-summary path (stream_factors' spine) derives the
    service fold_in(key, 1) estimation key and matches estimate_product."""
    A, B = gaussian_pair(key, d=96, n1=10, n2=8)
    summary = summary_engine.build_summary(key, A, B, 16)
    eng = PipelineEngine()
    plan = PipelinePlan(sketch=SketchSpec(k=16, backend="scan"),
                        estimation=EstimationSpec(m=200, T=2),
                        rank=RankPolicy(r=2), key_layout="service")
    est = eng.run_from_summary(plan, key, summary)
    manual = estimation_engine.estimate_product(
        jax.random.fold_in(key, 1), summary, 2, m=200, T=2)
    np.testing.assert_array_equal(np.asarray(est.factors.U),
                                  np.asarray(manual.factors.U))


def test_summarize_matches_build_summary_bitwise(key):
    A, B = gaussian_pair(key, d=64, n1=6, n2=5)
    eng = PipelineEngine()
    spec = SketchSpec(method="srht", backend="scan", k=8, block=32)
    got = eng.summarize(spec, key, A, B)
    want = summary_engine.build_summary(key, A, B, 8, method="srht",
                                        backend="scan", block=32)
    for name in ("A_sketch", "B_sketch", "norm_A", "norm_B"):
        np.testing.assert_array_equal(np.asarray(getattr(got, name)),
                                      np.asarray(getattr(want, name)))


# ---------------------------------------------------------------------------
# Executable cache: warm hits, zero retraces, one fused dispatch per bucket
# ---------------------------------------------------------------------------

def test_warm_flush_factors_one_fused_dispatch_zero_retraces(key):
    """The acceptance gate: a repeated-shape warm flush_factors performs
    exactly ONE fused dispatch per shape bucket with ZERO new traces."""
    eng = PipelineEngine()
    svc = _service(engine=eng)
    shapes = [(64, 6), (96, 5), (64, 6)]          # two buckets, one repeated
    t_cold = _submit_bucketed(svc, key, shapes)
    cold = svc.flush_factors(r=2, m=100, T=2)
    traces0 = eng.stats.traces
    assert traces0 == 2                           # one trace per shape bucket
    assert eng.stats.est_dispatches == 2          # ... and one fused dispatch
    assert eng.stats.curve_dispatches == 0        # fully fused: no extra stage

    t_warm = _submit_bucketed(svc, key, shapes)   # same keys, same shapes
    warm = svc.flush_factors(r=2, m=100, T=2)
    assert eng.stats.traces == traces0            # ZERO new traces
    assert eng.stats.est_dispatches == 4          # one fused dispatch/bucket
    assert eng.stats.hits == 2
    for tc, tw in zip(t_cold, t_warm):            # warm == cold, bit-for-bit
        np.testing.assert_array_equal(np.asarray(cold[tc].factors.U),
                                      np.asarray(warm[tw].factors.U))


def test_distinct_plans_never_share_entries(key):
    """Plans differing in any field get their own executables (and differing
    shapes get their own signatures under one plan)."""
    eng = PipelineEngine()
    svc = _service(engine=eng)
    _submit_bucketed(svc, key, [(64, 6)])
    svc.flush_factors(r=2, m=100, T=2)
    _submit_bucketed(svc, key, [(64, 6)])
    svc.flush_factors(r=3, m=100, T=2)            # different rank -> new entry
    _submit_bucketed(svc, key, [(64, 6)])
    svc.flush_factors(r=2, m=100, T=3)            # different T -> new entry
    assert eng.stats.misses == 3 and eng.stats.hits == 0
    assert len(eng) == 3
    _submit_bucketed(svc, key, [(48, 6)])         # same plan, new shape
    svc.flush_factors(r=2, m=100, T=2)
    assert eng.stats.misses == 4 and len(eng) == 4


def test_cache_eviction_at_lru_bound(key):
    """Past max_entries the least-recently-used executable is dropped and
    re-traced on next use."""
    eng = PipelineEngine(max_entries=2)
    svc = _service(engine=eng)

    def flush_shape(d):
        _submit_bucketed(svc, key, [(d, 6)])
        svc.flush_factors(r=2, m=100, T=2)

    flush_shape(32)
    flush_shape(48)
    assert eng.stats.evictions == 0 and len(eng) == 2
    flush_shape(64)                               # evicts the (32, 6) entry
    assert eng.stats.evictions == 1 and len(eng) == 2
    traces0 = eng.stats.traces
    flush_shape(48)                               # still cached: no retrace
    assert eng.stats.traces == traces0 and eng.stats.hits == 1
    flush_shape(32)                               # evicted: must retrace
    assert eng.stats.traces == traces0 + 1
    assert eng.stats.evictions == 2


def test_engine_clear_drops_executables(key):
    eng = PipelineEngine()
    svc = _service(engine=eng)
    _submit_bucketed(svc, key, [(64, 6)])
    svc.flush_factors(r=2, m=100, T=2)
    assert len(eng) == 1
    eng.clear()
    assert len(eng) == 0
    _submit_bucketed(svc, key, [(64, 6)])
    svc.flush_factors(r=2, m=100, T=2)
    assert eng.stats.traces == 2                  # cleared -> re-traced


# ---------------------------------------------------------------------------
# Quality-gated path: single-sweep gate, one estimation dispatch per bucket
# ---------------------------------------------------------------------------

def test_gated_flush_single_estimation_dispatch(key):
    """Regression for the per-round escalation: a gated flush is one curve
    dispatch + ONE estimation dispatch per bucket, however many ranks the
    doubling schedule probes — and a warm gated flush never retraces."""
    A, B, _ = known_spectrum_pair(
        key, 384, 14, 12, jnp.array([16.0, 12.0, 8.0, 6.0, 4.0, 3.0,
                                     0.05, 0.02]))
    eng = PipelineEngine()
    svc = _service(k=512, probes=24, engine=eng)
    svc.submit(key, A, B)
    svc.submit(jax.random.fold_in(key, 7), A, B)
    out = svc.flush_factors(r="auto", tol=0.2, m=1500, T=4,
                            est_method="direct_svd")
    assert eng.stats.curve_dispatches == 1        # ONE rank-curve sweep
    assert eng.stats.est_dispatches == 1          # ONE estimation dispatch
    assert all(v.factors.r >= 8 for v in out.values())   # it did escalate
    traces0 = eng.stats.traces
    svc.submit(key, A, B)
    svc.submit(jax.random.fold_in(key, 7), A, B)
    svc.flush_factors(r="auto", tol=0.2, m=1500, T=4, est_method="direct_svd")
    assert eng.stats.traces == traces0            # warm gate: zero retraces
    assert (eng.stats.curve_dispatches, eng.stats.est_dispatches) == (2, 2)


def test_gated_served_estimate_is_authoritative(key):
    """The curve only fast-forwards the schedule; the SERVED factors'
    a-posteriori estimate has the final word. With a starved completion
    (tiny m, T=1) the SVD-truncation curve passes rank 4 but the WAltMin
    factors miss tol there — the gate must keep escalating."""
    A, B, _ = known_spectrum_pair(
        key, 384, 14, 12, jnp.array([16.0, 12.0, 8.0, 6.0, 4.0, 3.0,
                                     0.05, 0.02]))
    eng = PipelineEngine()
    svc = _service(k=512, probes=24, engine=eng)
    t = svc.submit(key, A, B)
    out = svc.flush_factors(r="auto", tol=0.3, r_max=8, m=300, T=1)[t]
    # curve (rank-4 value ~0.25) picked 4; the served estimate (~0.37) failed
    # the gate, so the schedule doubled to the cap
    assert out.factors.r == 8
    assert eng.stats.curve_dispatches == 1
    assert eng.stats.est_dispatches == 2          # one escalation round
    assert float(out.error.rel_est) > 0.3         # honest at the cap


def test_gated_curve_executable_shared_across_tolerances(key):
    """tol is consumed host-side: gated flushes differing only in tol share
    one compiled curve sweep (only a new rank's estimation executable may
    trace)."""
    A, B, _ = known_spectrum_pair(
        key, 384, 14, 12, jnp.array([16.0, 12.0, 8.0, 6.0, 4.0, 3.0,
                                     0.05, 0.02]))
    eng = PipelineEngine()
    svc = _service(k=512, probes=24, engine=eng)
    svc.submit(key, A, B)
    svc.flush_factors(r="auto", tol=0.2, m=1500, T=4, est_method="direct_svd")
    assert eng.stats.traces == 2                  # one curve + one est trace
    svc.submit(key, A, B)
    svc.flush_factors(r="auto", tol=0.3, m=1500, T=4, est_method="direct_svd")
    assert eng.stats.traces == 3                  # curve shared; new rank only
    assert eng.stats.curve_dispatches == 2 and eng.stats.misses == 3
    svc.submit(key, A, B)
    svc.flush_factors(r="auto", tol=0.3, m=1500, T=4, est_method="direct_svd")
    assert eng.stats.traces == 3                  # fully warm


def test_gated_rank_curve_matches_adaptive_rank_sweep(key):
    """The gate's curve is the adaptive_rank sweep: same single-SVD relative
    error curve, read through the public rank_curve stage."""
    A, B, _ = known_spectrum_pair(key, 256, 12, 10, jnp.array(
        [8.0, 4.0, 2.0, 1.0, 0.5, 0.1, 0.05, 0.02, 0.01, 0.005]))
    summary = core.build_summary(key, A, B, 64, probes=16)
    curve = core.rank_curve(summary, 8)
    res = core.adaptive_rank(summary, tol=0.3, r_max=8)
    np.testing.assert_array_equal(np.asarray(curve), np.asarray(res.curve))


def test_rank_curve_requires_probes(key):
    A, B = gaussian_pair(key, d=64, n1=6, n2=5)
    with pytest.raises(ValueError, match="probe"):
        core.rank_curve(core.build_summary(key, A, B, 8), 4)
