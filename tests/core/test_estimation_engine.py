"""EstimationEngine tests: method x backend parity matrix, batched (vmapped)
mode, the two-engine end-to-end pipeline per sketch backend, and the serving
front-end's sketch->estimate path.

The engine's contract: ``key`` is split identically across backends (sample
key, ALS key), so for a fixed key every backend sees the same Omega and the
same initialization — outputs agree to float reassociation (the reference
backend runs the same ops eagerly; pallas swaps only the rescaled-JL value
extraction for the gather kernel).
"""
import jax
import numpy as np
import pytest

from repro import core
from repro.core import estimation_engine as ee
from tests.conftest import planted_pair


def _summary(key, d=512, n=40, k=64, corr=0.3):
    A, B = planted_pair(key, d, n, corr=corr)
    return A, B, core.build_summary(key, A, B, k)


def _dense(factors):
    return np.asarray(factors.U @ factors.V.T)


# ---------------------------------------------------------------------------
# Parity matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["rescaled_jl", "lela_waltmin",
                                    "direct_svd"])
@pytest.mark.parametrize("backend", ["jit", "pallas"])
def test_backend_parity_vs_reference(key, method, backend):
    """Every (method, backend) cell agrees with its reference cell."""
    A, B, s = _summary(key)
    kw = dict(m=1500, T=3,
              exact_pair=(A, B) if method == "lela_waltmin" else None)
    ref = core.estimate_product(key, s, 3, method=method,
                                backend="reference", **kw)
    got = core.estimate_product(key, s, 3, method=method, backend=backend,
                                **kw)
    scale = max(np.abs(_dense(ref.factors)).max(), 1.0)
    # direct_svd reference is a dense SVD vs the jit path's subspace
    # iteration: same subspace, slightly looser numerical agreement
    tol = 5e-3 if method == "direct_svd" else 1e-3
    np.testing.assert_allclose(_dense(got.factors), _dense(ref.factors),
                               atol=tol * scale, rtol=0)
    if method != "direct_svd":
        # same key -> bit-identical Omega on every backend
        np.testing.assert_array_equal(np.asarray(got.samples.rows),
                                      np.asarray(ref.samples.rows))
        np.testing.assert_allclose(np.asarray(got.values),
                                   np.asarray(ref.values), rtol=1e-4,
                                   atol=1e-5)


def test_pallas_values_match_reference_extraction(key):
    """The sampled_dot gather kernel == the pure-XLA rescaled-JL extraction
    (the one stage the pallas backend swaps)."""
    _, _, s = _summary(key)
    rows = jax.random.randint(key, (300,), 0, s.n1)
    cols = jax.random.randint(jax.random.fold_in(key, 1), (300,), 0, s.n2)
    want = core.rescaled_entries(s, rows, cols)
    got = ee._pallas_values(s, rows, cols)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                               atol=1e-5)


def test_unknown_method_backend_and_missing_exact_pair_raise(key):
    _, _, s = _summary(key, d=128, n=8, k=8)
    with pytest.raises(ValueError, match="method"):
        core.estimate_product(key, s, 2, method="nope")
    with pytest.raises(ValueError, match="backend"):
        core.estimate_product(key, s, 2, backend="nope")
    with pytest.raises(ValueError, match="exact_pair"):
        core.estimate_product(key, s, 2, method="lela_waltmin", m=64)
    cells = set(ee.estimators())
    assert {(m, b) for m in ee.METHODS for b in ee.BACKENDS} <= cells


def test_default_m_is_paper_budget():
    assert ee.default_m(100, 80, 5) == int(10 * 100 * 5 * np.log(100))


# ---------------------------------------------------------------------------
# Batched (vmapped) mode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["reference", "jit", "pallas"])
def test_batched_matches_looped(key, backend):
    """One dispatch over a stacked (L, ...) summary == L single dispatches."""
    L = 3
    A = jax.random.normal(key, (L, 256, 20))
    B = jax.random.normal(jax.random.fold_in(key, 1), (L, 256, 20))
    s = core.build_summary(key, A, B, 32)
    batched = core.estimate_product(key, s, 2, backend=backend, m=800, T=2)
    assert batched.factors.U.shape == (L, 20, 2)
    keys = jax.random.split(key, L)
    for i in range(L):
        solo = core.estimate_product(
            keys[i], jax.tree.map(lambda x: x[i], s), 2, backend=backend,
            m=800, T=2)
        np.testing.assert_allclose(
            _dense(jax.tree.map(lambda x: x[i], batched.factors)),
            _dense(solo.factors), rtol=1e-4, atol=1e-5)


def test_batched_direct_svd_and_key_stack(key):
    """direct_svd batches too (samples/values stay None), and an explicit
    key stack is used verbatim."""
    L = 2
    A = jax.random.normal(key, (L, 128, 12))
    B = jax.random.normal(jax.random.fold_in(key, 1), (L, 128, 12))
    s = core.build_summary(key, A, B, 16)
    keys = jax.random.split(jax.random.fold_in(key, 7), L)
    batched = core.estimate_product(keys, s, 2, method="direct_svd")
    assert batched.samples is None and batched.values is None
    solo = core.estimate_product(
        keys[1], jax.tree.map(lambda x: x[1], s), 2, method="direct_svd")
    np.testing.assert_allclose(
        _dense(jax.tree.map(lambda x: x[1], batched.factors)),
        _dense(solo.factors), rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("backend", ["reference", "jit"])
def test_batched_lela_stacks_exact_pair(key, backend):
    """Batched lela_waltmin slices the stacked exact pair per item on every
    backend (the reference loop must slice by hand; the jit path vmaps)."""
    L = 2
    A = jax.random.normal(key, (L, 128, 10))
    B = jax.random.normal(jax.random.fold_in(key, 1), (L, 128, 10))
    s = core.build_summary(key, A, B, 16)
    batched = core.estimate_product(key, s, 2, method="lela_waltmin",
                                    backend=backend, m=400, T=2,
                                    exact_pair=(A, B))
    keys = jax.random.split(key, L)
    for i in range(L):
        solo = core.estimate_product(
            keys[i], jax.tree.map(lambda x: x[i], s), 2,
            method="lela_waltmin", backend=backend, m=400, T=2,
            exact_pair=(A[i], B[i]))
        np.testing.assert_allclose(np.asarray(batched.values[i]),
                                   np.asarray(solo.values), rtol=1e-5,
                                   atol=1e-6)
        np.testing.assert_allclose(
            _dense(jax.tree.map(lambda x: x[i], batched.factors)),
            _dense(solo.factors), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Two-engine end-to-end (summary engine -> estimation engine)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sketch_backend", ["reference", "scan", "rows",
                                            "pallas"])
def test_end_to_end_per_sketch_backend(key, sketch_backend):
    """Any build_summary output flows straight into estimate_product, and the
    result quality is sketch-backend independent (the engines' joint
    contract)."""
    d, n, r = 1024, 50, 3
    A, B = planted_pair(key, d, n, corr=0.4)
    s = core.build_summary(key, A, B, 128, backend=sketch_backend, block=256)
    est = core.estimate_product(key, s, r, m=6000, T=4)
    err = float(core.spectral_error(A, B, est.factors))
    assert err < 0.8, (sketch_backend, err)


def test_smppca_is_the_two_engine_composition(key):
    """smppca == build_summary + estimate_product with its key derivation."""
    d, n, r, k, m = 512, 40, 3, 64, 1500
    A, B = planted_pair(key, d, n, corr=0.3)
    res = core.smppca(key, A, B, r=r, k=k, m=m, T=3)
    k_sketch, k_sample, _ = jax.random.split(key, 3)
    s = core.build_summary(k_sketch, A, B, k)
    est = core.estimate_product(jax.random.fold_in(k_sample, 0), s, r,
                                m=m, T=3)
    np.testing.assert_allclose(_dense(res.factors), _dense(est.factors),
                               rtol=1e-5, atol=1e-6)


def test_lela_is_the_norms_only_composition(key):
    """lela == norms_only_summary + estimate_product(lela_waltmin)."""
    d, n, r, m = 512, 40, 3, 1500
    A, B = planted_pair(key, d, n)
    f = core.lela(key, A, B, r=r, m=m, T=3)
    s = core.norms_only_summary(A, B)
    est = core.estimate_product(key, s, r, method="lela_waltmin", m=m, T=3,
                                exact_pair=(A, B))
    np.testing.assert_allclose(_dense(f), _dense(est.factors), rtol=1e-5,
                               atol=1e-6)


def test_sketch_svd_uses_direct_svd_method(key):
    d, n, r, k = 512, 40, 3, 64
    A, B = planted_pair(key, d, n, corr=0.3)
    f = core.sketch_svd(key, A, B, r=r, k=k)
    k_sketch, k_pow = jax.random.split(key)
    s = core.build_summary(k_sketch, A, B, k)
    est = core.estimate_product(k_pow, s, r, method="direct_svd")
    np.testing.assert_allclose(_dense(f), _dense(est.factors), rtol=1e-4,
                               atol=1e-5)


def test_rescaled_jl_beats_direct_svd_on_narrow_cone(key):
    """The paper's headline claim holds through the engine API."""
    d, n, r = 2000, 150, 5
    A, B = planted_pair(key, d, n, corr=0.2)
    s = core.build_summary(key, A, B, 128)
    est_jl = core.estimate_product(key, s, r, method="rescaled_jl",
                                   m=int(10 * n * r * np.log(n)), T=8)
    est_svd = core.estimate_product(key, s, r, method="direct_svd")
    e_jl = float(core.spectral_error(A, B, est_jl.factors))
    e_svd = float(core.spectral_error(A, B, est_svd.factors))
    assert e_jl < e_svd, (e_jl, e_svd)


# ---------------------------------------------------------------------------
# Serving pipeline
# ---------------------------------------------------------------------------

def test_sketch_service_flush_factors_matches_solo_pipeline(key):
    """flush_factors == solo build_summary + estimate_product per request,
    with the documented fold_in(key, 1) estimation-key derivation, across
    mixed shape buckets."""
    from repro.serve.engine import SketchService
    svc = SketchService(k=32, backend="scan", block=64)
    reqs = []
    for i, (d, n) in enumerate([(128, 10), (256, 8), (128, 10)]):
        kk = jax.random.fold_in(key, i)
        A = jax.random.normal(kk, (d, n))
        B = A + 0.3 * jax.random.normal(jax.random.fold_in(kk, 99), (d, n))
        reqs.append((svc.submit(kk, A, B), kk, A, B))
    out = svc.flush_factors(r=2, m=600, T=2)
    assert svc.pending == 0
    for ticket, kk, A, B in reqs:
        s = core.build_summary(kk, A, B, 32, backend="scan", block=64)
        est = core.estimate_product(jax.random.fold_in(kk, 1), s, 2,
                                    m=600, T=2)
        np.testing.assert_allclose(_dense(out[ticket].factors),
                                   _dense(est.factors), rtol=1e-5, atol=1e-6)
        for name in ("A_sketch", "B_sketch", "norm_A", "norm_B"):
            np.testing.assert_allclose(
                np.asarray(getattr(out[ticket].summary, name)),
                np.asarray(getattr(s, name)), rtol=1e-5, atol=1e-6)
