"""Step-2 tests: rescaled JL estimator (Eq 2) and biased sampling (Eq 1)."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # hypothesis is optional; see tests/_hyp.py
    from tests._hyp import given, settings, strategies as st

from repro import core
from tests.conftest import planted_pair


# ---------------------------------------------------------------------------
# Rescaled JL estimator
# ---------------------------------------------------------------------------

def test_fig2a_rescaled_beats_plain_jl(key):
    """Paper Fig 2(a): on unit-norm vector pairs with varying angles, the
    rescaled estimator has lower MSE than the plain JL dot product
    (paper: 0.053 vs 0.129 at d=1000, k=10)."""
    d, k, npairs = 1000, 10, 400
    kx, kt, ks = jax.random.split(key, 3)
    x = jax.random.normal(kx, (d, npairs))
    x = x / jnp.linalg.norm(x, axis=0)
    # y = x + t with E||t|| ~ 0.6 (paper Fig 2a construction: moderate angles,
    # where Eq 2's (1 - cos^2)^2/k beats plain JL's (1 + cos^2)/k decisively;
    # without the 1/sqrt(d) the angles are ~90 deg and the gap is seed noise)
    t = jax.random.normal(kt, (d, npairs)) * 0.6 / jnp.sqrt(d)
    y = x + t
    y = y / jnp.linalg.norm(y, axis=0)
    true = jnp.sum(x * y, axis=0)
    s = core.sketch_summary(ks, x, y, k=k)
    idx = jnp.arange(npairs)
    est_resc = core.rescaled_entries(s, idx, idx)
    est_plain = core.plain_jl_entries(s, idx, idx)
    mse_resc = float(jnp.mean((est_resc - true) ** 2))
    mse_plain = float(jnp.mean((est_plain - true) ** 2))
    assert mse_resc < mse_plain, (mse_resc, mse_plain)


def test_rescaled_exact_when_colinear(key):
    """Extreme case of Fig 2(a): cos theta = 1 -> rescaled JL is *exact*."""
    d, n, k = 300, 8, 4
    kx, ks = jax.random.split(key)
    x = jax.random.normal(kx, (d, n))
    scales = jnp.arange(1.0, n + 1.0)
    A = x
    B = x * scales[None, :]          # B_j parallel to A_j
    s = core.sketch_summary(ks, A, B, k=k)
    idx = jnp.arange(n)
    est = core.rescaled_entries(s, idx, idx)
    true = jnp.sum(A * B, axis=0)
    np.testing.assert_allclose(np.asarray(est), np.asarray(true), rtol=1e-3)


def test_rescaled_matrix_matches_entries(key):
    A, B = planted_pair(key, 200, 15, corr=0.3)
    s = core.sketch_summary(key, A, B, k=64)
    M = core.rescaled_matrix(s)
    ii, jj = jnp.meshgrid(jnp.arange(15), jnp.arange(15), indexing="ij")
    entries = core.rescaled_entries(s, ii.reshape(-1), jj.reshape(-1))
    np.testing.assert_allclose(np.asarray(M).reshape(-1), np.asarray(entries),
                               rtol=1e-4, atol=1e-5)


def test_lemma_b6_entrywise_bound(key):
    """Lemma B.6: |M~_ij - A_i^T B_j| <= eps ||A_i|| ||B_j|| whp, eps ~
    sqrt(log n / k). Checked at 3x the nominal eps."""
    d, n, k = 2000, 40, 512
    A, B = planted_pair(key, d, n, corr=0.5)
    s = core.sketch_summary(key, A, B, k=k)
    M = np.asarray(core.rescaled_matrix(s))
    exact = np.asarray(A.T @ B)
    scale = np.asarray(s.norm_A)[:, None] * np.asarray(s.norm_B)[None, :]
    eps = 3.0 * np.sqrt(np.log(2 * n) / k)
    assert np.all(np.abs(M - exact) <= eps * scale)


# ---------------------------------------------------------------------------
# Eq-(1) sampling
# ---------------------------------------------------------------------------

def test_q_probabilities_expected_count(key):
    norm_A = jnp.abs(jax.random.normal(key, (50,))) + 0.1
    norm_B = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (70,))) + 0.1
    m = 500
    q = core.q_probabilities(norm_A, norm_B, m)
    # sum q_ij == m when no entry saturates (Eq 1 normalization)
    qr = m * (norm_A[:, None] ** 2 / (2 * 70 * jnp.sum(norm_A ** 2))
              + norm_B[None, :] ** 2 / (2 * 50 * jnp.sum(norm_B ** 2)))
    assert abs(float(jnp.sum(qr)) - m) < 1e-3 * m
    assert float(jnp.max(q)) <= 1.0


def test_sampler_marginals_match_eq1(key):
    """Empirical row-marginals of the factored sampler match the Eq-(1)
    mixture: P(row=i) = 1/2 ||A_i||^2/||A||_F^2 + 1/(2 n1)."""
    n1, n2, m = 30, 20, 200_000
    norm_A = jnp.linspace(0.2, 3.0, n1)
    norm_B = jnp.linspace(1.0, 2.0, n2)
    ss = core.sample_entries(key, norm_A, norm_B, m)
    counts = np.bincount(np.asarray(ss.rows), minlength=n1) / m
    expect = 0.5 * np.asarray(norm_A ** 2 / jnp.sum(norm_A ** 2)) + 0.5 / n1
    np.testing.assert_allclose(counts, expect, atol=0.01)


def test_sampler_qhat_evaluation(key):
    norm_A = jnp.ones((10,))
    norm_B = jnp.ones((10,))
    ss = core.sample_entries(key, norm_A, norm_B, 50)
    # uniform norms: q_ij = m (1/(2*100) + 1/(2*100)) = m/100
    np.testing.assert_allclose(np.asarray(ss.q_hat), 0.5, rtol=1e-5)


def test_binomial_sampler_agrees_with_q(key):
    n = 40
    norm_A = jnp.linspace(0.5, 2.0, n)
    norm_B = jnp.linspace(0.5, 2.0, n)
    m = 300
    ss = core.sample_entries_binomial(key, norm_A, norm_B, m)
    n_sampled = int(np.asarray(ss.mask).sum())
    assert 0.5 * m < n_sampled < 2.0 * m


@settings(deadline=None, max_examples=10)
@given(n1=st.integers(3, 30), n2=st.integers(3, 30),
       m=st.integers(10, 400), seed=st.integers(0, 2**31 - 1))
def test_property_sampler_static_shapes_and_ranges(n1, n2, m, seed):
    kk = jax.random.PRNGKey(seed)
    norm_A = jnp.abs(jax.random.normal(kk, (n1,))) + 0.01
    norm_B = jnp.abs(jax.random.normal(jax.random.fold_in(kk, 1), (n2,))) + 0.01
    ss = core.sample_entries(kk, norm_A, norm_B, m)
    assert ss.rows.shape == (m,) and ss.cols.shape == (m,)
    assert int(ss.rows.min()) >= 0 and int(ss.rows.max()) < n1
    assert int(ss.cols.min()) >= 0 and int(ss.cols.max()) < n2
    q = np.asarray(ss.q_hat)
    assert np.all(q > 0) and np.all(q <= 1.0)


# ---------------------------------------------------------------------------
# Zero-norm / degenerate-CDF hardening
# ---------------------------------------------------------------------------

def test_zero_matrix_raises_named_valueerror(key):
    """An all-zero factor makes Eq. (1) a 0/0: both samplers refuse eagerly
    with a ValueError naming WHICH factor is degenerate."""
    import pytest
    zeros = jnp.zeros((8,))
    ones = jnp.ones((8,))
    with pytest.raises(ValueError, match="columns of A"):
        core.sample_entries(key, zeros, ones, 20)
    with pytest.raises(ValueError, match="columns of B"):
        core.sample_entries(key, ones, zeros, 20)
    with pytest.raises(ValueError, match="columns of A"):
        core.sample_entries_binomial(key, zeros, ones, 20)
    with pytest.raises(ValueError, match="columns of B"):
        core.sample_entries_binomial(key, ones, jnp.full((8,), jnp.nan), 20)


def test_zero_matrix_raises_through_estimate_product(key):
    """The guard fires end-to-end: estimate_product on a summary of an
    all-zero A raises the named ValueError instead of returning NaN
    factors — for both sampling-based methods."""
    import pytest
    from repro.core import estimation_engine
    from repro.core.summary_engine import build_summary, norms_only_summary
    A = jnp.zeros((64, 6))
    B = jax.random.normal(key, (64, 5))
    summary = build_summary(key, A, B, 8)
    with pytest.raises(ValueError, match="columns of A"):
        estimation_engine.estimate_product(key, summary, 2, m=50, T=2)
    with pytest.raises(ValueError, match="columns of A"):
        estimation_engine.estimate_product(
            key, norms_only_summary(A, B), 2, method="lela_waltmin",
            m=50, T=2, exact_pair=(A, B))


def test_zero_columns_fall_through_uniform_branch(key):
    """Zero-norm *columns* (rows of A^T B) are fine: the Eq. (1) mixture's
    uniform term keeps every q_ij > 0, the sampler stays in range, and
    estimate_product completes with finite factors end-to-end."""
    from repro.core import estimation_engine
    from repro.core.summary_engine import build_summary
    A = jax.random.normal(key, (64, 6)).at[:, :2].set(0.0)
    B = jax.random.normal(jax.random.fold_in(key, 1), (64, 5))
    norm_A = jnp.linalg.norm(A, axis=0)
    ss = core.sample_entries(key, norm_A, jnp.linalg.norm(B, axis=0), 60)
    q = np.asarray(ss.q_hat)
    assert np.all(q > 0) and np.all(np.isfinite(q))
    summary = build_summary(key, A, B, 16)
    est = estimation_engine.estimate_product(key, summary, 2, m=60, T=2)
    assert np.all(np.isfinite(np.asarray(est.factors.U)))
    assert np.all(np.isfinite(np.asarray(est.factors.V)))
