"""Streaming mergeable-summary tests.

The contract (docs/streaming.md): chunked ingestion, any merge order, and
the one-shot ``build_summary`` all produce the same summary — sequential
same-chunk ingestion bit-identical to the scan backend, merge commutative
bit-for-bit, arbitrary reassociation to float tolerance; checkpoint
round-trips and serving sessions are bit-exact.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    from tests._hyp import given, settings
    from tests._hyp import strategies as st

from repro import core
from repro.core import streaming
from repro.core.summary_engine import build_summary
from tests.conftest import gaussian_pair as _pair


def _ingest(summ, key, A, B, chunk):
    state = summ.init(key, (A.shape[0], A.shape[1], B.shape[1]))
    for off in range(0, A.shape[0], chunk):
        state = summ.update(state, A[off:off + chunk], B[off:off + chunk],
                            off)
    return state


def _assert_bit_equal(got, want, msg=""):
    for name in ("A_sketch", "B_sketch", "norm_A", "norm_B"):
        np.testing.assert_array_equal(
            np.asarray(getattr(got, name)), np.asarray(getattr(want, name)),
            err_msg=f"{msg}{name}")


def _assert_close(got, want, rtol=2e-4):
    for name in ("A_sketch", "B_sketch", "norm_A", "norm_B"):
        g, w = np.asarray(getattr(got, name)), np.asarray(getattr(want, name))
        np.testing.assert_allclose(
            g, w, rtol=rtol, atol=1e-5 * max(np.abs(w).max(), 1.0),
            err_msg=name)


# ---------------------------------------------------------------------------
# Chunked-vs-one-shot parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["gaussian", "srht"])
def test_sequential_chunks_bit_identical_to_scan(key, method):
    """Sequential ingestion at chunk c == build_summary(scan, block=c),
    bit-for-bit: the update performs the scan body's exact float ops."""
    A, B = _pair(key, d=256)
    summ = core.StreamingSummarizer(16, method=method)
    s = summ.finalize(_ingest(summ, key, A, B, chunk=64))
    scan = build_summary(key, A, B, 16, method=method, backend="scan",
                         block=64)
    _assert_bit_equal(s, scan, f"{method}/")


@pytest.mark.parametrize("method", ["gaussian", "srht"])
def test_chunked_matches_reference(key, method):
    """Any chunking agrees with the materialized-operator reference to
    float-reassociation tolerance (incl. a partial final chunk: 192 % 80)."""
    A, B = _pair(key)
    summ = core.StreamingSummarizer(16, method=method)
    ref = build_summary(key, A, B, 16, method=method, backend="reference")
    for chunk in (48, 80, 192):
        s = summ.finalize(_ingest(summ, key, A, B, chunk))
        _assert_close(s, ref)


def test_update_rows_arbitrary_order(key):
    """Shuffled explicit-id chunks (the co-occurrence stream) match the
    one-shot summary; a second shuffle matches the first to tolerance."""
    A, B = _pair(key)
    d = A.shape[0]
    summ = core.StreamingSummarizer(16)
    ref = build_summary(key, A, B, 16, backend="reference")
    for seed in (0, 1):
        perm = np.random.default_rng(seed).permutation(d)
        state = summ.init(key, (d, A.shape[1], B.shape[1]))
        for off in range(0, d, 48):
            ids = jnp.asarray(perm[off:off + 48])
            state = summ.update_rows(state, ids, A[ids], B[ids])
        assert int(state.rows_seen) == d
        _assert_close(summ.finalize(state), ref)


def test_summarize_chunks_convenience(key):
    A, B = _pair(key)
    summ = core.StreamingSummarizer(16)
    s = summ.summarize_chunks(
        key, (A.shape[0], A.shape[1], B.shape[1]),
        ((A[off:off + 64], B[off:off + 64])
         for off in range(0, A.shape[0], 64)))
    _assert_bit_equal(s, build_summary(key, A, B, 16, backend="scan",
                                       block=64))


# ---------------------------------------------------------------------------
# Monoid laws
# ---------------------------------------------------------------------------

def test_merge_commutative_bitwise(key):
    """merge(s1, s2) == merge(s2, s1) bit-for-bit (float add commutes)."""
    A, B = _pair(key)
    summ = core.StreamingSummarizer(16, method="srht")
    empty = summ.init(key, (192, 11, 7))
    s1 = summ.update(empty, A[:96], B[:96], 0)
    s2 = summ.update(empty, A[96:], B[96:], 96)
    m12, m21 = summ.merge(s1, s2), summ.merge(s2, s1)
    for f in ("A_acc", "B_acc", "na2", "nb2", "rows_seen"):
        np.testing.assert_array_equal(np.asarray(getattr(m12, f)),
                                      np.asarray(getattr(m21, f)), err_msg=f)


@settings(deadline=None, max_examples=8)
@given(i=st.sampled_from([32, 64, 96]), j=st.sampled_from([128, 160]))
def test_merge_associative_property(i, j):
    """finalize(merge(merge(a,b),c)) ~= finalize(merge(a,merge(b,c))) for
    arbitrary three-way splits (property test via tests/_hyp.py)."""
    key = jax.random.PRNGKey(3)
    A, B = _pair(key)
    summ = core.StreamingSummarizer(8)
    empty = summ.init(key, (192, 11, 7))
    a = summ.update(empty, A[:i], B[:i], 0)
    b = summ.update(empty, A[i:j], B[i:j], i)
    c = summ.update(empty, A[j:], B[j:], j)
    left = summ.finalize(summ.merge(summ.merge(a, b), c))
    right = summ.finalize(summ.merge(a, summ.merge(b, c)))
    _assert_close(left, right, rtol=2e-5)
    assert int(summ.merge(summ.merge(a, b), c).rows_seen) == 192


@settings(deadline=None, max_examples=6)
@given(chunk=st.sampled_from([32, 64, 96]), order_seed=st.integers(0, 99))
def test_any_merge_order_matches_one_shot(chunk, order_seed):
    """Per-chunk partial states merged in a random order match the one-shot
    reference summary (property test)."""
    key = jax.random.PRNGKey(4)
    A, B = _pair(key)
    summ = core.StreamingSummarizer(8)
    empty = summ.init(key, (192, 11, 7))
    parts = [summ.update(empty, A[off:off + chunk], B[off:off + chunk], off)
             for off in range(0, 192, chunk)]
    rng = np.random.default_rng(order_seed)
    rng.shuffle(parts)
    merged = parts[0]
    for p in parts[1:]:
        merged = streaming.merge_states(merged, p)
    _assert_close(summ.finalize(merged),
                  build_summary(key, A, B, 8, backend="reference"))


def test_tree_merge_matches_sequential(key):
    A, B = _pair(key)
    summ = core.StreamingSummarizer(16)
    empty = summ.init(key, (192, 11, 7))
    parts = [summ.update(empty, A[off:off + 48], B[off:off + 48], off)
             for off in range(0, 192, 48)]
    _assert_close(summ.finalize(core.tree_merge(parts)),
                  summ.finalize(_ingest(summ, key, A, B, 48)), rtol=2e-5)


def test_empty_chunk_is_identity(key):
    """Zero-row chunks are absorbed as no-ops (the monoid identity)."""
    summ = core.StreamingSummarizer(8)
    state = summ.update(summ.init(key, (64, 4, 3)), jnp.ones((16, 4)),
                        jnp.ones((16, 3)), 0)
    after = summ.update(state, jnp.zeros((0, 4)), jnp.zeros((0, 3)), 16)
    after = summ.update_rows(after, jnp.zeros((0,), jnp.int32),
                             jnp.zeros((0, 4)), jnp.zeros((0, 3)))
    for f in ("A_acc", "B_acc", "na2", "nb2", "rows_seen", "row_high"):
        np.testing.assert_array_equal(np.asarray(getattr(after, f)),
                                      np.asarray(getattr(state, f)),
                                      err_msg=f)
    # an empty A with a non-empty B is a mismatch, not an identity
    with pytest.raises(ValueError, match="row counts differ"):
        summ.update(state, jnp.zeros((0, 4)), jnp.ones((16, 3)), 16)
    with pytest.raises(ValueError, match="row counts differ"):
        summ.update_rows(state, jnp.zeros((0,), jnp.int32),
                         jnp.zeros((0, 4)), jnp.ones((16, 3)))


def test_resume_cursor_is_high_water_mark(key, tmp_path):
    """An out-of-order pass checkpointed and resumed continues appending
    after the highest absorbed row, not after rows_seen."""
    from repro.ckpt import checkpoint
    from repro.serve.engine import SketchService
    A, B = _pair(key, d=128, n1=10, n2=8)
    svc = SketchService(k=8, backend="scan", block=32)
    sid = svc.open_stream(key, 128, 10, 8)
    svc.append(sid, A[32:64], B[32:64], row_offset=32)   # out of order first
    state = svc.close_stream(sid)
    assert int(state.rows_seen) == 32 and int(state.row_high) == 64
    checkpoint.save_stream_state(str(tmp_path), 0, state)
    restored = checkpoint.restore_stream_state(
        str(tmp_path), like=core.StreamingSummarizer(8).init(
            key, (128, 10, 8)))
    sid2 = svc.open_stream(key, 128, 10, 8, state=restored)
    svc.append(sid2, A[64:96], B[64:96])        # default cursor -> row 64
    svc.append(sid2, A[96:], B[96:])
    svc.append(sid2, A[:32], B[:32], row_offset=0)       # backfill the gap
    # chunk order differs from sequential -> reassociation tolerance
    _assert_close(svc.query(sid2),
                  build_summary(key, A, B, 8, backend="scan", block=32),
                  rtol=2e-5)


@pytest.mark.parametrize("method", ["gaussian", "srht"])
def test_out_of_range_rows_rejected(key, method):
    """Row ids outside [0, d_total) raise instead of silently corrupting
    the summary (SRHT would clamp into the sign vector)."""
    summ = core.StreamingSummarizer(8, method=method)
    state = summ.init(key, (64, 4, 3))
    A = jnp.ones((16, 4))
    B = jnp.ones((16, 3))
    with pytest.raises(ValueError, match="d_total"):
        summ.update(state, A, B, row_offset=64)
    with pytest.raises(ValueError, match="d_total"):
        summ.update_rows(state, jnp.array([-1] + list(range(15))), A, B)
    summ.update(state, A, B, row_offset=48)         # last valid chunk is fine


def test_open_stream_resume_validation(key):
    """Resuming a session with a mismatched state (shape, key, or method)
    raises instead of silently breaking the stream_factors parity."""
    from repro.serve.engine import SketchService
    svc = SketchService(k=8, backend="scan", block=32)
    summ = core.StreamingSummarizer(8)
    state = summ.init(key, (64, 4, 3))
    with pytest.raises(ValueError, match="does not match"):
        svc.open_stream(key, 64, 5, 3, state=state)      # wrong n1
    with pytest.raises(ValueError, match="does not match"):
        svc.open_stream(key, 128, 4, 3, state=state)     # wrong d
    with pytest.raises(ValueError, match="different base key"):
        svc.open_stream(jax.random.PRNGKey(99), 64, 4, 3, state=state)
    srht_state = core.StreamingSummarizer(8, method="srht").init(
        key, (64, 4, 3))
    with pytest.raises(ValueError, match="method"):
        svc.open_stream(key, 64, 4, 3, state=srht_state)
    sid = svc.open_stream(key, 64, 4, 3, state=state)    # matching: fine
    assert svc.append(sid, jnp.ones((32, 4)), jnp.ones((32, 3))) == 32


def test_merge_guards(key):
    summ = core.StreamingSummarizer(8)
    s_a = summ.init(key, (64, 4, 3))
    s_b = summ.init(key, (64, 5, 3))
    with pytest.raises(ValueError, match="shapes"):
        streaming.merge_states(s_a, s_b)
    s_srht = core.StreamingSummarizer(8, method="srht").init(key, (64, 4, 3))
    with pytest.raises(ValueError, match="gaussian and srht"):
        streaming.merge_states(s_a, s_srht)
    with pytest.raises(ValueError, match="method"):
        core.StreamingSummarizer(8, method="nope")
    with pytest.raises(ValueError, match="tree_merge"):
        streaming.tree_merge([])


# ---------------------------------------------------------------------------
# Checkpoint round-trip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["gaussian", "srht"])
def test_checkpoint_roundtrip_bitwise(key, tmp_path, method):
    """save mid-pass -> restore -> continue == uninterrupted, bit-for-bit;
    the manifest records coverage."""
    from repro.ckpt import checkpoint
    A, B = _pair(key)
    summ = core.StreamingSummarizer(16, method=method)
    half = summ.update(summ.init(key, (192, 11, 7)), A[:96], B[:96], 0)
    checkpoint.save_stream_state(str(tmp_path), 96, half)
    manifest = checkpoint.read_manifest(str(tmp_path))
    assert manifest["extra"]["rows_seen"] == 96
    assert manifest["extra"]["kind"] == "stream_state"
    assert manifest["extra"]["srht"] == (method == "srht")
    restored = checkpoint.restore_stream_state(
        str(tmp_path), like=summ.init(key, (192, 11, 7)))
    full_resumed = summ.finalize(summ.update(restored, A[96:], B[96:], 96))
    full_direct = summ.finalize(summ.update(half, A[96:], B[96:], 96))
    _assert_bit_equal(full_resumed, full_direct)


# ---------------------------------------------------------------------------
# Serving accumulator sessions
# ---------------------------------------------------------------------------

def test_stream_session_matches_one_shot_flush(key):
    """open_stream/append/query == submit/flush, and stream_factors ==
    flush_factors, bit-for-bit when chunks align with the service block."""
    from repro.serve.engine import SketchService
    A, B = _pair(key, d=128, n1=10, n2=8)
    svc = SketchService(k=8, backend="scan", block=32)
    sid = svc.open_stream(key, 128, 10, 8)
    for off in range(0, 128, 32):
        seen = svc.append(sid, A[off:off + 32], B[off:off + 32])
    assert seen == 128
    ticket = svc.submit(key, A, B)
    flushed = svc.flush()[ticket]
    _assert_bit_equal(svc.query(sid), flushed)

    ticket = svc.submit(key, A, B)
    ff = svc.flush_factors(r=2, m=200, T=2)[ticket]
    sf = svc.stream_factors(sid, r=2, m=200, T=2)
    np.testing.assert_array_equal(np.asarray(sf.factors.U),
                                  np.asarray(ff.factors.U))
    np.testing.assert_array_equal(np.asarray(sf.factors.V),
                                  np.asarray(ff.factors.V))
    state = svc.close_stream(sid)
    assert int(state.rows_seen) == 128
    assert sid not in svc._streams


def test_stream_session_resumes_from_checkpoint(key, tmp_path):
    """A checkpointed state seeds a fresh session (open_stream(state=...))."""
    from repro.ckpt import checkpoint
    from repro.serve.engine import SketchService
    A, B = _pair(key, d=128, n1=10, n2=8)
    svc = SketchService(k=8, backend="scan", block=32)
    sid = svc.open_stream(key, 128, 10, 8)
    svc.append(sid, A[:32], B[:32])
    svc.append(sid, A[32:64], B[32:64])
    checkpoint.save_stream_state(str(tmp_path), 64, svc.close_stream(sid))

    svc2 = SketchService(k=8, backend="scan", block=32)
    summ = core.StreamingSummarizer(8)
    restored = checkpoint.restore_stream_state(
        str(tmp_path), like=summ.init(key, (128, 10, 8)))
    sid2 = svc2.open_stream(key, 128, 10, 8, state=restored)
    svc2.append(sid2, A[64:96], B[64:96])             # cursor resumed at 64
    assert svc2.append(sid2, A[96:], B[96:]) == 128
    _assert_bit_equal(svc2.query(sid2),
                      build_summary(key, A, B, 8, backend="scan", block=32))


# ---------------------------------------------------------------------------
# Distributed tree-reduce
# ---------------------------------------------------------------------------

@pytest.mark.dist
def test_distributed_streaming_tree_reduce():
    """Per-device partial states merged by one psum (2-shard CPU mesh, slab
    chunking) match the reference summary, both methods."""
    from tests.dist.helpers import run_with_devices
    out = run_with_devices("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro import core
    mesh = Mesh(np.array(jax.devices()), ("shard",))
    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (256, 20))
    B = jax.random.normal(jax.random.fold_in(key, 1), (256, 14))
    for method in ("gaussian", "srht"):
        ref = core.build_summary(key, A, B, 32, method=method,
                                 backend="reference")
        # slab=96 leaves a trailing partial slab (256 = 96+96+64): the
        # rounding guard must keep every slab divisible by the 2 shards
        got = core.distributed_streaming_summary(
            mesh, "shard", key, A, B, 32, method=method, slab=96)
        for name in ("A_sketch", "B_sketch", "norm_A", "norm_B"):
            g = np.asarray(getattr(got, name))
            w = np.asarray(getattr(ref, name))
            np.testing.assert_allclose(
                g, w, rtol=2e-4, atol=1e-5 * max(np.abs(w).max(), 1.0),
                err_msg=f"{method}/{name}")
    print("DIST_STREAM_OK")
    """, n_devices=2)
    assert "DIST_STREAM_OK" in out


# ---------------------------------------------------------------------------
# Gradient taps ride the same monoid
# ---------------------------------------------------------------------------

def test_tap_state_monoid(key):
    """accumulate_taps is merge_states on wrapped states; decompress_tap
    finalizes through streaming.finalize_state."""
    from repro.train import sketched_dense as sd
    k1, k2 = jax.random.split(key)
    def mk(kk):
        ks = jax.random.split(kk, 4)
        return {"a": jax.random.normal(ks[0], (8, 6)),
                "b": jax.random.normal(ks[1], (8, 5)),
                "na2": jnp.abs(jax.random.normal(ks[2], (6,))),
                "nb2": jnp.abs(jax.random.normal(ks[3], (5,)))}
    t1, t2 = mk(k1), mk(k2)
    acc = sd.accumulate_taps(t1, t2)
    for f in ("a", "b", "na2", "nb2"):
        np.testing.assert_array_equal(np.asarray(acc[f]),
                                      np.asarray(t1[f] + t2[f]), err_msg=f)
    s = streaming.finalize_state(sd.tap_state(t1))
    np.testing.assert_allclose(np.asarray(s.norm_A),
                               np.sqrt(np.asarray(t1["na2"])), rtol=1e-6)
    dw = sd.decompress_tap(key, t1, sd.TapConfig(sketch_k=8, rank=2,
                                                 als_iters=2))
    assert dw.shape == (6, 5)
