"""Step-1 tests: JL guarantees, SRHT, streaming-order invariance, merging."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # hypothesis is optional; see tests/_hyp.py
    from tests._hyp import given, settings, strategies as st

from repro import core
from tests.conftest import planted_pair


def test_gaussian_pi_scale(key):
    Pi = core.gaussian_pi(key, 64, 512)
    # E||Pi x||^2 = ||x||^2
    x = jnp.ones((512,))
    assert abs(float(jnp.sum((Pi @ x) ** 2)) / 512.0 - 1.0) < 0.5


def test_sketch_preserves_norms_statistically(key):
    A, B = planted_pair(key, 1024, 50)
    s = core.sketch_summary(key, A, B, k=256)
    sk_norms = jnp.linalg.norm(s.A_sketch, axis=0)
    rel = np.asarray(jnp.abs(sk_norms - s.norm_A) / s.norm_A)
    assert rel.mean() < 0.15  # eps ~ 1/sqrt(k)


def test_column_norms_exact(key):
    A, B = planted_pair(key, 200, 30)
    s = core.sketch_summary(key, A, B, k=16)
    np.testing.assert_allclose(
        np.asarray(s.norm_A), np.linalg.norm(np.asarray(A), axis=0), rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(s.norm_B), np.linalg.norm(np.asarray(B), axis=0), rtol=1e-5)


def test_fwht_is_orthogonal_involution(key):
    x = jax.random.normal(key, (64, 7))
    y = core.fwht(core.fwht(x, axis=0), axis=0) / 64.0
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-4)


def test_fwht_matches_hadamard_matrix(key):
    d = 16
    H = np.array([[1.0]])
    while H.shape[0] < d:
        H = np.block([[H, H], [H, -H]])
    x = np.asarray(jax.random.normal(key, (d, 3)))
    np.testing.assert_allclose(np.asarray(core.fwht(jnp.array(x), axis=0)),
                               H @ x, rtol=1e-4, atol=1e-4)


def test_srht_preserves_dot_products(key):
    A, B = planted_pair(key, 500, 40, corr=0.5)
    s = core.sketch_summary(key, A, B, k=256, method="srht")
    exact = np.asarray(A.T @ B)
    approx = np.asarray(s.A_sketch.T @ s.B_sketch)
    scale = np.linalg.norm(np.asarray(A), axis=0)[:, None] * \
        np.linalg.norm(np.asarray(B), axis=0)[None, :]
    assert np.mean(np.abs(exact - approx) / scale) < 0.1


def test_streaming_order_invariance(key):
    """The paper's arbitrary-order claim: permuting the row stream leaves the
    one-pass summary numerically unchanged."""
    d, n = 256, 20
    A, B = planted_pair(key, d, n)
    idx = jnp.arange(d)
    perm = jax.random.permutation(jax.random.fold_in(key, 7), d)
    s1 = core.streamed_rows_summary(key, idx, A, B, k=32)
    s2 = core.streamed_rows_summary(key, perm, A[perm], B[perm], k=32)
    np.testing.assert_allclose(np.asarray(s1.A_sketch), np.asarray(s2.A_sketch),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1.norm_A), np.asarray(s2.norm_A),
                               rtol=1e-5)


def test_sketch_pass_matches_streamed(key):
    """Block-streamed pass == row-streamed pass (same per-row Pi derivation)."""
    d, n = 512, 16
    A, B = planted_pair(key, d, n)
    s_blk = core.sketch_pass(key, A, B, k=32, block=128)
    s_str = core.streamed_rows_summary(key, jnp.arange(d), A, B, k=32)
    np.testing.assert_allclose(np.asarray(s_blk.A_sketch),
                               np.asarray(s_str.A_sketch), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_blk.norm_B),
                               np.asarray(s_str.norm_B), rtol=1e-5)


def test_merge_summaries_is_shard_concat(key):
    d, n = 400, 12
    A, B = planted_pair(key, d, n)
    full = core.streamed_rows_summary(key, jnp.arange(d), A, B, k=16)
    half1 = core.streamed_rows_summary(key, jnp.arange(0, 200), A[:200], B[:200], k=16)
    half2 = core.streamed_rows_summary(key, jnp.arange(200, 400), A[200:], B[200:], k=16)
    merged = core.merge_summaries(half1, half2)
    np.testing.assert_allclose(np.asarray(merged.A_sketch),
                               np.asarray(full.A_sketch), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(merged.norm_A),
                               np.asarray(full.norm_A), rtol=1e-5)


@settings(deadline=None, max_examples=15)
@given(d=st.sampled_from([64, 128, 257]), n=st.integers(2, 24),
       k=st.sampled_from([8, 32]), seed=st.integers(0, 2**31 - 1))
def test_property_sketch_linearity(d, n, k, seed):
    """sketch(aA1 + bA2) == a sketch(A1) + b sketch(A2) for a fixed Pi —
    the linearity that makes the distributed psum aggregation exact."""
    kk = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(kk)
    A1 = jax.random.normal(k1, (d, n))
    A2 = jax.random.normal(k2, (d, n))
    Pi = core.gaussian_pi(kk, k, d)
    lhs = Pi @ (2.0 * A1 - 0.5 * A2)
    rhs = 2.0 * (Pi @ A1) - 0.5 * (Pi @ A2)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               rtol=1e-4, atol=1e-4)


@settings(deadline=None, max_examples=10)
@given(n=st.integers(4, 40), seed=st.integers(0, 2**31 - 1))
def test_property_norm_merge_pythagorean(n, seed):
    """Column norms of disjoint row shards combine in quadrature."""
    kk = jax.random.PRNGKey(seed)
    A = jax.random.normal(kk, (100, n))
    B = jax.random.normal(jax.random.fold_in(kk, 1), (100, n))
    s1 = core.streamed_rows_summary(kk, jnp.arange(0, 50), A[:50], B[:50], k=4)
    s2 = core.streamed_rows_summary(kk, jnp.arange(50, 100), A[50:], B[50:], k=4)
    merged = core.merge_summaries(s1, s2)
    np.testing.assert_allclose(np.asarray(merged.norm_A),
                               np.linalg.norm(np.asarray(A), axis=0), rtol=1e-4)


def test_fwht_non_pow2_raises_named_valueerror():
    """fwht on a non-power-of-two axis is a descriptive ValueError naming
    the offending length and shape, never a strippable assert."""
    import pytest
    from repro.core.sketch import fwht
    with pytest.raises(ValueError, match=r"power of two.*48"):
        fwht(jnp.ones((48, 4)), axis=0)
    with pytest.raises(ValueError, match=r"axis 1"):
        fwht(jnp.ones((4, 12)), axis=1)
    # power-of-two lengths still pass through untouched
    assert fwht(jnp.ones((16, 3)), axis=0).shape == (16, 3)
