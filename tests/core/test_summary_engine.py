"""SummaryEngine tests: backend-parity matrix, batched (vmapped) mode,
precision policy, identity-product path, and the serving front-end.

The engine's contract: identical (key, global_row_index) randomness across
backends, so for a fixed key every backend produces the same summary up to
float reassociation ('rows' shares the reference's exact contraction and is
bit-identical; scan/pallas/distributed reassociate the d-accumulation).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.core import summary_engine as se
from tests.conftest import gaussian_pair, planted_pair


def _pair(key, d=300, n1=24, n2=18):
    return gaussian_pair(key, d, n1, n2)


def _assert_summary_close(got, want, rtol=2e-4, atol_scale=1e-5):
    for name in ("A_sketch", "B_sketch", "norm_A", "norm_B"):
        g, w = np.asarray(getattr(got, name)), np.asarray(getattr(want, name))
        np.testing.assert_allclose(
            g, w, rtol=rtol, atol=atol_scale * max(np.abs(w).max(), 1.0),
            err_msg=name)


# ---------------------------------------------------------------------------
# Parity matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["gaussian", "srht"])
@pytest.mark.parametrize("backend", ["scan", "rows", "pallas"])
def test_backend_parity_vs_reference(key, method, backend):
    """Every backend x method cell agrees with the reference backend."""
    A, B = _pair(key)                       # d=300: exercises padding paths
    ref = se.build_summary(key, A, B, 32, method=method, backend="reference")
    got = se.build_summary(key, A, B, 32, method=method, backend=backend,
                           block=128)
    if backend == "rows":                   # same contraction -> bit-identical
        for name in ("A_sketch", "B_sketch", "norm_A", "norm_B"):
            np.testing.assert_array_equal(
                np.asarray(getattr(got, name)),
                np.asarray(getattr(ref, name)), err_msg=name)
    else:
        _assert_summary_close(got, ref)


@pytest.mark.dist
def test_distributed_backend_parity():
    """2-shard CPU mesh vs reference, both methods (subprocess: the main
    pytest process must keep the single real CPU device)."""
    from tests.dist.helpers import run_with_devices
    out = run_with_devices("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro.core import summary_engine as se
    mesh = Mesh(np.array(jax.devices()), ("shard",))
    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (256, 20))
    B = jax.random.normal(jax.random.fold_in(key, 1), (256, 14))
    for method in ("gaussian", "srht"):
        ref = se.build_summary(key, A, B, 32, method=method,
                               backend="reference")
        got = se.build_summary(key, A, B, 32, method=method,
                               backend="distributed", mesh=mesh, axis="shard")
        for name in ("A_sketch", "B_sketch", "norm_A", "norm_B"):
            g = np.asarray(getattr(got, name))
            w = np.asarray(getattr(ref, name))
            np.testing.assert_allclose(
                g, w, rtol=2e-4, atol=1e-5 * max(np.abs(w).max(), 1.0),
                err_msg=f"{method}/{name}")
    print("DIST_PARITY_OK")
    """, n_devices=2)
    assert "DIST_PARITY_OK" in out


def test_unknown_backend_and_method_raise(key):
    A, B = _pair(key, d=64, n1=4, n2=4)
    with pytest.raises(ValueError, match="backend"):
        se.build_summary(key, A, B, 8, backend="nope")
    with pytest.raises(ValueError, match="method"):
        se.build_summary(key, A, B, 8, method="nope")
    assert set(se.backends()) >= {"reference", "scan", "rows", "pallas",
                                  "distributed"}


def test_srht_is_a_subspace_embedding_on_every_backend(key):
    """Statistical sanity on top of parity: srht preserves column norms."""
    A, B = planted_pair(key, 500, 40, corr=0.5)
    for backend in ("reference", "scan", "pallas"):
        s = se.build_summary(key, A, B, 256, method="srht", backend=backend)
        rel = np.asarray(
            jnp.abs(jnp.linalg.norm(s.A_sketch, axis=0) - s.norm_A)
            / s.norm_A)
        assert rel.mean() < 0.15, backend


# ---------------------------------------------------------------------------
# Batched (vmapped) mode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["reference", "scan", "pallas"])
def test_batched_matches_looped(key, backend):
    """One vmapped dispatch over a (L, d, n) stack == L single dispatches."""
    L = 3
    A = jax.random.normal(key, (L, 128, 12))
    B = jax.random.normal(jax.random.fold_in(key, 1), (L, 128, 9))
    batched = se.build_summary(key, A, B, 16, backend=backend, block=64)
    keys = jax.random.split(key, L)
    for i in range(L):
        single = se.build_summary(keys[i], A[i], B[i], 16, backend=backend,
                                  block=64)
        _assert_summary_close(
            jax.tree.map(lambda x: x[i], batched), single, rtol=1e-5)


def test_batched_accepts_key_stack(key):
    """An explicit (L, 2) key stack is used verbatim (per-request keys)."""
    L = 2
    A = jax.random.normal(key, (L, 64, 6))
    B = jax.random.normal(jax.random.fold_in(key, 1), (L, 64, 5))
    keys = jax.random.split(jax.random.fold_in(key, 7), L)
    batched = se.build_summary(keys, A, B, 8, backend="scan", block=32)
    single = se.build_summary(keys[1], A[1], B[1], 8, backend="scan",
                              block=32)
    _assert_summary_close(
        jax.tree.map(lambda x: x[1], batched), single, rtol=1e-5)


def test_sketch_service_buckets_and_matches(key):
    """The serving front-end returns per-request results identical to solo
    dispatches, across mixed shape buckets."""
    from repro.serve.engine import SketchService
    svc = SketchService(k=8, backend="scan", block=32)
    reqs = []
    for i, (d, n1, n2) in enumerate([(64, 6, 5), (96, 4, 7), (64, 6, 5)]):
        kk = jax.random.fold_in(key, i)
        A, B = _pair(kk, d, n1, n2)
        reqs.append((svc.submit(kk, A, B), kk, A, B))
    assert svc.pending == 3
    out = svc.flush()
    assert svc.pending == 0
    for ticket, kk, A, B in reqs:
        solo = se.build_summary(kk, A, B, 8, backend="scan", block=32)
        _assert_summary_close(out[ticket], solo, rtol=1e-5)


# ---------------------------------------------------------------------------
# Precision policy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["reference", "scan", "pallas"])
def test_bf16_precision_policy(key, backend):
    """bf16-in/f32-accumulate: outputs stay f32 and track the f32 result to
    bf16 input-rounding accuracy."""
    A, B = _pair(key, d=256, n1=16, n2=12)
    s32 = se.build_summary(key, A, B, 32, backend=backend)
    sbf = se.build_summary(key, A, B, 32, backend=backend, precision="bf16")
    for name in ("A_sketch", "B_sketch", "norm_A", "norm_B"):
        assert getattr(sbf, name).dtype == jnp.float32, name
    scale = float(jnp.abs(s32.A_sketch).max())
    assert float(jnp.max(jnp.abs(sbf.A_sketch - s32.A_sketch))) < 0.05 * scale
    np.testing.assert_allclose(np.asarray(sbf.norm_A), np.asarray(s32.norm_A),
                               rtol=2e-2)


# ---------------------------------------------------------------------------
# Structured-product paths (the engine-owned caller integrations)
# ---------------------------------------------------------------------------

def test_identity_product_summary_matches_manual(key):
    """A=I mapping: A_sketch is Pi itself, B_sketch = Pi @ G, exact norms."""
    G = jax.random.normal(key, (64, 48))
    s = se.identity_product_summary(key, G, 16)
    Pi = core.gaussian_pi(key, 16, 64)
    np.testing.assert_array_equal(np.asarray(s.A_sketch), np.asarray(Pi))
    np.testing.assert_allclose(np.asarray(s.B_sketch), np.asarray(Pi @ G),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s.norm_B),
                               np.linalg.norm(np.asarray(G), axis=0),
                               rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(s.norm_A), np.ones(64))


def test_compress_leaf_stacked_matches_loop(key):
    """(L, n1, n2) stacked layer groups compress layer-by-layer identically
    to the looped 2D path (the batched engine mode)."""
    from repro.optim import grad_compression as gc
    cfg = gc.CompressionConfig(rank=2, sketch_k=16, als_iters=2)
    G = jax.random.normal(key, (2, 64, 72)) * 0.1
    stacked = gc.compress_leaf(key, G, cfg)
    assert stacked.shape == G.shape
    keys = jax.random.split(key, 2)
    for i in range(2):
        solo = gc.compress_leaf(keys[i], G[i], cfg)
        np.testing.assert_allclose(np.asarray(stacked[i]), np.asarray(solo),
                                   rtol=1e-4, atol=1e-5)


def test_smppca_through_engine_backends(key):
    """End-to-end Alg 1 quality is backend-independent."""
    A, B = planted_pair(key, 1024, 50, corr=0.4)
    errs = {}
    for backend in ("reference", "scan", "pallas"):
        res = core.smppca(key, A, B, r=3, k=128, m=6000, T=4,
                          backend=backend)
        errs[backend] = float(core.spectral_error(A, B, res.factors))
    for backend, e in errs.items():
        assert e < 0.8, (backend, errs)
    spread = max(errs.values()) - min(errs.values())
    assert spread < 0.05, errs


def test_srht_oversized_k_raises_named_valueerror(key):
    """srht with k > next_pow2(d) cannot sample k distinct rows: a
    descriptive ValueError naming the shapes, never a strippable assert."""
    import pytest
    from repro.core.summary_engine import srht_plan
    with pytest.raises(ValueError, match=r"k=100.*d=48"):
        srht_plan(key, 48, 100)
    A = jax.random.normal(key, (48, 6))
    B = jax.random.normal(jax.random.fold_in(key, 1), (48, 5))
    with pytest.raises(ValueError, match="next_pow2"):
        core.build_summary(key, A, B, 100, method="srht")
    # k exactly at the padded dimension is still legal
    assert srht_plan(key, 48, 64)[1].shape == (64,)
