"""Drift-aware summary tests: decay/window algebra, recovery, lifecycle.

The contract (docs/streaming.md "Drifting streams"):

* exponential decay is *exactly* compatible with the monoid —
  ``decay(merge(s1, s2)) == merge(decay(s1), decay(s2))`` bit-for-bit
  (laziness: decay only moves an integer timestamp; settlement runs the
  identical float ops on both sides), merge stays bit-commutative, and
  ``decay=1.0`` is bit-identical to the vanilla ``StreamState`` path;
* the sliding window is a ring of per-epoch buckets under reserved-fold
  keys: the merged window equals the same buckets rebuilt independently,
  bit-for-bit, and sliding is O(1) forgetting;
* both variants checkpoint/resume bit-exactly (timestamps and ring index
  ride the manifest) and serve through ``SketchService`` sessions;
* on a piecewise-stationary stream (``drifting_spectrum_pair``) the
  decayed/windowed summaries recover the phase-2 subspace after the flip
  while the cumulative summary does not.

Every new ``ValueError`` raise path in core/streaming.py and
ckpt/checkpoint.py is exercised here by message.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    from tests._hyp import given, settings
    from tests._hyp import strategies as st

from repro import core
from repro.core.error_engine import probe_omega
from repro.core.streaming import (
    StreamingSummarizer, WindowedSummarizer, WindowState, decay_state,
    finalize_state, merge_states, tree_merge, window_bucket_key)
from repro.ckpt import checkpoint
from tests.conftest import drifting_spectrum_pair, gaussian_pair as _pair

D, N1, N2 = 192, 11, 7


def _assert_states_bit_equal(s1, s2, msg=""):
    """Pytree structure AND every leaf bit-for-bit."""
    assert jax.tree.structure(s1) == jax.tree.structure(s2), msg
    for a, b in zip(jax.tree.leaves(s1), jax.tree.leaves(s2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=msg)


def _make_pair(seed=0, d=D):
    return _pair(jax.random.PRNGKey(seed), d=d)


# ---------------------------------------------------------------------------
# The decay algebra (property tests)
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=8)
@given(split=st.sampled_from([32, 64, 96, 128]),
       dt=st.integers(0, 5),
       gamma=st.sampled_from([0.5, 0.9, 0.99]))
def test_decay_merge_commutation_bitwise(split, dt, gamma):
    """decay(merge(s1, s2)) == merge(decay(s1), decay(s2)), BIT-FOR-BIT:
    the decay op only advances the integer clock, so both sides settle with
    the identical float ops (the tentpole law)."""
    key = jax.random.PRNGKey(3)
    A, B = _make_pair(3)
    summ = StreamingSummarizer(8, probes=2, decay=gamma)
    s1 = summ.update(summ.init(key, (D, N1, N2)), A[:split], B[:split], 0)
    s2 = summ.update(summ.init(key, (D, N1, N2)), A[split:], B[split:],
                     split)
    lhs = decay_state(merge_states(s1, s2), dt)
    rhs = merge_states(decay_state(s1, dt), decay_state(s2, dt))
    _assert_states_bit_equal(lhs, rhs, f"split={split} dt={dt} g={gamma}")
    # and the law survives finalization (settlement) too
    _assert_states_bit_equal(finalize_state(lhs), finalize_state(rhs))


@settings(deadline=None, max_examples=8)
@given(split=st.sampled_from([32, 64, 96]),
       dt1=st.integers(0, 4), dt2=st.integers(0, 4))
def test_decayed_merge_commutative_bitwise(split, dt1, dt2):
    """merge stays bit-commutative on decayed states even when the two
    operands sit at different logical times (the alignment is symmetric)."""
    key = jax.random.PRNGKey(5)
    A, B = _make_pair(5)
    summ = StreamingSummarizer(8, probes=2, decay=0.9)
    s1 = decay_state(
        summ.update(summ.init(key, (D, N1, N2)), A[:split], B[:split], 0),
        dt1)
    s2 = decay_state(
        summ.update(summ.init(key, (D, N1, N2)), A[split:], B[split:],
                    split), dt2)
    _assert_states_bit_equal(merge_states(s1, s2), merge_states(s2, s1))


@settings(deadline=None, max_examples=6)
@given(i=st.sampled_from([32, 64]), j=st.sampled_from([96, 128]),
       dt=st.integers(0, 3))
def test_decayed_monoid_associative(i, j, dt):
    """Reassociating the merge tree of decayed partials agrees to float
    tolerance (the settlement factors multiply out the same either way)."""
    key = jax.random.PRNGKey(7)
    A, B = _make_pair(7)
    summ = StreamingSummarizer(8, decay=0.9)
    parts = [summ.update(summ.init(key, (D, N1, N2)), A[a:b], B[a:b], a)
             for a, b in ((0, i), (i, j), (j, D))]
    parts = [decay_state(s, n) for s, n in zip(parts, (dt, 0, dt))]
    left = merge_states(merge_states(parts[0], parts[1]), parts[2])
    right = merge_states(parts[0], merge_states(parts[1], parts[2]))
    lf, rf = finalize_state(left), finalize_state(right)
    for name in ("A_sketch", "B_sketch", "norm_A", "norm_B"):
        np.testing.assert_allclose(np.asarray(getattr(lf, name)),
                                   np.asarray(getattr(rf, name)),
                                   rtol=2e-4, atol=1e-5)


@settings(deadline=None, max_examples=6)
@given(chunk=st.sampled_from([32, 48, 64, 192]))
def test_decay_one_bit_parity_with_vanilla(chunk):
    """decay=1.0 is the vanilla path, bit-for-bit: identical pytree
    structure, identical leaves, after any chunking — every historical
    parity/golden suite keeps its meaning."""
    key = jax.random.PRNGKey(11)
    A, B = _make_pair(11)
    plain = StreamingSummarizer(8, probes=2)
    one = StreamingSummarizer(8, probes=2, decay=1.0)

    def run(summ):
        s = summ.init(key, (D, N1, N2))
        for off in range(0, D, chunk):
            s = summ.update(s, A[off:off + chunk], B[off:off + chunk], off)
        return summ.advance(s, 3)     # identity without a decay clock

    _assert_states_bit_equal(run(plain), run(one))


def test_decay_matches_explicit_reweighting(key):
    """Semantics: after ``advance(dt)`` the earlier mass is worth
    ``gamma^dt`` — the decayed accumulator equals the explicit weighted sum
    of per-chunk contributions."""
    A, B = _make_pair(13)
    gamma, dt = 0.5, 3
    summ = StreamingSummarizer(8, probes=2, decay=gamma)
    van = StreamingSummarizer(8, probes=2)
    s = summ.update(summ.init(key, (D, N1, N2)), A[:96], B[:96], 0)
    s = summ.advance(s, dt)
    s = summ.update(s, A[96:], B[96:], 96)
    c1 = van.update(van.init(key, (D, N1, N2)), A[:96], B[:96], 0)
    c2 = van.update(van.init(key, (D, N1, N2)), A[96:], B[96:], 96)
    w = gamma ** dt
    np.testing.assert_allclose(
        np.asarray(s.A_acc), np.asarray(w * c1.A_acc + c2.A_acc), rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(s.probe_acc),
        np.asarray(w * c1.probe_acc + c2.probe_acc), rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(s.na2), np.asarray(w * c1.na2 + c2.na2), rtol=1e-5)


def test_distributed_update_decay_commutes_with_psum(key):
    """The sharded slab update on a decayed state equals the single-device
    decayed update to float-reassociation tolerance — decay (a scalar on
    linear accumulators) commutes with the psum."""
    from jax.sharding import Mesh
    from repro.core.distributed import distributed_streaming_update
    mesh = Mesh(np.array(jax.devices()[:1]), ("x",))
    A, B = _make_pair(17)
    summ = StreamingSummarizer(8, probes=2, decay=0.5)
    st0 = summ.update(summ.init(key, (D, N1, N2)), A[:96], B[:96], 0)
    st0 = summ.advance(st0, 2)
    got = distributed_streaming_update(mesh, "x", summ, st0,
                                       A[96:], B[96:], row_offset=96)
    want = summ.update(st0, A[96:], B[96:], 96)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# The sliding window (property tests)
# ---------------------------------------------------------------------------

def _rebuild_window(key, shapes, epoch_log, head, n_buckets, probes):
    """Independently rebuild each live bucket from the per-epoch chunk log
    and merge ascending — the windowed-slide vs rebuilt-from-buckets
    oracle."""
    inner = StreamingSummarizer(8, probes=probes)
    omega = probe_omega(key, shapes[2], probes) if probes else None
    states = []
    for e in range(head - n_buckets + 1, head + 1):
        b = inner.init(window_bucket_key(key, e), shapes)
        if omega is not None:
            b = b._replace(omega=omega)
        for A_c, B_c, off in epoch_log.get(e, []):
            b = inner.update(b, A_c, B_c, off)
        states.append(b)
    return tree_merge(states)


@settings(deadline=None, max_examples=8)
@given(chunk=st.sampled_from([32, 64, 96]), slides=st.integers(1, 4),
       probes=st.sampled_from([0, 2]))
def test_windowed_slide_matches_rebuilt_from_buckets(chunk, slides, probes):
    """Driving the ring through interleaved updates and O(1) slides equals
    rebuilding every live bucket from scratch and merging ascending —
    BIT-FOR-BIT (same bucket keys, same update ops, same merge tree)."""
    key = jax.random.PRNGKey(19)
    win = WindowedSummarizer(8, 3, probes=probes)
    w = win.init(key, (D, N1, N2))
    epoch_log = {}
    rnd = np.random.default_rng(chunk * 100 + slides)
    for s in range(slides + 1):
        A, B = _make_pair(seed=1000 + s)
        off = 0
        while off < D:
            w = win.update(w, A[off:off + chunk], B[off:off + chunk], off)
            epoch_log.setdefault(int(w.head), []).append(
                (A[off:off + chunk], B[off:off + chunk], off))
            off += chunk
        if s < slides:
            n = int(rnd.integers(1, 3))
            w = win.slide(w, n)
    rebuilt = _rebuild_window(key, (D, N1, N2), epoch_log, int(w.head),
                              3, probes)
    _assert_states_bit_equal(win.merged(w), rebuilt)
    _assert_states_bit_equal(finalize_state(win.merged(w)),
                             win.finalize(w))


def test_window_forgets_expired_epochs(key):
    """Sliding past an epoch erases its rows from the summary entirely —
    the O(1) slide is exact forgetting, not attenuation."""
    A, B = _make_pair(23)
    win = WindowedSummarizer(8, 2)
    w = win.init(key, (D, N1, N2))
    w = win.update(w, A, B, 0)
    assert int(win.merged(w).rows_seen) == D
    w = win.slide(w)                      # still inside the 2-epoch window
    assert int(win.merged(w).rows_seen) == D
    w = win.slide(w)                      # now expired
    assert int(win.merged(w).rows_seen) == 0
    s = win.finalize(w)
    assert bool(jnp.all(s.A_sketch == 0)) and bool(jnp.all(s.norm_A == 0))


def test_window_bucket_keys_decorrelate_epochs(key):
    """Two epochs ingesting the SAME rows under the same bucket-local ids
    produce different sketches (per-epoch reserved-fold keys) — repeating
    row ids across epochs does not reuse projection columns."""
    A, B = _make_pair(29)
    win = WindowedSummarizer(8, 2)
    w = win.init(key, (D, N1, N2))
    w = win.update(w, A, B, 0)
    b_first = w.buckets[int(w.head) % 2]
    w = win.slide(w)
    w = win.update(w, A, B, 0)
    b_second = w.buckets[int(w.head) % 2]
    assert not np.array_equal(np.asarray(b_first.A_acc),
                              np.asarray(b_second.A_acc))
    # while each bucket alone is a faithful summary under its own key
    np.testing.assert_array_equal(np.asarray(b_first.na2),
                                  np.asarray(b_second.na2))


# ---------------------------------------------------------------------------
# Drift recovery: the piecewise-stationary spectrum flip
# ---------------------------------------------------------------------------

def _top_subspace_residual(summary, U):
    """||(I - Uhat Uhat^T) U||_2 of the estimate's top left subspace."""
    E = summary.A_sketch.T @ summary.B_sketch
    Uh = jnp.linalg.svd(E, full_matrices=False)[0][:, :U.shape[1]]
    return float(jnp.linalg.norm(U - Uh @ (Uh.T @ U), 2))


def test_drift_windowed_and_decayed_recover_vanilla_does_not(key,
                                                             drifting_pair):
    """After the subspace flip, the windowed and decayed summaries answer
    with the phase-2 subspace; the cumulative summary stays pinned to the
    (stronger) phase-1 subspace."""
    (A1, B1, _, U1), (A2, B2, _, U2) = drifting_pair
    d, n1, n2 = A1.shape[0], A1.shape[1], B1.shape[1]
    k = 128

    van = StreamingSummarizer(k)
    s = van.init(key, (2 * d, n1, n2))
    s = van.update(s, A1, B1, 0)
    s = van.update(s, A2, B2, d)
    r_vanilla = _top_subspace_residual(van.finalize(s), U2)

    dec = StreamingSummarizer(k, decay=0.5)
    s = dec.update(dec.init(key, (d, n1, n2)), A1, B1, 0)
    s = dec.advance(s, 6)                 # phase-1 mass worth 2^-6
    s = dec.update(s, A2, B2, 0)
    r_decay = _top_subspace_residual(dec.finalize(s), U2)

    win = WindowedSummarizer(k, 2)
    w = win.init(key, (d, n1, n2))
    w = win.update(w, A1, B1, 0)
    w = win.slide(w)
    w = win.update(w, A2, B2, 0)
    w = win.slide(w)                      # phase 1 expires
    r_window = _top_subspace_residual(win.finalize(w), U2)

    assert r_vanilla > 0.9, r_vanilla     # cumulative: stuck on phase 1
    assert r_decay < 0.5, r_decay
    assert r_window < 0.5, r_window
    # and the fixture's phases really are disjoint subspaces
    assert float(jnp.linalg.norm(U1.T @ U2, 2)) < 1e-5


# ---------------------------------------------------------------------------
# Checkpoint round-trips (timestamps + ring index in the manifest)
# ---------------------------------------------------------------------------

def test_decayed_checkpoint_roundtrip_bit_exact(key, tmp_path):
    """A decayed state with PENDING decay saves/restores bit-exactly, the
    manifest carries the clock, and resuming then continuing is
    bit-identical to the uninterrupted pass."""
    A, B = _make_pair(31)
    summ = StreamingSummarizer(8, probes=2, decay=0.9)
    s = summ.update(summ.init(key, (D, N1, N2)), A[:96], B[:96], 0)
    s = summ.advance(s, 2)                # leave the decay pending
    checkpoint.save_stream_state(str(tmp_path), 1, s)
    meta = checkpoint.read_manifest(str(tmp_path))["extra"]
    assert meta["t_state"] == 2 and meta["t_data"] == 0
    assert meta["decay_rate"] == pytest.approx(0.9)
    restored = checkpoint.restore_stream_state(
        str(tmp_path), summ.init(key, (D, N1, N2)))
    _assert_states_bit_equal(restored, s)
    cont = summ.update(restored, A[96:], B[96:], 96)
    direct = summ.update(s, A[96:], B[96:], 96)
    _assert_states_bit_equal(cont, direct)
    _assert_states_bit_equal(finalize_state(cont), finalize_state(direct))


def test_window_checkpoint_roundtrip_bit_exact(key, tmp_path):
    """A slid window saves/restores bit-exactly and the manifest carries
    head / ring index / per-bucket coverage."""
    A, B = _make_pair(37)
    win = WindowedSummarizer(8, 3, probes=2)
    w = win.init(key, (D, N1, N2))
    w = win.update(w, A[:96], B[:96], 0)
    w = win.slide(w, 2)
    w = win.update(w, A[96:], B[96:], 0)
    checkpoint.save_window_state(str(tmp_path), 1, w)
    meta = checkpoint.read_manifest(str(tmp_path))["extra"]
    assert meta["kind"] == "window_state"
    assert meta["head"] == 4 and meta["n_buckets"] == 3
    assert meta["ring_index"] == 4 % 3
    assert sorted(meta["bucket_rows_seen"]) == [0, 96, 96]
    restored = checkpoint.restore_window_state(
        str(tmp_path), win.init(key, (D, N1, N2)))
    _assert_states_bit_equal(restored, w)
    # the restored ring keeps sliding/absorbing identically
    _assert_states_bit_equal(win.finalize(win.slide(restored)),
                             win.finalize(win.slide(w)))


# ---------------------------------------------------------------------------
# Serving sessions: open_stream(decay=/window=), advance_stream, the gate
# ---------------------------------------------------------------------------

def _service(k=8, **kw):
    from repro.serve.engine import SketchService
    return SketchService(k=k, backend="scan", block=32, **kw)


def test_serving_decayed_session_matches_manual(key):
    """A decay= session is the manual summarizer lifecycle, bit-for-bit —
    append/advance/query against update/advance/finalize."""
    A, B = _make_pair(41)
    svc = _service(probes=2)
    sid = svc.open_stream(key, D, N1, N2, decay=0.5)
    svc.append(sid, A[:96], B[:96])
    svc.advance_stream(sid, 2)
    svc.append(sid, A[96:], B[96:])
    got = svc.query(sid)
    summ = StreamingSummarizer(8, probes=2, decay=0.5)
    s = summ.update(summ.init(key, (D, N1, N2)), A[:96], B[:96], 0)
    s = summ.update(summ.advance(s, 2), A[96:], B[96:], 96)
    want = finalize_state(s)
    for name in ("A_sketch", "B_sketch", "norm_A", "norm_B"):
        np.testing.assert_array_equal(np.asarray(getattr(got, name)),
                                      np.asarray(getattr(want, name)))
    # close_stream hands back the decayed state for checkpointing
    assert svc.close_stream(sid).decayed


def test_serving_windowed_session_lifecycle(key):
    """A window= session slides via advance_stream (cursor restarts each
    epoch) and forgets expired epochs; stream_factors answers 'top-r NOW'
    with the auto-rank quality gate."""
    (A1, B1, _, _), (A2, B2, _, U2) = drifting_spectrum_pair(key)
    d, n1, n2 = A1.shape[0], A1.shape[1], B1.shape[1]
    svc = _service(k=128, probes=4)
    sid = svc.open_stream(key, d, n1, n2, window=2)
    svc.append(sid, A1, B1)
    svc.advance_stream(sid)
    assert svc.append(sid, A2, B2) == 2 * d      # cursor restarted at 0
    svc.advance_stream(sid)                      # phase 1 expires
    est = svc.stream_factors(sid, r="auto", tol=0.35, m=600, T=3,
                             with_error=True)
    assert est.error is not None
    Uh = est.factors.U
    resid = float(jnp.linalg.norm(U2 - Uh @ (Uh.T @ U2), 2))
    assert resid < 0.6, resid
    state = svc.close_stream(sid)
    assert isinstance(state, WindowState)


def test_serving_windowed_resume_roundtrip(key, tmp_path):
    """close_stream -> save_window_state -> restore -> open_stream(state=)
    resumes the ring bit-exactly."""
    A, B = _make_pair(43)
    svc = _service(probes=2)
    sid = svc.open_stream(key, D, N1, N2, window=2)
    svc.append(sid, A, B)
    svc.advance_stream(sid)
    w = svc.close_stream(sid)
    checkpoint.save_window_state(str(tmp_path), 0, w)
    win = WindowedSummarizer(8, 2, probes=2)
    restored = checkpoint.restore_window_state(
        str(tmp_path), win.init(key, (D, N1, N2)))
    sid2 = svc.open_stream(key, D, N1, N2, window=2, state=restored)
    got = svc.query(sid2)
    want = win.finalize(w)
    for name in ("A_sketch", "B_sketch", "norm_A", "norm_B"):
        np.testing.assert_array_equal(np.asarray(getattr(got, name)),
                                      np.asarray(getattr(want, name)))


def test_serving_decayed_resume_roundtrip(key, tmp_path):
    """Decayed sessions resume through the existing save_stream_state path
    (pending clock included) and keep ticking."""
    A, B = _make_pair(47)
    svc = _service()
    sid = svc.open_stream(key, D, N1, N2, decay=0.5)
    svc.append(sid, A[:96], B[:96])
    svc.advance_stream(sid, 3)
    s = svc.close_stream(sid)
    checkpoint.save_stream_state(str(tmp_path), 0, s)
    summ = StreamingSummarizer(8, decay=0.5)
    restored = checkpoint.restore_stream_state(
        str(tmp_path), summ.init(key, (D, N1, N2)))
    sid2 = svc.open_stream(key, D, N1, N2, decay=0.5, state=restored)
    svc.append(sid2, A[96:], B[96:], 96)
    got = svc.query(sid2)
    want = finalize_state(summ.update(s, A[96:], B[96:], 96))
    for name in ("A_sketch", "B_sketch"):
        np.testing.assert_array_equal(np.asarray(getattr(got, name)),
                                      np.asarray(getattr(want, name)))


# ---------------------------------------------------------------------------
# Raise paths: every new ValueError names its offender
# ---------------------------------------------------------------------------

def test_decay_config_rejected(key):
    for bad in (0.0, -0.5, 1.5, True, "fast"):
        with pytest.raises(ValueError, match="retention factor"):
            StreamingSummarizer(8, decay=bad)


def test_decay_state_rejects_negative_dt(key):
    summ = StreamingSummarizer(8, decay=0.5)
    s = summ.init(key, (D, N1, N2))
    with pytest.raises(ValueError, match="non-negative"):
        decay_state(s, -1)


def test_merge_rejects_mixed_decay(key):
    plain = StreamingSummarizer(8).init(key, (D, N1, N2))
    decayed = StreamingSummarizer(8, decay=0.5).init(key, (D, N1, N2))
    other = StreamingSummarizer(8, decay=0.9).init(key, (D, N1, N2))
    with pytest.raises(ValueError, match="decayed stream state with an "
                                         "undecayed"):
        merge_states(plain, decayed)
    with pytest.raises(ValueError, match="different decay rates: 0.5"):
        merge_states(decayed, other)


def test_window_config_rejected(key):
    for bad in (0, -1, True, 2.0, "3"):
        with pytest.raises(ValueError, match="n_buckets"):
            WindowedSummarizer(8, bad)
    with pytest.raises(ValueError, match="epoch must be non-negative"):
        window_bucket_key(key, -1)
    win = WindowedSummarizer(8, 2)
    w = win.init(key, (D, N1, N2))
    for bad in (0, -2, True, 1.5):
        with pytest.raises(ValueError, match="positive epoch count"):
            win.slide(w, bad)
    wrong = WindowedSummarizer(8, 3).init(key, (D, N1, N2))
    with pytest.raises(ValueError, match="expects n_buckets=2"):
        win.merged(wrong)


def test_serving_session_raises(key):
    svc = _service()
    with pytest.raises(ValueError, match="decay= OR window=, not both"):
        svc.open_stream(key, D, N1, N2, decay=0.5, window=2)
    sid = svc.open_stream(key, D, N1, N2)
    with pytest.raises(ValueError, match="no time axis"):
        svc.advance_stream(sid)
    # resume-policy mismatches
    dec = StreamingSummarizer(8, decay=0.5).init(key, (D, N1, N2))
    with pytest.raises(ValueError, match="decay policy"):
        svc.open_stream(key, D, N1, N2, state=dec)
    with pytest.raises(ValueError, match="decayed at rate 0.5"):
        svc.open_stream(key, D, N1, N2, decay=0.9, state=dec)
    w = WindowedSummarizer(8, 2).init(key, (D, N1, N2))
    with pytest.raises(ValueError, match="window="):
        svc.open_stream(key, D, N1, N2, state=w)
    with pytest.raises(ValueError, match="resized"):
        svc.open_stream(key, D, N1, N2, window=3, state=w)
    with pytest.raises(ValueError, match="needs a WindowState"):
        svc.open_stream(key, D, N1, N2, window=2,
                        state=StreamingSummarizer(8).init(key, (D, N1, N2)))
    with pytest.raises(ValueError, match="different base key"):
        svc.open_stream(jax.random.PRNGKey(9), D, N1, N2, window=2, state=w)


def test_checkpoint_raises(key, tmp_path):
    summ = StreamingSummarizer(8)
    s = summ.update(summ.init(key, (D, N1, N2)), *_make_pair(53), 0)
    checkpoint.save_stream_state(str(tmp_path), 0, s)
    # shape mismatch names the leaf and both shapes
    with pytest.raises(ValueError, match="shape"):
        checkpoint.restore(str(tmp_path),
                           StreamingSummarizer(16).init(key, (D, N1, N2)))
    # structure mismatch (decayed template vs undecayed checkpoint)
    with pytest.raises(ValueError, match="no leaf"):
        checkpoint.restore(
            str(tmp_path),
            StreamingSummarizer(8, decay=0.5).init(key, (D, N1, N2)))
    # save_window_state refuses a plain StreamState
    with pytest.raises(ValueError, match="WindowState"):
        checkpoint.save_window_state(str(tmp_path), 1, s)
    # restore_window_state refuses a resized ring
    win2 = WindowedSummarizer(8, 2)
    checkpoint.save_window_state(str(tmp_path), 2,
                                 win2.init(key, (D, N1, N2)))
    with pytest.raises(ValueError, match="resized"):
        checkpoint.restore_window_state(
            str(tmp_path), WindowedSummarizer(8, 3).init(key, (D, N1, N2)))
