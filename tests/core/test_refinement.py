"""RefinementEngine tests: Tropp co-sketch block + sketch-power refinement.

The contract (docs/estimation.md "Refined reconstruction"):

* the co-sketch pair (Y, W) is EXACTLY ((A^T B) omega, psi (A^T B)) — linear
  in the streamed rows, so it rides the streaming monoid (merge laws below)
  and the one-shot builder bit-for-bit;
* refined factorizations are never worse than the raw rescaled-sketch
  truncation at equal rank (the parity matrix), and the quality gate
  (``adaptive_rank``) passes at strictly lower rank on a slow spectrum —
  the acceptance criterion of the refinement PR;
* ``cosketch=0`` (the default) is bit-identical to the pre-refinement
  engine: no new pytree leaves, same treedef, same values.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    from tests._hyp import given, settings
    from tests._hyp import strategies as st

from repro import core
from repro.core import error_engine, estimation_engine, refinement, streaming
from repro.core.refinement import RefineSpec
from repro.core.summary_engine import build_summary
from tests.conftest import (
    drifting_spectrum_pair, gaussian_pair, known_spectrum_pair,
    spectrum_values)


def _spectral_err(A, B, factors):
    M = np.asarray(A.T @ B)
    approx = np.asarray(factors.U) @ np.asarray(factors.V).T
    return (np.linalg.norm(M - approx, ord=2)
            / np.linalg.norm(M, ord=2))


# ---------------------------------------------------------------------------
# The co-sketch block is exact and deterministic
# ---------------------------------------------------------------------------

def test_cosketch_block_is_exact(key):
    """Y == (A^T B) omega and W == psi (A^T B) to float tolerance, with the
    test matrices drawn from the reserved "csk!" fold of the base key."""
    A, B = gaussian_pair(key)
    s = build_summary(key, A, B, 16, cosketch=5)
    M = np.asarray(A.T @ B)
    omega = np.asarray(s.cosketch_omega)
    psi = np.asarray(s.cosketch_psi)
    assert omega.shape == (7, 5)
    assert psi.shape == (refinement.cosketch_width(5), 11)
    np.testing.assert_allclose(np.asarray(s.cosketch_Y), M @ omega,
                               rtol=2e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s.cosketch_W), psi @ M,
                               rtol=2e-4, atol=1e-4)
    assert s.n_cosketch == 5


def test_cosketch_off_is_bit_identical_legacy(key):
    """cosketch=0 (the default) adds no pytree leaves: same treedef, same
    leaf values as the pre-refinement engine produced."""
    A, B = gaussian_pair(key)
    with_off = build_summary(key, A, B, 16)
    assert with_off.cosketch_Y is None and with_off.cosketch_W is None
    assert with_off.cosketch_omega is None and with_off.cosketch_psi is None
    assert with_off.n_cosketch == 0
    # None fields are not leaves: the treedef/leaf count is the legacy one
    leaves = jax.tree_util.tree_leaves(with_off)
    assert len(leaves) == 4
    # and a cosketch-carrying build leaves the legacy block bit-untouched
    with_on = build_summary(key, A, B, 16, cosketch=3)
    for name in ("A_sketch", "B_sketch", "norm_A", "norm_B"):
        np.testing.assert_array_equal(
            np.asarray(getattr(with_off, name)),
            np.asarray(getattr(with_on, name)), err_msg=name)


def test_refine_spec_validation():
    with pytest.raises(TypeError, match="RefineSpec"):
        refinement.validate_refine((1, "tropp"))
    with pytest.raises(ValueError, match="method"):
        refinement.validate_refine(RefineSpec(1, "qr"))
    with pytest.raises(ValueError, match="iters"):
        refinement.validate_refine(RefineSpec(-1, "power"))
    with pytest.raises(ValueError, match="iters"):
        refinement.validate_refine(RefineSpec(True, "power"))
    refinement.validate_refine(RefineSpec())          # defaults are valid


def test_estimate_product_power_guards(key):
    """method='power' needs a co-sketch-carrying summary; refine= rejects
    other methods eagerly (never a silent ignore)."""
    A, B = gaussian_pair(key)
    bare = build_summary(key, A, B, 16)
    with pytest.raises(ValueError, match="co-sketch"):
        estimation_engine.estimate_product(key, bare, 2, method="power")
    with pytest.raises(ValueError, match="refine"):
        estimation_engine.estimate_product(
            key, bare, 2, m=64, T=2, refine=RefineSpec(1, "power"))


# ---------------------------------------------------------------------------
# Refinement parity matrix: refined never worse at equal rank
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["fast", "slow"])
@pytest.mark.parametrize("spec", [RefineSpec(0, "tropp"),
                                  RefineSpec(2, "power")])
def test_refined_not_worse_at_equal_rank(key, kind, spec):
    """On the known-spectrum fixtures, the refined factorization's spectral
    error at rank r is never worse than the raw rescaled-sketch truncation
    (direct_svd) at the same rank — across both refinement methods."""
    A, B, _ = known_spectrum_pair(key, 384, 14, 12, spectrum_values(kind))
    summary = build_summary(key, A, B, 48, cosketch=10)
    for r in (3, 6):
        raw = estimation_engine.estimate_product(
            key, summary, r, method="direct_svd")
        ref = estimation_engine.estimate_product(
            key, summary, r, method="power", refine=spec)
        e_raw = _spectral_err(A, B, raw.factors)
        e_ref = _spectral_err(A, B, ref.factors)
        assert e_ref <= e_raw * 1.02 + 1e-4, \
            (kind, spec, r, e_ref, e_raw)


def test_refined_not_worse_on_drifting_phases(key):
    """Same parity on both phases of the drifting-stream fixture (disjoint
    top subspaces, exact low rank): refined recovers each phase's product
    at least as well as the raw truncation."""
    (A1, B1, _, _), (A2, B2, _, _) = drifting_spectrum_pair(key)
    for A, B in ((A1, B1), (A2, B2)):
        summary = build_summary(key, A, B, 48, cosketch=8)
        raw = estimation_engine.estimate_product(
            key, summary, 3, method="direct_svd")
        ref = estimation_engine.estimate_product(
            key, summary, 3, method="power", refine=RefineSpec(0, "tropp"))
        assert _spectral_err(A, B, ref.factors) <= \
            _spectral_err(A, B, raw.factors) * 1.02 + 1e-4


def test_power_iterations_tighten_tight_retention(key):
    """In the tight-retention regime (co-sketch width barely above the
    target rank, decaying spectrum) sketch-power iterations buy real
    accuracy: err(iters=2) is clearly below err(iters=0). This is the
    retained-bytes-vs-accuracy trade the power method exists for."""
    A, B, _ = known_spectrum_pair(key, 384, 14, 12, spectrum_values("slow"))
    summary = build_summary(key, A, B, 128, cosketch=6)
    errs = []
    for iters in (0, 2):
        est = estimation_engine.estimate_product(
            key, summary, 3, method="power",
            refine=RefineSpec(iters, "power"))
        errs.append(_spectral_err(A, B, est.factors))
    assert errs[1] < errs[0] * 0.8, errs


# ---------------------------------------------------------------------------
# The acceptance pin: the auto-rank gate passes at lower rank
# ---------------------------------------------------------------------------

def test_adaptive_rank_passes_at_lower_rank_slow_spectrum(key):
    """THE acceptance criterion: on the slow-decay known-spectrum fixture,
    quality-gated rank selection with Tropp refinement meets tol=0.3 at a
    STRICTLY smaller rank than the unrefined gate, and the refined pick is
    honest (its true spectral error is consistent with the tolerance
    regime). Power refinement is never worse than unrefined."""
    A, B, _ = known_spectrum_pair(key, 384, 14, 12, spectrum_values("slow"))
    summary = build_summary(key, A, B, 48, probes=24, cosketch=10)
    plain = error_engine.adaptive_rank(summary, tol=0.3)
    tropp = error_engine.adaptive_rank(summary, tol=0.3,
                                       refine=RefineSpec(0, "tropp"))
    power = error_engine.adaptive_rank(summary, tol=0.3,
                                       refine=RefineSpec(1, "power"))
    assert tropp.r < plain.r, (tropp.r, plain.r)
    assert power.r <= plain.r, (power.r, plain.r)
    # the refined gate is not a free lunch: its factors really are that good
    assert _spectral_err(A, B, tropp.factors) < \
        _spectral_err(A, B, plain.factors) * 1.02 + 1e-4
    # and the refined curve sits at or below the raw curve where both exist
    n = min(tropp.curve.shape[0], plain.curve.shape[0])
    assert float(jnp.mean(tropp.curve[:n] - plain.curve[:n])) <= 1e-3


def test_rank_curve_refined_capped_by_cosketch_width(key):
    """The refined basis has only s columns, so the refined curve is capped
    at s even when r_max asks for more."""
    A, B = gaussian_pair(key)
    summary = build_summary(key, A, B, 16, probes=6, cosketch=4)
    curve = error_engine.rank_curve(summary, 7, refine=RefineSpec(0, "tropp"))
    assert curve.shape[0] == 4
    assert error_engine.rank_curve(summary, 7).shape[0] == 7
    bare = build_summary(key, A, B, 16, probes=6)
    with pytest.raises(ValueError, match="co-sketch"):
        error_engine.adaptive_rank(bare, tol=0.5, refine=RefineSpec())


# ---------------------------------------------------------------------------
# The co-sketch block rides the streaming monoid
# ---------------------------------------------------------------------------

def _cosketch_close(got, want, rtol=2e-4):
    for name in ("cosketch_Y", "cosketch_W"):
        g, w = np.asarray(getattr(got, name)), np.asarray(getattr(want, name))
        np.testing.assert_allclose(
            g, w, rtol=rtol, atol=1e-5 * max(np.abs(w).max(), 1.0),
            err_msg=name)


def test_streaming_cosketch_bit_identical_to_scan(key):
    """Sequential chunked ingestion with a co-sketch block == the scan
    backend at the same block size, bit-for-bit — including Y and W."""
    A, B = gaussian_pair(key, d=256)
    summ = core.StreamingSummarizer(16, probes=3, cosketch=4)
    state = summ.init(key, (256, 11, 7))
    for off in range(0, 256, 64):
        state = summ.update(state, A[off:off + 64], B[off:off + 64], off)
    got = summ.finalize(state)
    want = build_summary(key, A, B, 16, backend="scan", block=64,
                         probes=3, cosketch=4)
    for name in ("A_sketch", "B_sketch", "norm_A", "norm_B",
                 "cosketch_Y", "cosketch_W", "cosketch_omega",
                 "cosketch_psi"):
        np.testing.assert_array_equal(
            np.asarray(getattr(got, name)), np.asarray(getattr(want, name)),
            err_msg=name)


def test_cosketch_merge_commutative_bitwise(key):
    A, B = gaussian_pair(key)
    summ = core.StreamingSummarizer(8, cosketch=3)
    empty = summ.init(key, (192, 11, 7))
    s1 = summ.update(empty, A[:96], B[:96], 0)
    s2 = summ.update(empty, A[96:], B[96:], 96)
    m12, m21 = summ.merge(s1, s2), summ.merge(s2, s1)
    for f in ("cosketch_Y", "cosketch_W"):
        np.testing.assert_array_equal(np.asarray(getattr(m12, f)),
                                      np.asarray(getattr(m21, f)), err_msg=f)


@settings(deadline=None, max_examples=8)
@given(i=st.sampled_from([32, 64, 96]), j=st.sampled_from([128, 160]))
def test_cosketch_merge_associative_property(i, j):
    """finalize(merge(merge(a,b),c)) ~= finalize(merge(a,merge(b,c))) on the
    co-sketch accumulators for arbitrary three-way splits (property test)."""
    key = jax.random.PRNGKey(3)
    A, B = gaussian_pair(key)
    summ = core.StreamingSummarizer(8, cosketch=3)
    empty = summ.init(key, (192, 11, 7))
    a = summ.update(empty, A[:i], B[:i], 0)
    b = summ.update(empty, A[i:j], B[i:j], i)
    c = summ.update(empty, A[j:], B[j:], j)
    left = summ.finalize(summ.merge(summ.merge(a, b), c))
    right = summ.finalize(summ.merge(a, summ.merge(b, c)))
    _cosketch_close(left, right, rtol=2e-5)


@settings(deadline=None, max_examples=6)
@given(chunk=st.sampled_from([32, 64, 96]), order_seed=st.integers(0, 99))
def test_cosketch_any_merge_order_matches_one_shot(chunk, order_seed):
    """Per-chunk partial states merged in a random order reproduce the
    one-shot co-sketch block (property test)."""
    key = jax.random.PRNGKey(4)
    A, B = gaussian_pair(key)
    summ = core.StreamingSummarizer(8, cosketch=3)
    empty = summ.init(key, (192, 11, 7))
    parts = [summ.update(empty, A[off:off + chunk], B[off:off + chunk], off)
             for off in range(0, 192, chunk)]
    rng = np.random.default_rng(order_seed)
    rng.shuffle(parts)
    merged = parts[0]
    for p in parts[1:]:
        merged = streaming.merge_states(merged, p)
    _cosketch_close(summ.finalize(merged),
                    build_summary(key, A, B, 8, cosketch=3))


def test_cosketch_presence_mismatch_rejected(key):
    """Merging a co-sketch-carrying state with a co-sketch-free one is a
    descriptive ValueError, not a silent drop — in both engines."""
    A, B = gaussian_pair(key)
    with_c = core.StreamingSummarizer(8, cosketch=3)
    without = core.StreamingSummarizer(8)
    sa = with_c.update(with_c.init(key, (192, 11, 7)), A[:96], B[:96], 0)
    sb = without.update(without.init(key, (192, 11, 7)), A[96:], B[96:], 96)
    with pytest.raises(ValueError, match="cosketch"):
        streaming.merge_states(sa, sb)
    from repro.core.sketch import merge_summaries
    with pytest.raises(ValueError, match="cosketch"):
        merge_summaries(build_summary(key, A, B, 8, cosketch=3),
                        build_summary(key, A, B, 8))


def test_merged_summaries_cosketch_matches_full_build(key):
    """merge_summaries on row-split one-shot summaries reproduces the full
    build's co-sketch block (the SketchSummary-level monoid)."""
    from repro.core.sketch import merge_summaries
    A, B = gaussian_pair(key, d=256)
    full = build_summary(key, A, B, 16, cosketch=4)
    top = build_summary(key, A[:128], B[:128], 16, cosketch=4)
    # bottom half must sketch with its GLOBAL row ids
    bot_state = core.StreamingSummarizer(16, cosketch=4).init(
        key, (256, 11, 7))
    bot_state = core.StreamingSummarizer(16, cosketch=4).update(
        bot_state, A[128:], B[128:], 128)
    bot = streaming.finalize_state(bot_state)
    # top half as a summary has rows 0..128 at the same global ids
    _cosketch_close(merge_summaries(top, bot), full)


def test_decayed_and_windowed_sessions_carry_cosketch(key):
    """Drifting-stream variants keep the block consistent: a decayed state
    scales Y/W with the sketches, and window buckets share the BASE key's
    (omega, psi) pair so expired epochs drop out linearly."""
    A, B = gaussian_pair(key, d=128)
    dec = core.StreamingSummarizer(8, cosketch=3, decay=0.5)
    st_ = dec.init(key, (128, 11, 7))
    st_ = dec.update(st_, A[:64], B[:64], 0)
    st_ = dec.advance(st_, 1)
    st_ = dec.update(st_, A[64:], B[64:], 64)
    s = dec.finalize(st_)
    # decayed Y == 0.5 * Y(first half) + Y(second half), like the sketches
    s1 = dec.finalize(dec.update(dec.init(key, (128, 11, 7)),
                                 A[:64], B[:64], 0))
    s2 = dec.finalize(dec.update(dec.init(key, (128, 11, 7)),
                                 A[64:], B[64:], 64))
    np.testing.assert_allclose(
        np.asarray(s.cosketch_Y),
        0.5 * np.asarray(s1.cosketch_Y) + np.asarray(s2.cosketch_Y),
        rtol=2e-5, atol=1e-5)

    win = core.WindowedSummarizer(8, 2, cosketch=3)
    w = win.init(key, (64, 11, 7))
    base_omega = w.buckets[0].cosketch_omega
    w = win.update(w, A[:64], B[:64], 0)
    w = win.slide(w, 2)                        # first epoch fully expired
    w = win.update(w, A[64:128], B[64:128], 0)
    got = win.finalize(w)
    # every bucket shares the base pair ...
    for b in w.buckets:
        np.testing.assert_array_equal(np.asarray(b.cosketch_omega),
                                      np.asarray(base_omega))
    # ... so the finalized window equals the live rows' exact co-sketch —
    # the expired epoch's contribution dropped out linearly
    np.testing.assert_allclose(
        np.asarray(got.cosketch_Y),
        np.asarray(A[64:128].T @ (B[64:128] @ base_omega)),
        rtol=2e-4, atol=1e-4)


def test_stream_state_checkpoint_roundtrip_with_cosketch(key, tmp_path):
    """save_stream_state/restore_stream_state round-trip the co-sketch
    accumulators bit-exactly and record the width in the manifest."""
    from repro.ckpt import checkpoint
    A, B = gaussian_pair(key, d=128)
    summ = core.StreamingSummarizer(8, cosketch=3)
    state = summ.update(summ.init(key, (128, 11, 7)), A[:64], B[:64], 0)
    checkpoint.save_stream_state(str(tmp_path), 1, state)
    assert checkpoint.read_manifest(str(tmp_path))["extra"]["cosketch"] == 3
    like = summ.init(key, (128, 11, 7))
    back = checkpoint.restore_stream_state(str(tmp_path), like)
    back = summ.update(back, A[64:], B[64:], 64)
    state = summ.update(state, A[64:], B[64:], 64)
    for f in ("cosketch_Y", "cosketch_W", "A_acc"):
        np.testing.assert_array_equal(np.asarray(getattr(back, f)),
                                      np.asarray(getattr(state, f)), err_msg=f)


# ---------------------------------------------------------------------------
# Plan/serving integration: refine joins the cache key
# ---------------------------------------------------------------------------

def test_pipeline_refine_joins_cache_key(key):
    """Two plans differing only in RefineSpec compile separately; repeat
    traffic under a pinned refinement never re-traces."""
    from repro.core import pipeline
    A, B = gaussian_pair(key, d=128)
    eng = pipeline.PipelineEngine()
    mk = lambda spec: pipeline.PipelinePlan(
        sketch=pipeline.SketchSpec(k=16, cosketch=4),
        estimation=pipeline.EstimationSpec(method="power", backend="jit"),
        rank=pipeline.RankPolicy(r=2), refine=spec)
    r0 = eng.run(mk(RefineSpec(0, "tropp")), key, A, B)
    assert eng.stats.misses == 1
    eng.run(mk(RefineSpec(2, "power")), key, A, B)
    assert eng.stats.misses == 2                      # distinct executable
    eng.run(mk(RefineSpec(0, "tropp")), key, A, B)
    assert (eng.stats.hits, eng.stats.traces) == (1, 2)   # warm: no re-trace
    assert r0.estimate.factors.U.shape == (11, 2)


def test_service_stream_refined_matches_one_shot(key):
    """stream_factors with a co-sketch-carrying service reproduces one-shot
    flush_factors bit-for-bit under method='power' + refine."""
    from repro.serve.engine import SketchService
    A, B = gaussian_pair(key, d=64)
    svc = SketchService(k=8, backend="scan", block=32, cosketch=3)
    t = svc.submit(key, A, B)
    served = svc.flush_factors(r=2, est_method="power",
                               refine=RefineSpec(1, "power"))[t]
    sid = svc.open_stream(key, 64, 11, 7)
    svc.append(sid, A[:32], B[:32])
    svc.append(sid, A[32:], B[32:])
    est = svc.stream_factors(sid, r=2, est_method="power",
                             refine=RefineSpec(1, "power"))
    np.testing.assert_array_equal(np.asarray(est.factors.U),
                                  np.asarray(served.factors.U))
    np.testing.assert_array_equal(np.asarray(est.factors.V),
                                  np.asarray(served.factors.V))


def test_batched_power_estimation(key):
    """The vmapped service path handles method='power': stacked summaries
    yield stacked refined factors equal to the per-pair runs."""
    keys = jnp.stack([key, jax.random.fold_in(key, 7)])
    A = jax.random.normal(key, (2, 64, 6))
    B = jax.random.normal(jax.random.fold_in(key, 1), (2, 64, 5))
    summary = build_summary(keys, A, B, 8, cosketch=3)
    est = estimation_engine.estimate_product(
        keys, summary, 2, method="power", refine=RefineSpec(1, "power"))
    assert est.factors.U.shape == (2, 6, 2)
    solo = build_summary(keys[1], A[1], B[1], 8, cosketch=3)
    one = estimation_engine.estimate_product(
        keys[1], solo, 2, method="power", refine=RefineSpec(1, "power"))
    np.testing.assert_allclose(np.asarray(est.factors.U[1]),
                               np.asarray(one.factors.U),
                               rtol=2e-5, atol=1e-5)
