"""Golden key-derivation regression tests.

The last PRs promised a bit-for-bit randomness contract: every engine
derives its PRNG keys from the caller's base key through FIXED fold_in/split
trees (documented in docs/architecture.md "Where the randomness lives").
These tests freeze that tree as hard-coded uint32 key data for
``PRNGKey(0)`` — a refactor that silently moves a split or fold_in now fails
here instead of invisibly invalidating every reproducibility claim.

Golden values were recorded from the jax threefry2x32 PRNG (the default;
stable across jax versions by design). Each test ALSO checks the public
entry point consumes the derived key (composition equality), so the goldens
pin behavior, not just documentation.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import core
from repro.core import estimation_engine, summary_engine
from repro.core.error_engine import probe_key, probe_omega

KEY0 = [0, 0]                     # PRNGKey(0) raw key data

# split(PRNGKey(0), 3) — smppca's (k_sketch, k_sample, k_als) layout
SMPPCA_SPLIT3 = [[2467461003, 428148500],
                 [3186719485, 3840466878],
                 [2562233961, 1946702221]]
# fold_in(k_sample, 0) — the key smppca hands to estimate_product
SMPPCA_EST_KEY = [3085582442, 3617870444]
# split(SMPPCA_EST_KEY) — estimation's (sample key, ALS key)
EST_SPLIT2 = [[3818717833, 1612203793], [166711035, 3635324495]]

# fold_in(PRNGKey(0), i) — the per-row gaussian projection keys
ROW_KEYS = {0: [1797259609, 2579123966],
            1: [928981903, 3453687069],
            5: [1524306142, 1887795613]}

# split(PRNGKey(0)) — srht_plan's (sign key, row-sample key); sketch_svd and
# estimate_product share the same single split of their own base key
SPLIT2 = [[4146024105, 967050713], [2718843009, 1272950319]]

# fold_in(PRNGKey(0), 1) — SketchService's per-request estimation key
SERVICE_EST_KEY = [928981903, 3453687069]

# fold_in(fold_in(PRNGKey(0), 0x70726F62), 0x6521) — the ErrorEngine's
# reserved two-level probe fold ("prob", "e!")
PROBE_KEY = [3361526193, 307077598]

# fold_in(PRNGKey(0), 0x77647721) — the reserved window tag fold ("wdw!"),
# and the full two-level window_bucket_key derivation for epochs 0, 1, 5:
# fold_in(WINDOW_TAG_FOLD, epoch)
WINDOW_TAG_FOLD = [2296611242, 153240566]
WINDOW_KEYS = {0: [1127536114, 704093423],
               1: [1755690605, 2856154744],
               5: [1564771073, 3152420000]}

# fold_in(PRNGKey(0), 0x63736B21) — the RefinementEngine's reserved co-sketch
# tag fold ("csk!"), and the second-level test-matrix keys:
# omega = normal(fold_in(tag fold, 0)), psi = normal(fold_in(tag fold, 1))
COSKETCH_TAG_FOLD = [1946431690, 1695170262]
COSKETCH_OMEGA_KEY = [1132837233, 2203595539]
COSKETCH_PSI_KEY = [3222476339, 429157182]

# fold_in(PRNGKey(0), 0x746E7421) — the reserved tenant tag fold ("tnt!"),
# and the full two-level tenant_key derivation for a str and an int tenant:
# fold_in(TENANT_TAG_FOLD, tenant_id) with tenant_id("acme") = crc32 masked
# to uint31 = 96778814 and tenant_id(7) = 7
TENANT_TAG_FOLD = [2274185980, 3446456051]
TENANT_ACME_KEY = [1560486690, 3089195157]
TENANT_7_KEY = [2609152254, 3911254465]


def _eq(got_key, want):
    np.testing.assert_array_equal(np.asarray(got_key, np.uint32),
                                  np.asarray(want, np.uint32))


def test_base_key_layout(key):
    _eq(key, KEY0)
    _eq(jax.random.split(key, 3), SMPPCA_SPLIT3)
    _eq(jax.random.split(key), SPLIT2)


def test_row_projection_key_tree(key):
    """projection_rows row i == normal(fold_in(key, i))/sqrt(k), with the
    fold_in values frozen bit-for-bit."""
    for i, kd in ROW_KEYS.items():
        _eq(jax.random.fold_in(key, i), kd)
        got = summary_engine.projection_rows(key, jnp.array([i]), 8)[0]
        want = jax.random.normal(jnp.asarray(kd, jnp.uint32),
                                 (8,)) / jnp.sqrt(8.0)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_srht_plan_key_tree(key):
    """srht_plan = (rademacher(sign key), choice(row key)) with the single
    split frozen."""
    signs, rows, dp = summary_engine.srht_plan(key, 48, 16)
    k_sign, k_rows = (jnp.asarray(k, jnp.uint32) for k in SPLIT2)
    np.testing.assert_array_equal(
        np.asarray(signs),
        np.asarray(jax.random.rademacher(k_sign, (48,), dtype=jnp.float32)))
    np.testing.assert_array_equal(
        np.asarray(rows),
        np.asarray(jax.random.choice(k_rows, dp, (16,), replace=False)))


def test_smppca_key_tree(key):
    """smppca == build_summary(k_sketch) + estimate_product(fold_in(
    k_sample, 0)) with every derived key frozen."""
    _eq(jax.random.fold_in(jnp.asarray(SMPPCA_SPLIT3[1], jnp.uint32), 0),
        SMPPCA_EST_KEY)
    _eq(jax.random.split(jnp.asarray(SMPPCA_EST_KEY, jnp.uint32)),
        EST_SPLIT2)
    A = jax.random.normal(key, (96, 10))
    B = jax.random.normal(jax.random.fold_in(key, 1), (96, 8))
    res = core.smppca(key, A, B, r=2, k=16, m=200, T=2)
    summary = summary_engine.build_summary(
        jnp.asarray(SMPPCA_SPLIT3[0], jnp.uint32), A, B, 16)
    manual = estimation_engine.estimate_product(
        jnp.asarray(SMPPCA_EST_KEY, jnp.uint32), summary, 2, m=200, T=2)
    np.testing.assert_array_equal(np.asarray(res.factors.U),
                                  np.asarray(manual.factors.U))
    np.testing.assert_array_equal(np.asarray(res.samples.rows),
                                  np.asarray(manual.samples.rows))


def test_lela_key_tree(key):
    """lela passes the caller key straight to estimate_product (whose single
    split is frozen above): composition equality, no hidden folds."""
    A = jax.random.normal(key, (96, 10))
    B = jax.random.normal(jax.random.fold_in(key, 1), (96, 8))
    got = core.lela(key, A, B, r=2, m=200, T=2)
    manual = estimation_engine.estimate_product(
        key, core.norms_only_summary(A, B), 2, method="lela_waltmin",
        m=200, T=2, exact_pair=(A, B))
    np.testing.assert_array_equal(np.asarray(got.U),
                                  np.asarray(manual.factors.U))


def test_sketch_svd_key_tree(key):
    """sketch_svd == build_summary(split[0]) + direct_svd(split[1]) with the
    split frozen."""
    A = jax.random.normal(key, (96, 10))
    B = jax.random.normal(jax.random.fold_in(key, 1), (96, 8))
    got = core.sketch_svd(key, A, B, r=2, k=16)
    k_sketch, k_pow = (jnp.asarray(k, jnp.uint32) for k in SPLIT2)
    summary = summary_engine.build_summary(k_sketch, A, B, 16)
    manual = estimation_engine.estimate_product(
        k_pow, summary, 2, method="direct_svd")
    np.testing.assert_array_equal(np.asarray(got.U),
                                  np.asarray(manual.factors.U))


def test_sketch_service_key_tree(key):
    """flush_factors derives each request's estimation key as
    fold_in(request key, 1) — frozen and observable through the service."""
    from repro.serve.engine import SketchService
    _eq(jax.random.fold_in(key, 1), SERVICE_EST_KEY)
    A = jax.random.normal(key, (64, 6))
    B = jax.random.normal(jax.random.fold_in(key, 2), (64, 5))
    svc = SketchService(k=8, backend="scan", block=32)
    ticket = svc.submit(key, A, B)
    served = svc.flush_factors(r=2, m=100, T=2)[ticket]
    summary = summary_engine.build_summary(key, A, B, 8, backend="scan",
                                           block=32)
    manual = estimation_engine.estimate_product(
        jnp.asarray(SERVICE_EST_KEY, jnp.uint32), summary, 2, m=100, T=2)
    np.testing.assert_array_equal(np.asarray(served.factors.U),
                                  np.asarray(manual.factors.U))


def test_pipeline_plan_key_tree(key):
    """The plan-compiled path consumes exactly the frozen key tree: the
    smppca/sketch_svd presets and the service layout, executed through a
    PipelineEngine's fused executables, reproduce the stage-by-stage
    compositions built from the golden key literals bit-for-bit."""
    from repro.core import pipeline
    A = jax.random.normal(key, (96, 10))
    B = jax.random.normal(jax.random.fold_in(key, 1), (96, 8))
    eng = pipeline.PipelineEngine()

    # smppca preset: sketch key = split3[0], estimation key = fold(split3[1])
    res = eng.run(pipeline.smppca_plan(r=2, k=16, m=200, T=2), key, A, B)
    summary = summary_engine.build_summary(
        jnp.asarray(SMPPCA_SPLIT3[0], jnp.uint32), A, B, 16)
    manual = estimation_engine.estimate_product(
        jnp.asarray(SMPPCA_EST_KEY, jnp.uint32), summary, 2, m=200, T=2)
    np.testing.assert_array_equal(np.asarray(res.estimate.factors.U),
                                  np.asarray(manual.factors.U))

    # sketch_svd preset: (sketch key, power key) = the single split
    res = eng.run(pipeline.sketch_svd_plan(r=2, k=16), key, A, B)
    k_sketch, k_pow = (jnp.asarray(k, jnp.uint32) for k in SPLIT2)
    summary = summary_engine.build_summary(k_sketch, A, B, 16)
    manual = estimation_engine.estimate_product(
        k_pow, summary, 2, method="direct_svd")
    np.testing.assert_array_equal(np.asarray(res.estimate.factors.U),
                                  np.asarray(manual.factors.U))

    # service layout from a summary (the stream_factors spine): estimation
    # key = fold_in(key, 1), frozen as SERVICE_EST_KEY
    summary = summary_engine.build_summary(key, A, B, 16)
    plan = pipeline.PipelinePlan(
        sketch=pipeline.SketchSpec(k=16),
        estimation=pipeline.EstimationSpec(m=200, T=2),
        rank=pipeline.RankPolicy(r=2), key_layout="service")
    est = eng.run_from_summary(plan, key, summary)
    manual = estimation_engine.estimate_product(
        jnp.asarray(SERVICE_EST_KEY, jnp.uint32), summary, 2, m=200, T=2)
    np.testing.assert_array_equal(np.asarray(est.factors.U),
                                  np.asarray(manual.factors.U))

    # the derivation helper itself is pinned to the same literals
    _eq(pipeline.derive_keys("service", key)[1], SERVICE_EST_KEY)
    _eq(pipeline.derive_keys("smppca", key)[0], SMPPCA_SPLIT3[0])
    _eq(pipeline.derive_keys("smppca", key)[1], SMPPCA_EST_KEY)
    _eq(pipeline.derive_keys("sketch_svd", key)[0], SPLIT2[0])
    _eq(pipeline.derive_keys("sketch_svd", key)[1], SPLIT2[1])
    _eq(pipeline.derive_keys("direct", key)[1], KEY0)


def test_tenant_key_tree(key):
    """The multi-tenant namespacing fold is frozen: tenant_key is the
    reserved two-level ``fold_in(fold_in(key, 0x746E7421), tenant_id)``,
    tenant ids are canonical (ints pass through, strs crc32-masked), and
    ``derive_keys(tenant=...)`` applies the fold BEFORE the layout fan-out
    while ``tenant=None`` leaves every historical derivation untouched."""
    from repro.core import pipeline
    _eq(jax.random.fold_in(key, 0x746E7421), TENANT_TAG_FOLD)
    assert pipeline.tenant_id("acme") == 96778814
    assert pipeline.tenant_id(7) == 7
    _eq(pipeline.tenant_key(key, "acme"), TENANT_ACME_KEY)
    _eq(pipeline.tenant_key(key, 96778814), TENANT_ACME_KEY)   # id == str
    _eq(pipeline.tenant_key(key, 7), TENANT_7_KEY)

    # the fold namespaces BEFORE the layout fan-out: deriving under a tenant
    # == deriving from the folded key, for every layout
    acme = jnp.asarray(TENANT_ACME_KEY, jnp.uint32)
    for layout in ("service", "smppca", "sketch_svd", "direct"):
        got = pipeline.derive_keys(layout, key, tenant="acme")
        want = pipeline.derive_keys(layout, acme)
        _eq(got[0], np.asarray(want[0], np.uint32))
        _eq(got[1], np.asarray(want[1], np.uint32))
    # tenant=None is bit-identical to the pre-tenant derivation
    _eq(pipeline.derive_keys("service", key, tenant=None)[1],
        SERVICE_EST_KEY)

    # batched mode folds each stacked key independently
    stack = jnp.stack([key, jax.random.fold_in(key, 3)])
    got = pipeline.derive_keys("service", stack, batched=True,
                               tenant="acme")[0]
    _eq(got[0], TENANT_ACME_KEY)

    # invalid tenant handles are rejected, not silently hashed
    import pytest
    for bad in (True, 3.5, None, -1, 2 ** 31):
        with pytest.raises((TypeError, ValueError)):
            pipeline.tenant_id(bad)


def test_window_bucket_key_tree(key):
    """The sliding window's per-epoch bucket keys are frozen: the reserved
    two-level ``fold_in(fold_in(key, 0x77647721), epoch)`` fold ("wdw!"),
    and a WindowedSummarizer bucket's carried key and sketch contents are
    exactly those of a plain summarizer initialized at the golden key —
    while the probe test matrix stays the BASE key's (probe blocks only
    merge across buckets against a shared omega)."""
    from repro.core.streaming import (
        StreamingSummarizer, WindowedSummarizer, window_bucket_key)
    _eq(jax.random.fold_in(key, 0x77647721), WINDOW_TAG_FOLD)
    for epoch, kd in WINDOW_KEYS.items():
        _eq(window_bucket_key(key, epoch), kd)

    win = WindowedSummarizer(8, 2, probes=3)
    w = win.init(key, (64, 6, 4))
    A = jax.random.normal(key, (64, 6))
    B = jax.random.normal(jax.random.fold_in(key, 2), (64, 4))
    w = win.slide(w, 4)                       # head: 1 -> 5
    w = win.update(w, A, B, 0)                # rows land in epoch 5's bucket
    bucket = w.buckets[5 % 2]
    _eq(bucket.key, WINDOW_KEYS[5])
    manual = StreamingSummarizer(8, probes=3)
    ref = manual.init(jnp.asarray(WINDOW_KEYS[5], jnp.uint32), (64, 6, 4))
    ref = ref._replace(omega=probe_omega(key, 4, 3))   # the shared base omega
    ref = manual.update(ref, A, B, 0)
    np.testing.assert_array_equal(np.asarray(bucket.A_acc),
                                  np.asarray(ref.A_acc))
    np.testing.assert_array_equal(np.asarray(bucket.probe_acc),
                                  np.asarray(ref.probe_acc))
    np.testing.assert_array_equal(np.asarray(bucket.omega),
                                  np.asarray(probe_omega(key, 4, 3)))


def test_cosketch_key_tree(key):
    """The refinement co-sketch block's reserved two-level fold is frozen
    ("csk!" then sub-index 0/1 for omega/psi), and build_summary's retained
    test matrices are drawn from exactly those keys — so a co-sketch built
    during serving is bit-reproducible from the caller's base key alone."""
    from repro.core.refinement import (
        cosketch_key, cosketch_omega, cosketch_psi, cosketch_width)
    _eq(cosketch_key(key), COSKETCH_TAG_FOLD)
    _eq(jax.random.fold_in(key, 0x63736B21), COSKETCH_TAG_FOLD)
    tag = jnp.asarray(COSKETCH_TAG_FOLD, jnp.uint32)
    _eq(jax.random.fold_in(tag, 0), COSKETCH_OMEGA_KEY)
    _eq(jax.random.fold_in(tag, 1), COSKETCH_PSI_KEY)

    A = jax.random.normal(key, (64, 6))
    B = jax.random.normal(jax.random.fold_in(key, 2), (64, 5))
    s = summary_engine.build_summary(key, A, B, 8, cosketch=3)
    want_omega = jax.random.normal(
        jnp.asarray(COSKETCH_OMEGA_KEY, jnp.uint32), (5, 3))
    want_psi = jax.random.normal(
        jnp.asarray(COSKETCH_PSI_KEY, jnp.uint32), (cosketch_width(3), 6))
    np.testing.assert_array_equal(np.asarray(s.cosketch_omega),
                                  np.asarray(want_omega))
    np.testing.assert_array_equal(np.asarray(s.cosketch_psi),
                                  np.asarray(want_psi))
    np.testing.assert_array_equal(np.asarray(cosketch_omega(key, 5, 3)),
                                  np.asarray(s.cosketch_omega))
    np.testing.assert_array_equal(np.asarray(cosketch_psi(key, 6, 3)),
                                  np.asarray(s.cosketch_psi))


def test_probe_key_tree(key):
    """The ErrorEngine's reserved two-level probe fold is frozen, and
    build_summary's retained probe_omega is drawn from exactly that key."""
    _eq(probe_key(key), PROBE_KEY)
    _eq(jax.random.fold_in(key, 0x70726F62),
        np.asarray([3608120998, 148634447], np.uint32))
    A = jax.random.normal(key, (64, 6))
    B = jax.random.normal(jax.random.fold_in(key, 2), (64, 5))
    s = summary_engine.build_summary(key, A, B, 8, probes=4)
    np.testing.assert_array_equal(
        np.asarray(s.probe_omega),
        np.asarray(jax.random.normal(jnp.asarray(PROBE_KEY, jnp.uint32),
                                     (5, 4))))
    np.testing.assert_array_equal(np.asarray(probe_omega(key, 5, 4)),
                                  np.asarray(s.probe_omega))
