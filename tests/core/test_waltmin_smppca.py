"""Step-3 + end-to-end tests: WAltMin completion and Algorithm 1."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # hypothesis is optional; see tests/_hyp.py
    from tests._hyp import given, settings, strategies as st

from repro import core
from repro.core.types import SampleSet
from repro.core.waltmin import waltmin
from tests.conftest import planted_pair


def _full_sample(n1, n2):
    ii, jj = jnp.meshgrid(jnp.arange(n1), jnp.arange(n2), indexing="ij")
    return SampleSet(ii.reshape(-1).astype(jnp.int32),
                     jj.reshape(-1).astype(jnp.int32),
                     jnp.ones(n1 * n2), jnp.ones(n1 * n2, bool))


def test_waltmin_exact_rank_r_full_observation(key):
    n, r = 80, 4
    kU, kV = jax.random.split(key)
    M = jax.random.normal(kU, (n, r)) @ jax.random.normal(kV, (n, r)).T
    f = waltmin(key, _full_sample(n, n), M.reshape(-1), n, n, r, 4,
                use_splits=False)
    err = float(jnp.linalg.norm(M - f.U @ f.V.T) / jnp.linalg.norm(M))
    assert err < 5e-4, err


def test_waltmin_exact_rank_r_subsampled(key):
    """Exact rank-r matrix from ~35% of uniformly sampled entries."""
    n, r = 100, 3
    kU, kV, ks = jax.random.split(key, 3)
    M = jax.random.normal(kU, (n, r)) @ jax.random.normal(kV, (n, r)).T
    m = int(0.35 * n * n)
    rows = jax.random.randint(ks, (m,), 0, n).astype(jnp.int32)
    cols = jax.random.randint(jax.random.fold_in(ks, 1), (m,), 0, n).astype(jnp.int32)
    q = jnp.full((m,), 0.35)
    ss = SampleSet(rows, cols, q, jnp.ones(m, bool))
    vals = M[rows, cols]
    f = waltmin(key, ss, vals, n, n, r, 10, use_splits=False)
    err = float(jnp.linalg.norm(M - f.U @ f.V.T) / jnp.linalg.norm(M))
    assert err < 1e-2, err


def test_waltmin_splits_mode_bounded(key):
    """Alg-2 sample splitting at small scale is out of its Eq-(5) regime; we
    assert the damped solver stays bounded (no NaN/inf blowup) and T<=2 works."""
    n, r = 100, 3
    kU, kV = jax.random.split(key)
    M = jax.random.normal(kU, (n, r)) @ jax.random.normal(kV, (n, r)).T
    f = waltmin(key, _full_sample(n, n), M.reshape(-1), n, n, r, 2,
                use_splits=True)
    rel = float(jnp.linalg.norm(M - f.U @ f.V.T) / jnp.linalg.norm(M))
    assert np.isfinite(rel) and rel < 0.5, rel


def test_coo_topr_svd_matches_dense(key):
    n1, n2, r = 60, 50, 5
    M = jax.random.normal(key, (n1, n2))
    ii, jj = jnp.meshgrid(jnp.arange(n1), jnp.arange(n2), indexing="ij")
    U, s, V = core.coo_topr_svd(key, ii.reshape(-1), jj.reshape(-1),
                                M.reshape(-1), n1, n2, r)
    s_true = jnp.linalg.svd(M, compute_uv=False)[:r]
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_true), rtol=1e-3)


# ---------------------------------------------------------------------------
# End-to-end SMP-PCA (paper-claim regressions live in benchmarks too)
# ---------------------------------------------------------------------------

def _m(n, r):
    return int(10 * n * r * np.log(n))


def test_smppca_recovers_correlated_product(key):
    d, n, r = 2000, 200, 5
    A, B = planted_pair(key, d, n, corr=0.3)
    res = core.smppca(key, A, B, r=r, k=512, m=_m(n, r), T=8)
    err, opt = core.spectral_error_vs_optimal(A, B, r, res.factors)
    assert float(err) < 3.0 * float(opt) + 0.05, (float(err), float(opt))


def test_smppca_error_decreases_with_k(key):
    d, n, r = 1500, 150, 5
    A, B = planted_pair(key, d, n, corr=0.3)
    errs = []
    for k in [32, 128, 1024]:
        res = core.smppca(key, A, B, r=r, k=k, m=_m(n, r), T=8)
        e, _ = core.spectral_error_vs_optimal(A, B, r, res.factors)
        errs.append(float(e))
    assert errs[2] < errs[0], errs  # Thm 3.1: eta ~ 1/sqrt(k)


def test_smppca_beats_sketch_svd(key):
    """The paper's headline comparison (Figs 2b, 3b, 4b)."""
    d, n, r = 2000, 150, 5
    A, B = planted_pair(key, d, n, corr=0.2)  # narrow cone
    k = 128
    res = core.smppca(key, A, B, r=r, k=k, m=_m(n, r), T=8)
    e_smp, _ = core.spectral_error_vs_optimal(A, B, r, res.factors)
    sf = core.sketch_svd(key, A, B, r=r, k=k)
    e_svd, _ = core.spectral_error_vs_optimal(A, B, r, sf)
    assert float(e_smp) < float(e_svd), (float(e_smp), float(e_svd))


def test_lela_approaches_optimal(key):
    d, n, r = 1500, 150, 5
    A, B = planted_pair(key, d, n)
    f = core.lela(key, A, B, r=r, m=_m(n, r), T=8)
    err, opt = core.spectral_error_vs_optimal(A, B, r, f)
    assert float(err) < 1.5 * float(opt) + 0.02


def test_pca_special_case_a_equals_b(key):
    """Remark 3: A=B gives single-pass PCA of A^T A."""
    d, n, r = 1500, 100, 4
    A, _ = planted_pair(key, d, n)
    res = core.smppca(key, A, A, r=r, k=768, m=_m(n, r), T=8)
    err, opt = core.spectral_error_vs_optimal(A, A, r, res.factors)
    assert float(err) < 3.0 * float(opt) + 0.05


def test_product_of_pcas_fails_on_orthogonal_subspaces(key):
    """Fig 4(c): A_r^T B_r is a poor approximation when top subspaces of A
    and B are orthogonal, while SMP-PCA is not."""
    d, n, r = 600, 60, 3
    kq, kn = jax.random.split(key)
    # Q1 (A's top), Q2 (B's top), Qs (shared lower directions), all orthogonal
    Q, _ = jnp.linalg.qr(jax.random.normal(kq, (d, 3 * r)))
    Q1, Q2, Qs = Q[:, :r], Q[:, r:2 * r], Q[:, 2 * r:]
    CA = jax.random.normal(jax.random.fold_in(key, 1), (r, n))
    CB = jax.random.normal(jax.random.fold_in(key, 2), (r, n))
    SA = jax.random.normal(jax.random.fold_in(key, 3), (r, n))
    SB = jax.random.normal(jax.random.fold_in(key, 4), (r, n))
    noise = 0.02 * jax.random.normal(kn, (d, 2 * n))
    A = 3.0 * Q1 @ CA + Qs @ SA + noise[:, :n]
    B = 3.0 * Q2 @ CB + Qs @ SB + noise[:, n:]
    # per-matrix top-r spaces are Q1 vs Q2 (orthogonal) -> A_r^T B_r ~ 0,
    # while A^T B ~ SA^T SB (rank r) carried by the *shared lower* directions
    f_pp = core.product_of_pcas(key, A, B, r)
    e_pp, _ = core.spectral_error_vs_optimal(A, B, r, f_pp)
    res = core.smppca(key, A, B, r=r, k=512, m=_m(n, r), T=8)
    e_smp, _ = core.spectral_error_vs_optimal(A, B, r, res.factors)
    assert float(e_pp) > 0.5
    assert float(e_smp) < float(e_pp)


def test_smppca_streaming_summary_entry_point(key):
    """smppca_from_summary == smppca when fed the same summary."""
    d, n, r = 800, 80, 3
    A, B = planted_pair(key, d, n, corr=0.3)
    m = _m(n, r)
    res1 = core.smppca(key, A, B, r=r, k=256, m=m, T=6)
    err1, _ = core.spectral_error_vs_optimal(A, B, r, res1.factors)
    assert float(err1) < 1.0


@settings(deadline=None, max_examples=6)
@given(n=st.sampled_from([40, 70]), r=st.integers(1, 4),
       seed=st.integers(0, 2**31 - 1))
def test_property_waltmin_completes_exact_lowrank(n, r, seed):
    """Property: any exact rank-r matrix is completed from full observation."""
    kk = jax.random.PRNGKey(seed)
    kU, kV = jax.random.split(kk)
    M = jax.random.normal(kU, (n, r)) @ jax.random.normal(kV, (n, r)).T
    ii, jj = jnp.meshgrid(jnp.arange(n), jnp.arange(n), indexing="ij")
    ss = SampleSet(ii.reshape(-1).astype(jnp.int32),
                   jj.reshape(-1).astype(jnp.int32),
                   jnp.ones(n * n), jnp.ones(n * n, bool))
    f = waltmin(kk, ss, M.reshape(-1), n, n, r, 3, use_splits=False)
    err = float(jnp.linalg.norm(M - f.U @ f.V.T) / jnp.linalg.norm(M))
    assert err < 1e-3, err


@settings(deadline=None, max_examples=6)
@given(seed=st.integers(0, 2**31 - 1))
def test_property_masked_padding_is_ignored(seed):
    """Padding entries (mask=False) must not affect the completion."""
    kk = jax.random.PRNGKey(seed)
    n, r = 50, 2
    kU, kV = jax.random.split(kk)
    M = jax.random.normal(kU, (n, r)) @ jax.random.normal(kV, (n, r)).T
    ii, jj = jnp.meshgrid(jnp.arange(n), jnp.arange(n), indexing="ij")
    rows = ii.reshape(-1).astype(jnp.int32)
    cols = jj.reshape(-1).astype(jnp.int32)
    vals = M.reshape(-1)
    ss1 = SampleSet(rows, cols, jnp.ones(n * n), jnp.ones(n * n, bool))
    # append garbage padding
    pad = 64
    ss2 = SampleSet(jnp.concatenate([rows, jnp.zeros(pad, jnp.int32)]),
                    jnp.concatenate([cols, jnp.zeros(pad, jnp.int32)]),
                    jnp.concatenate([jnp.ones(n * n), jnp.full((pad,), 0.5)]),
                    jnp.concatenate([jnp.ones(n * n, bool), jnp.zeros(pad, bool)]))
    vals2 = jnp.concatenate([vals, jnp.full((pad,), 1e6)])
    f1 = waltmin(kk, ss1, vals, n, n, r, 3, use_splits=False)
    f2 = waltmin(kk, ss2, vals2, n, n, r, 3, use_splits=False)
    np.testing.assert_allclose(np.asarray(f1.U @ f1.V.T),
                               np.asarray(f2.U @ f2.V.T), rtol=1e-3, atol=1e-3)
