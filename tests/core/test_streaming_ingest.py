"""Double-buffered ingest + the compressed wire format.

Property-tests the PR's two streaming contracts:

* **Ingest bit-parity** — ``StreamingSummarizer.ingest`` (any prefetch
  depth, plain or windowed, via the service's ``append_async``) produces
  the bit-identical state to the synchronous ``update`` loop: pipelining
  changes *when* chunks are staged, never *what* is accumulated.
* **Compression laws** — ``decompress(compress(s))`` at f32 is
  bit-identical to the settled state (structure included); norm and probe
  blocks round-trip bit-exactly at EVERY precision; quantized merge error
  stays within the probe-measured ``wire_error`` bound; ``wire_pack`` /
  ``wire_unpack`` round-trips every leaf; compressed checkpoints restore
  through the same laws.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    from tests._hyp import given, settings
    from tests._hyp import strategies as st

from repro.core import streaming
from repro.core.streaming import (
    StreamingSummarizer, WindowedSummarizer, WireSpec, choose_wire_spec,
    compress_state, decompress_state, tree_merge, wire_bytes, wire_error,
    wire_pack, wire_unpack)
from repro.ckpt import checkpoint

_KEY = jax.random.PRNGKey(42)
_D, _NA, _NB = 96, 9, 7


def _pair(key=_KEY, d=_D):
    kA, kB = jax.random.split(key)
    return (jax.random.normal(kA, (d, _NA)), jax.random.normal(kB, (d, _NB)))


def _stream_state(*, probes=4, cosketch=0, decay=1.0, method="gaussian",
                  d=_D):
    summ = StreamingSummarizer(8, method=method, probes=probes,
                               cosketch=cosketch, decay=decay)
    A, B = _pair(d=d)
    st = summ.init(_KEY, (d, _NA, _NB))
    st = summ.update(st, A, B, 0)
    return summ, st


def _assert_tree_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    assert (jax.tree_util.tree_structure(a)
            == jax.tree_util.tree_structure(b))
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        assert x.shape == y.shape and x.dtype == y.dtype
        np.testing.assert_array_equal(x, y)


# ---------------------------------------------------------------------------
# ingest bit-parity


@settings(deadline=None, max_examples=8)
@given(prefetch=st.sampled_from([0, 1, 2, 4]),
       chunk=st.sampled_from([16, 32, 96]))
def test_ingest_bit_parity_with_update_loop(prefetch, chunk):
    summ = StreamingSummarizer(8, probes=4, cosketch=4)
    A, B = _pair()
    ref = summ.init(_KEY, (_D, _NA, _NB))
    for off in range(0, _D, chunk):
        ref = summ.update(ref, A[off:off + chunk], B[off:off + chunk], off)
    got = summ.ingest(
        summ.init(_KEY, (_D, _NA, _NB)),
        ((A[off:off + chunk], B[off:off + chunk])
         for off in range(0, _D, chunk)),
        prefetch=prefetch)
    _assert_tree_equal(got, ref)


def test_ingest_resumes_from_row_high():
    summ = StreamingSummarizer(8)
    A, B = _pair()
    ref = summ.init(_KEY, (_D, _NA, _NB))
    ref = summ.update(ref, A[:32], B[:32], 0)
    ref = summ.update(ref, A[32:64], B[32:64], 32)
    got = summ.ingest(summ.init(_KEY, (_D, _NA, _NB)), [(A[:32], B[:32])])
    got = summ.ingest(got, [(A[32:64], B[32:64])])   # offset = row_high
    _assert_tree_equal(got, ref)


def test_ingest_rejects_bad_prefetch():
    summ = StreamingSummarizer(8)
    st = summ.init(_KEY, (_D, _NA, _NB))
    for bad in (-1, True, 1.5):
        with pytest.raises(ValueError):
            summ.ingest(st, [], prefetch=bad)


def test_windowed_ingest_matches_head_bucket_updates():
    ws = WindowedSummarizer(8, n_buckets=2, probes=4)
    A, B = _pair()
    ref = ws.init(_KEY, (_D, _NA, _NB))
    for off in range(0, 64, 32):
        ref = ws.update(ref, A[off:off + 32], B[off:off + 32], off)
    got = ws.ingest(ws.init(_KEY, (_D, _NA, _NB)),
                    ((A[off:off + 32], B[off:off + 32])
                     for off in range(0, 64, 32)),
                    row_offset=0)
    _assert_tree_equal(got, ref)


def test_service_append_async_matches_append(key):
    from repro.serve.engine import SketchService
    A, B = _pair()
    ref_svc = SketchService(k=8, probes=4)
    ref_sid = ref_svc.open_stream(key, _D, _NA, _NB)
    got_svc = SketchService(k=8, probes=4)
    got_sid = got_svc.open_stream(key, _D, _NA, _NB)
    for off in range(0, _D, 32):
        ref_svc.append(ref_sid, A[off:off + 32], B[off:off + 32])
    n = got_svc.append_async(
        got_sid, ((A[off:off + 32], B[off:off + 32])
                  for off in range(0, _D, 32)))
    assert n == _D
    _assert_tree_equal(got_svc._streams[got_sid].state,
                       ref_svc._streams[ref_sid].state)


# ---------------------------------------------------------------------------
# compression laws


@settings(deadline=None, max_examples=8)
@given(cosketch=st.sampled_from([0, 4]),
       decay=st.sampled_from([1.0, 0.95]),
       method=st.sampled_from(["gaussian", "srht"]))
def test_f32_round_trip_is_bit_identical(cosketch, decay, method):
    _, st = _stream_state(cosketch=cosketch, decay=decay, method=method)
    settled = streaming._settle_state(st)
    back = decompress_state(compress_state(st, "f32"))
    _assert_tree_equal(back, settled)


@settings(deadline=None, max_examples=6)
@given(spec=st.sampled_from(["f32", "bf16", "int8"]),
       cosketch=st.sampled_from([0, 4]))
def test_norm_and_probe_blocks_bit_exact_at_every_precision(spec, cosketch):
    _, st = _stream_state(cosketch=cosketch)
    back = decompress_state(compress_state(st, spec))
    np.testing.assert_array_equal(np.asarray(back.na2), np.asarray(st.na2))
    np.testing.assert_array_equal(np.asarray(back.nb2), np.asarray(st.nb2))
    np.testing.assert_array_equal(np.asarray(back.probe_acc),
                                  np.asarray(st.probe_acc))
    # key-derived randomness is regenerated, not shipped
    np.testing.assert_array_equal(np.asarray(back.omega),
                                  np.asarray(st.omega))
    assert int(back.rows_seen) == int(st.rows_seen)


@settings(deadline=None, max_examples=6)
@given(spec=st.sampled_from(["f32", "bf16", "int8"]))
def test_wire_pack_round_trips_every_leaf(spec):
    _, st = _stream_state(cosketch=4, decay=0.95)
    comp = compress_state(st, spec)
    back = wire_unpack(wire_pack(comp))
    _assert_tree_equal(back, comp)
    assert wire_bytes(back) == wire_bytes(comp)


def test_wire_bytes_ordering_and_spec_bits():
    _, st = _stream_state(cosketch=4)
    sizes = {s: wire_bytes(compress_state(st, s))
             for s in streaming.WIRE_DTYPES}
    assert sizes["f32"] > sizes["bf16"] > sizes["int8"]
    assert WireSpec("f32").bits == 32 and WireSpec("int8").bits == 8
    with pytest.raises(ValueError):
        compress_state(st, "f16")


@settings(deadline=None, max_examples=6)
@given(spec=st.sampled_from(["bf16", "int8"]),
       split=st.sampled_from([32, 48, 64]))
def test_quantized_merge_error_within_probe_bound(spec, split):
    """Merging two quantized-wire partials stays within the sum of their
    probe-measured wire errors (each round-trip adds its own measured
    error; merge is linear)."""
    summ = StreamingSummarizer(8, probes=4)
    A, B = _pair()
    parts, errs = [], []
    for lo, hi in ((0, split), (split, _D)):
        st = summ.init(_KEY, (_D, _NA, _NB))
        st = summ.update(st, A[lo:hi], B[lo:hi], lo)
        errs.append(wire_error(st, spec))
        parts.append(decompress_state(compress_state(st, spec)))
    merged = tree_merge(parts)

    exact = summ.init(_KEY, (_D, _NA, _NB))
    exact = summ.update(exact, A, B, 0)

    # measure the merged deviation the same way wire_error does: through
    # the probe sketches, normalized by the exact probe norms
    w = np.asarray(exact.omega)
    dev = (np.asarray(merged.A_acc).T @ (np.asarray(merged.B_acc) @ w)
           - np.asarray(exact.A_acc).T @ (np.asarray(exact.B_acc) @ w))
    ref = np.asarray(exact.probe_acc)
    rel = np.sqrt((dev ** 2).sum() / (ref ** 2).sum())
    assert rel <= 2.0 * (sum(errs) + 1e-6), (spec, rel, errs)


def test_wire_error_f32_is_zero_and_gate_is_total():
    _, st = _stream_state()
    assert wire_error(st, "f32") == 0.0
    spec, err = choose_wire_spec(st, tol=0.05)
    assert spec.sketch in streaming.WIRE_DTYPES and err <= 0.05
    # a tolerance no lossy spec can meet lands on lossless f32
    spec, err = choose_wire_spec(st, tol=1e-12)
    assert spec == WireSpec("f32") and err == 0.0
    # the quantized-only candidate list still falls back to f32
    spec, err = choose_wire_spec(st, tol=1e-12, specs=("int8", "bf16"))
    assert spec == WireSpec("f32") and err == 0.0
    with pytest.raises(ValueError):
        choose_wire_spec(st, tol=0.0)
    # no probes -> the gate has nothing to measure
    summ = StreamingSummarizer(8)
    bare = summ.init(_KEY, (_D, _NA, _NB))
    with pytest.raises(ValueError):
        wire_error(bare, "bf16")


def test_compress_requires_key():
    _, st = _stream_state()
    with pytest.raises(ValueError, match="key"):
        compress_state(st._replace(key=None), "f32")


# ---------------------------------------------------------------------------
# compressed checkpoints


@settings(deadline=None, max_examples=4)
@given(spec=st.sampled_from(["f32", "bf16"]))
def test_compressed_checkpoint_round_trip(spec):
    import tempfile
    summ, st = _stream_state(cosketch=4, decay=0.95)
    with tempfile.TemporaryDirectory() as d:
        checkpoint.save_stream_state(d, 3, st, wire=spec)
        man = checkpoint.read_manifest(d)
        assert man["extra"]["wire"]["spec"] == spec
        assert man["extra"]["wire"]["bytes"] == wire_bytes(
            compress_state(st, spec))
        back = checkpoint.restore_stream_state(
            d, summ.init(_KEY, (_D, _NA, _NB)))
        if spec == "f32":
            _assert_tree_equal(back, streaming._settle_state(st))
        else:
            np.testing.assert_array_equal(np.asarray(back.na2),
                                          np.asarray(st.na2))


def test_gated_checkpoint_records_measured_error():
    import tempfile
    summ, st = _stream_state()
    with tempfile.TemporaryDirectory() as d:
        checkpoint.save_stream_state(d, 1, st, tol=0.05)
        wire = checkpoint.read_manifest(d)["extra"]["wire"]
        assert wire["spec"] in streaming.WIRE_DTYPES
        assert 0.0 <= wire["error"] <= 0.05
        back = checkpoint.restore_stream_state(
            d, summ.init(_KEY, (_D, _NA, _NB)))
        assert int(back.rows_seen) == _D


def test_plain_checkpoint_path_unchanged():
    import tempfile
    summ, st = _stream_state(cosketch=4)
    with tempfile.TemporaryDirectory() as d:
        checkpoint.save_stream_state(d, 1, st)
        assert "wire" not in checkpoint.read_manifest(d)["extra"]
        back = checkpoint.restore_stream_state(
            d, summ.init(_KEY, (_D, _NA, _NB)))
        _assert_tree_equal(back, st)
