"""Per-arch smoke tests (reduced configs) + prefill/decode consistency.

Each assigned architecture instantiates a REDUCED same-family config and runs
one forward/train step on CPU asserting output shapes + finiteness, plus the
serving-path equivalence: prefill + step-by-step decode must match the
parallel full-sequence forward (f32 for MoE archs — bf16 router tie-flips
legitimately reroute tokens; verified exact in f32)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import build, transformer

ARCHS = list(list_archs())


def _batch(cfg, B, S, key):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.is_encdec:
        batch["enc_frames"] = 0.1 * jax.random.normal(
            key, (B, cfg.enc_context, cfg.d_model)).astype(jnp.bfloat16)
    if cfg.n_img_tokens:
        batch["img_embeds"] = 0.1 * jax.random.normal(
            key, (B, cfg.n_img_tokens, cfg.d_model)).astype(jnp.bfloat16)
    return batch


@pytest.mark.parametrize("name", ARCHS)
def test_smoke_forward_and_train_step(name):
    cfg = get_config(name).reduced()
    m = build(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init_params(key)
    B, S = 2, 64
    batch = _batch(cfg, B, S, key)
    loss, grads = jax.jit(jax.value_and_grad(m.loss))(params, batch)
    assert jnp.isfinite(loss), (name, loss)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert jnp.isfinite(gnorm) and float(gnorm) > 0, name


@pytest.mark.parametrize("name", ARCHS)
def test_prefill_decode_matches_forward(name):
    cfg = get_config(name).reduced()
    if cfg.n_experts:   # see module docstring
        cfg = dataclasses.replace(cfg, compute_dtype="float32",
                                  capacity_factor=8.0)
    m = build(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init_params(key)
    B, S = 2, 32
    batch = _batch(cfg, B, S, key)
    ctx = {"positions": jnp.arange(S),
           "xattn_ctx": transformer._xattn_context(params, cfg, batch)}
    x = transformer._embed_tokens(params, cfg, batch["tokens"])
    x, _, _ = transformer._backbone(params, cfg, x, ctx, mode="seq")
    full_logits = transformer._logits(params, cfg, x)

    P = S // 2
    cache = m.init_cache(B, S)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :P]
    lg, cache = jax.jit(m.prefill)(params, pre, cache)
    errs = [float(jnp.abs(lg[:, 0] - full_logits[:, P - 1]).max())]
    dstep = jax.jit(m.decode_step)
    for t in range(P, S):
        lg, cache = dstep(params, cache, batch["tokens"][:, t:t + 1],
                          jnp.int32(t))
        errs.append(float(jnp.abs(lg[:, 0] - full_logits[:, t]).max()))
    tol = 1e-3 if cfg.compute_dtype == "float32" else 0.15
    assert max(errs) < tol, (name, errs)


@pytest.mark.parametrize("name", ARCHS)
def test_param_count_matches_analytic(name):
    """init'd parameter count == ArchConfig.n_params() on the reduced config
    (validates both the analytic MODEL_FLOPS bookkeeping and the init)."""
    cfg = get_config(name).reduced()
    m = build(cfg)
    shapes = m.param_shapes()
    total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
    analytic = cfg.n_params()
    # analytic formula omits norm scales / small biases / gates: allow 5%
    assert abs(total - analytic) / analytic < 0.08, (name, total, analytic)


def test_moe_capacity_drops_are_only_divergence():
    """bf16 MoE decode==forward when routing is forced deterministic (f32)."""
    cfg = dataclasses.replace(get_config("moonshot-v1-16b-a3b").reduced(),
                              compute_dtype="float32", capacity_factor=8.0)
    m = build(cfg)
    params = m.init_params(jax.random.PRNGKey(1))
    B, S = 2, 16
    batch = _batch(cfg, B, S, jax.random.PRNGKey(2))
    ctx = {"positions": jnp.arange(S), "xattn_ctx": None}
    x = transformer._embed_tokens(params, cfg, batch["tokens"])
    x, _, _ = transformer._backbone(params, cfg, x, ctx, mode="seq")
    full = transformer._logits(params, cfg, x)
    cache = m.init_cache(B, S)
    lg, cache = jax.jit(m.prefill)(params, batch, cache)
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(full[:, -1]),
                               atol=2e-3)


def test_chunked_attention_matches_dense():
    from repro.models import attention as attn
    key = jax.random.PRNGKey(0)
    B, S, H, Hkv, Dh = 2, 4096, 4, 2, 32
    q = jax.random.normal(key, (B, S, H, Dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, Dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, Dh))
    dense = attn.dense_attention(q, k, v, causal=True)
    chunk = attn.chunked_attention(q, k, v, causal=True, q_chunk=512,
                                   kv_chunk=512)
    np.testing.assert_allclose(np.asarray(chunk), np.asarray(dense),
                               rtol=2e-3, atol=2e-3)


def test_chunked_window_attention_matches_dense():
    from repro.models import attention as attn
    key = jax.random.PRNGKey(3)
    B, S, H, Dh, W = 1, 4096, 2, 16, 1024
    q = jax.random.normal(key, (B, S, H, Dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, Dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, Dh))
    dense = attn.dense_attention(q, k, v, causal=True, window=W)
    chunk = attn.chunked_attention(q, k, v, causal=True, window=W,
                                   q_chunk=512, kv_chunk=512)
    np.testing.assert_allclose(np.asarray(chunk), np.asarray(dense),
                               rtol=2e-3, atol=2e-3)


def test_rglru_associative_scan_matches_step():
    """Parallel associative-scan RG-LRU == sequential stepping (the TPU
    adaptation is numerically faithful)."""
    from repro.models import rglru
    key = jax.random.PRNGKey(0)
    B, S, W = 2, 64, 32
    p = rglru.rglru_init(key, 48, W)
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, W))
    y_par, h_final = rglru.rglru_seq(p, x)
    h = jnp.zeros((B, W))
    ys = []
    for t in range(S):
        y, h = rglru.rglru_step(p, x[:, t], h)
        ys.append(y)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_final), np.asarray(h),
                               rtol=1e-4, atol=1e-4)


def test_mlstm_chunked_matches_stepwise():
    """Chunkwise-parallel mLSTM == recurrent stepping (incl. cross-chunk
    carry), validating the stabilized chunk algebra."""
    import math
    from repro.models import xlstm
    key = jax.random.PRNGKey(0)
    B, H, S, Dh = 1, 2, 512, 8
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, H, S, Dh))
    k = jax.random.normal(ks[1], (B, H, S, Dh))
    v = jax.random.normal(ks[2], (B, H, S, Dh))
    log_f = jax.nn.log_sigmoid(jax.random.normal(ks[3], (B, H, S)) + 2.0)
    log_i = jax.random.normal(ks[4], (B, H, S)) - 1.0
    h_par, _ = xlstm._mlstm_chunk_parallel(q, k, v, log_f, log_i)
    # stepwise reference
    C = jnp.zeros((B, H, Dh, Dh))
    n = jnp.zeros((B, H, Dh))
    m = jnp.full((B, H), -1e30)
    outs = []
    for t in range(S):
        m_new = jnp.maximum(log_f[..., t] + m, log_i[..., t])
        df = jnp.exp(log_f[..., t] + m - m_new)
        di = jnp.exp(log_i[..., t] - m_new)
        C = df[..., None, None] * C + di[..., None, None] * jnp.einsum(
            "bhd,bhe->bhde", k[..., t, :], v[..., t, :])
        n = df[..., None] * n + di[..., None] * k[..., t, :]
        num = jnp.einsum("bhd,bhde->bhe", q[..., t, :], C) / math.sqrt(Dh)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q[..., t, :]))
                          / math.sqrt(Dh), jnp.exp(-m_new))
        outs.append(num / den[..., None])
        m = m_new
    h_seq = jnp.stack(outs, axis=2)
    np.testing.assert_allclose(np.asarray(h_par), np.asarray(h_seq),
                               rtol=5e-3, atol=5e-3)
