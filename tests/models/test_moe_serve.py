"""MoE dispatch invariants + serving engine integration tests."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # hypothesis is optional; see tests/_hyp.py
    from tests._hyp import given, settings, strategies as st

from repro.models import moe as moe_mod


def _moe_params(key, d, dff, E, shared=0):
    return moe_mod.moe_init(key, d, dff, E, n_shared=shared, gated=True)


def test_moe_full_capacity_matches_dense_experts():
    """With capacity >= all assignments, sort+scatter dispatch == explicit
    per-token expert evaluation."""
    key = jax.random.PRNGKey(0)
    B, S, d, dff, E, k = 2, 8, 16, 32, 4, 2
    p = _moe_params(key, d, dff, E)
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, d))
    out, _ = moe_mod.moe_apply(p, x, top_k=k, capacity_factor=float(E),
                               act="silu", compute_dtype=jnp.float32)
    # explicit reference
    xt = x.reshape(-1, d)
    logits = xt @ p["router"]["w"]
    probs = jax.nn.softmax(logits, -1)
    gates, eids = jax.lax.top_k(probs, k)
    gates = gates / gates.sum(-1, keepdims=True)
    ref = jnp.zeros_like(xt)
    for e in range(E):
        h = jax.nn.silu(xt @ p["w_gate"][e]) * (xt @ p["w_up"][e])
        y = h @ p["w_down"][e]
        for j in range(k):
            ref += jnp.where((eids[:, j] == e)[:, None], gates[:, j:j+1] * y,
                             0.0)
    np.testing.assert_allclose(np.asarray(out.reshape(-1, d)),
                               np.asarray(ref), rtol=2e-3, atol=2e-3)


@settings(deadline=None, max_examples=8)
@given(T=st.sampled_from([16, 64]), E=st.sampled_from([4, 8]),
       seed=st.integers(0, 2**31 - 1))
def test_property_moe_capacity_drop_is_full_or_zero(T, E, seed):
    """top_k=1, no shared experts: under capacity pressure every token's
    output row equals either its full-capacity row (kept) or exactly zero
    (dropped) — the sort+scatter dispatch never mixes or invents values."""
    key = jax.random.PRNGKey(seed)
    d, dff = 8, 16
    p = _moe_params(key, d, dff, E)
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, T, d))
    out_low, _ = moe_mod.moe_apply(p, x, top_k=1, capacity_factor=0.5,
                                   act="silu", compute_dtype=jnp.float32)
    out_full, _ = moe_mod.moe_apply(p, x, top_k=1, capacity_factor=float(E),
                                    act="silu", compute_dtype=jnp.float32)
    lo = np.asarray(out_low.reshape(T, d))
    hi = np.asarray(out_full.reshape(T, d))
    assert np.isfinite(lo).all()
    row_is_full = np.all(np.abs(lo - hi) < 1e-4, axis=-1)
    row_is_zero = np.all(np.abs(lo) < 1e-5, axis=-1)
    assert np.all(row_is_full | row_is_zero)
    assert row_is_full.any()          # capacity 0.5 never drops everything


def test_moe_aux_loss_balanced_router_is_minimal():
    """Aux loss for a perfectly uniform router ~= 1 (its minimum scale)."""
    key = jax.random.PRNGKey(0)
    d, dff, E = 8, 16, 4
    p = _moe_params(key, d, dff, E)
    p["router"]["w"] = jnp.zeros_like(p["router"]["w"])   # uniform routing
    x = jax.random.normal(key, (1, 64, d))
    _, aux = moe_mod.moe_apply(p, x, top_k=1, capacity_factor=4.0,
                               act="silu", compute_dtype=jnp.float32)
    assert 0.9 < float(aux) < 1.1


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------

def test_engine_greedy_generation_deterministic():
    from repro.configs import get_config
    from repro.models import build
    from repro.serve.engine import Engine, ServeConfig
    cfg = get_config("phi3-mini-3.8b").reduced()
    m = build(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.ones((2, 8), jnp.int32)}
    eng = Engine(m, params, ServeConfig(max_new_tokens=6, temperature=0.0))
    out1 = eng.generate(batch)
    out2 = eng.generate(batch)
    assert out1.shape == (2, 14)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_engine_matches_stepwise_argmax():
    """Engine greedy tokens == manual prefill+decode argmax loop."""
    from repro.configs import get_config
    from repro.models import build
    from repro.serve.engine import Engine, ServeConfig
    cfg = get_config("xlstm-350m").reduced()
    m = build(cfg)
    params = m.init_params(jax.random.PRNGKey(1))
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 8), 0,
                              cfg.vocab_size)
    eng = Engine(m, params, ServeConfig(max_new_tokens=4, temperature=0.0))
    out = eng.generate({"tokens": toks})
    # manual
    cache = m.init_cache(1, 12)
    logits, cache = jax.jit(m.prefill)(params, {"tokens": toks}, cache)
    cur = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    manual = [cur]
    for t in range(3):
        logits, cache = jax.jit(m.decode_step)(params, cache, cur,
                                               jnp.int32(8 + t))
        cur = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        manual.append(cur)
    np.testing.assert_array_equal(np.asarray(out[:, 8:]),
                                  np.asarray(jnp.concatenate(manual, 1)))
