"""Subprocess helpers for multi-device tests.

The main pytest process must keep the single real CPU device (see
tests/conftest.py — no XLA_FLAGS there), so any test that needs a mesh
spawns a fresh interpreter with ``--xla_force_host_platform_device_count``
set before jax initializes.
"""
from __future__ import annotations

import os
import re
import socket
import subprocess
import sys
import textwrap

_SRC = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", "src"))


def _child_env(n_devices: int) -> dict:
    """Environment for a fresh-interpreter jax child with ``n_devices`` fake
    CPU devices. Any inherited device-count flag (the CI dist lane exports
    one for the parent process) is stripped so the child's count wins."""
    env = dict(os.environ)
    inherited = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                       env.get("XLA_FLAGS", ""))
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_devices} "
                        + inherited).strip()
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    return env


def run_with_devices(code: str, n_devices: int = 8,
                     timeout: int = 300) -> str:
    """Run ``code`` in a subprocess with n_devices fake CPU devices; returns
    stdout. Raises with both streams attached if the subprocess fails."""
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=_child_env(n_devices),
        timeout=timeout)
    assert proc.returncode == 0, (
        f"subprocess failed (rc={proc.returncode})\n"
        f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr}")
    return proc.stdout


def free_port() -> int:
    """An OS-assigned free localhost TCP port (the coordinator address)."""
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def run_multiprocess(code: str, n_procs: int = 2, n_devices: int = 1,
                     timeout: int = 300) -> list[str]:
    """Run ``code`` in ``n_procs`` concurrent interpreters forming one
    ``jax.distributed`` localhost cell; returns each process's stdout in
    process order. The cell is wired through the ``REPRO_COORDINATOR`` /
    ``REPRO_NUM_PROCESSES`` / ``REPRO_PROCESS_ID`` environment, so the code
    under test joins it with a bare ``dist.multihost.initialize()`` — the
    exact call production entry points make.
    """
    coord = f"127.0.0.1:{free_port()}"
    procs = []
    for pid in range(n_procs):
        env = _child_env(n_devices)
        env.update(REPRO_COORDINATOR=coord,
                   REPRO_NUM_PROCESSES=str(n_procs),
                   REPRO_PROCESS_ID=str(pid))
        procs.append(subprocess.Popen(
            [sys.executable, "-c", textwrap.dedent(code)],
            stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
            env=env))
    outs, fails = [], []
    for pid, p in enumerate(procs):
        try:
            out, err = p.communicate(timeout=timeout)
        except subprocess.TimeoutExpired:
            for q in procs:
                q.kill()
            raise
        outs.append(out)
        if p.returncode != 0:
            fails.append(f"process {pid} rc={p.returncode}\n"
                         f"--- stdout ---\n{out}\n--- stderr ---\n{err}")
    assert not fails, "\n".join(fails)
    return outs
