"""Subprocess helpers for multi-device tests.

The main pytest process must keep the single real CPU device (see
tests/conftest.py — no XLA_FLAGS there), so any test that needs a mesh
spawns a fresh interpreter with ``--xla_force_host_platform_device_count``
set before jax initializes.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

_SRC = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", "src"))


def run_with_devices(code: str, n_devices: int = 8,
                     timeout: int = 300) -> str:
    """Run ``code`` in a subprocess with n_devices fake CPU devices; returns
    stdout. Raises with both streams attached if the subprocess fails."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_devices} "
                        + env.get("XLA_FLAGS", "")).strip()
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=timeout)
    assert proc.returncode == 0, (
        f"subprocess failed (rc={proc.returncode})\n"
        f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr}")
    return proc.stdout
