"""Multi-host ingest: topology helpers, sharded ingestion, and the
compressed cross-host merge.

In-process tests cover the single-process (laptop) behaviour of every
``repro.dist.multihost`` entry point — the same code paths a fleet runs,
minus the coordinator. ``@pytest.mark.dist`` tests spawn their own
interpreters: a 4-fake-device cell for the hierarchical tree-reduce parity
claims, and a real 2-process ``jax.distributed`` localhost cell for the
wire-format merge.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.streaming import StreamingSummarizer
from repro.dist import multihost


# ---------------------------------------------------------------------------
# in-process: topology helpers


def test_host_shard_range_covers_and_balances():
    for d in (0, 1, 7, 10, 64, 101):
        for hosts in (1, 2, 3, 4, 7):
            ranges = [multihost.host_shard_range(d, hosts=hosts, host=h)
                      for h in range(hosts)]
            # contiguous cover of [0, d) in host order
            assert ranges[0][0] == 0 and ranges[-1][1] == d
            for (a, b), (c, _) in zip(ranges, ranges[1:]):
                assert b == c
            sizes = [hi - lo for lo, hi in ranges]
            assert max(sizes) - min(sizes) <= 1
            # the first d % hosts hosts take the extra row
            assert sizes == sorted(sizes, reverse=True)


def test_host_shard_range_validates():
    with pytest.raises(ValueError):
        multihost.host_shard_range(10, hosts=2, host=2)
    with pytest.raises(ValueError):
        multihost.host_shard_range(10, hosts=0, host=0)
    with pytest.raises(ValueError):
        multihost.host_shard_range(-1, hosts=2, host=0)


def test_initialize_is_noop_without_coordinator(monkeypatch):
    for var in ("REPRO_COORDINATOR", "REPRO_NUM_PROCESSES",
                "REPRO_PROCESS_ID"):
        monkeypatch.delenv(var, raising=False)
    assert multihost.initialize() is False
    # an explicit single-process cell is equally a no-op
    assert multihost.initialize("127.0.0.1:1234", 1, 0) is False
    # a configured address with no process count is still single-process
    monkeypatch.setenv("REPRO_COORDINATOR", "127.0.0.1:1234")
    assert multihost.initialize() is False


def test_process_topology_single_process():
    assert multihost.process_topology() == (0, 1)


def test_host_mesh_single_process():
    mesh = multihost.host_mesh()
    assert mesh.shape["host"] == 1
    assert mesh.shape["device"] == len(jax.devices())
    mesh = multihost.host_mesh(host_axis="h", device_axis="d")
    assert tuple(mesh.axis_names) == ("h", "d")
    with pytest.raises(ValueError):
        multihost.host_mesh(len(jax.devices()) + 1)


def test_kv_client_requires_coordinator():
    with pytest.raises(RuntimeError, match="coordinator"):
        multihost._kv_client()


# ---------------------------------------------------------------------------
# in-process: single-process ingest + merge


def test_cross_host_merge_single_process_is_passthrough(key):
    summ = StreamingSummarizer(8, probes=4)
    st = summ.init(key, (32, 6, 5))
    st = summ.update(st, jnp.ones((32, 6)), jnp.ones((32, 5)), 0)
    out = multihost.cross_host_merge(st, wire="bf16", tol=None)
    assert out is st          # no wire, no copy on a 1-process cell


def test_sharded_ingest_single_process_matches_local(key):
    d, na, nb, chunk = 50, 7, 5, 16
    A = jax.random.normal(key, (d, na))
    B = jax.random.normal(jax.random.fold_in(key, 1), (d, nb))
    summ = StreamingSummarizer(8, probes=4, cosketch=4)

    got = multihost.sharded_ingest(
        summ, key, (d, na, nb),
        lambda lo, hi: (A[lo:hi], B[lo:hi]), chunk=chunk)

    ref = summ.init(key, (d, na, nb))
    for off in range(0, d, chunk):
        ref = summ.update(ref, A[off:off + chunk], B[off:off + chunk], off)

    assert int(got.rows_seen) == d
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sharded_ingest_validates_chunk(key):
    summ = StreamingSummarizer(8)
    for bad in (0, -1, True, 2.0):
        with pytest.raises(ValueError):
            multihost.sharded_ingest(
                summ, key, (10, 3, 3),
                lambda lo, hi: (jnp.zeros((hi - lo, 3)),) * 2, chunk=bad)


# ---------------------------------------------------------------------------
# subprocess: hierarchical reduce on a 4-device emulated mesh


@pytest.mark.dist
def test_hierarchical_reduce_matches_flat_4dev():
    """(host, device) 2x2 tree-reduce vs flat 4-way psum: squared-norm
    blocks bit-exact, sketch blocks within reassociation tolerance — on a
    probed + co-sketched + decayed stream over a ragged row count."""
    from tests.dist.helpers import run_with_devices
    out = run_with_devices("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro import core
    from repro.dist import multihost

    key = jax.random.PRNGKey(0)
    d = 250                                # ragged: 250 % 4 != 0
    A = jax.random.normal(key, (d, 12))
    B = jax.random.normal(jax.random.fold_in(key, 1), (d, 10))
    summ = core.StreamingSummarizer(16, probes=4, cosketch=4, decay=0.97)

    flat = Mesh(np.array(jax.devices()), ("shard",))
    hier = multihost.host_mesh(2)          # 2 fake hosts x 2 devices
    assert hier.devices.shape == (2, 2)

    def run(mesh, axis):
        st = summ.init(key, (d, 12, 10))
        for off in range(0, d, 64):
            st = core.distributed_streaming_update(
                mesh, axis, summ, st, A[off:off + 64], B[off:off + 64],
                row_offset=off)
        return st

    st_flat = run(flat, "shard")
    st_hier = run(hier, ("host", "device"))

    for name in ("na2", "nb2"):
        fa, hi_ = getattr(st_flat, name), getattr(st_hier, name)
        assert np.array_equal(np.asarray(fa), np.asarray(hi_)), name
    for name in ("A_acc", "B_acc", "probe_acc", "cosketch_Y", "cosketch_W"):
        fa = np.asarray(getattr(st_flat, name))
        hi_ = np.asarray(getattr(st_hier, name))
        scale = max(1.0, float(np.abs(fa).max()))
        assert np.abs(fa - hi_).max() <= 1e-5 * scale, name
    assert int(st_hier.rows_seen) == d

    print("HIER_STREAM_OK", flush=True)
    """, n_devices=4)
    assert "HIER_STREAM_OK" in out


@pytest.mark.dist
def test_hierarchical_windowed_merge_matches_flat_4dev():
    """A sliding window whose epochs were absorbed through the hierarchical
    reduce merges to the same state as the flat-mesh window (norms
    bit-exact)."""
    from tests.dist.helpers import run_with_devices
    out = run_with_devices("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro import core
    from repro.dist import multihost

    key = jax.random.PRNGKey(3)
    ws = core.WindowedSummarizer(8, n_buckets=2, probes=4)
    flat = Mesh(np.array(jax.devices()), ("shard",))
    hier = multihost.host_mesh(2)

    def run(mesh, axis):
        w = ws.init(key, (60, 6, 5))
        for epoch in range(3):
            ek = jax.random.fold_in(key, 100 + epoch)
            A = jax.random.normal(ek, (60, 6))
            B = jax.random.normal(jax.random.fold_in(ek, 1), (60, 5))
            slot = int(w.head) % ws.n_buckets
            bucket = core.distributed_streaming_update(
                mesh, axis, ws._inner, w.buckets[slot], A, B, 0)
            w = ws._with_head_bucket(w, bucket)
            if epoch < 2:
                w = ws.slide(w)
        return ws.merged(w)

    m_flat = run(flat, "shard")
    m_hier = run(hier, ("host", "device"))
    assert np.array_equal(np.asarray(m_flat.na2), np.asarray(m_hier.na2))
    assert np.array_equal(np.asarray(m_flat.nb2), np.asarray(m_hier.nb2))
    diff = np.abs(np.asarray(m_flat.A_acc) - np.asarray(m_hier.A_acc)).max()
    assert diff <= 1e-5
    print("HIER_WINDOW_OK", flush=True)
    """, n_devices=4)
    assert "HIER_WINDOW_OK" in out


@pytest.mark.dist
def test_ragged_shard_bit_parity_with_padded_input():
    """The zero-padded trailing shard gives the bitwise-identical summary
    to manually padding the input to a shard multiple (both methods), and
    stays close to the single-device reference."""
    from tests.dist.helpers import run_with_devices
    out = run_with_devices("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro import core

    key = jax.random.PRNGKey(1)
    d, k = 250, 16                          # 250 = 4*62 + 2: ragged
    A = jax.random.normal(key, (d, 9))
    B = jax.random.normal(jax.random.fold_in(key, 1), (d, 7))
    mesh = Mesh(np.array(jax.devices()), ("shard",))
    pad = 252 - d
    A_pad = jnp.pad(A, ((0, pad), (0, 0)))
    B_pad = jnp.pad(B, ((0, pad), (0, 0)))

    for method in ("gaussian", "srht"):
        ragged = core.distributed_sketch_summary(
            mesh, "shard", key, A, B, k, method=method)
        # reference: same srht plan must come from the REAL d, so compare
        # the gaussian path bitwise against pre-padded input
        if method == "gaussian":
            padded = core.distributed_sketch_summary(
                mesh, "shard", key, A_pad, B_pad, k, method=method)
            assert np.array_equal(np.asarray(ragged.A_sketch),
                                  np.asarray(padded.A_sketch))
            assert np.array_equal(np.asarray(ragged.B_sketch),
                                  np.asarray(padded.B_sketch))
        ref = core.build_summary(key, A, B, k, method=method,
                                 backend="reference")
        err = np.abs(np.asarray(ragged.A_sketch)
                     - np.asarray(ref.A_sketch)).max()
        assert err <= 1e-4, (method, err)
        # zero padding must not leak into the norms
        assert np.allclose(np.asarray(ragged.norm_A),
                           np.asarray(ref.norm_A), rtol=1e-6)
    print("RAGGED_OK", flush=True)
    """, n_devices=4)
    assert "RAGGED_OK" in out


# ---------------------------------------------------------------------------
# subprocess: real 2-process jax.distributed cell


@pytest.mark.dist
def test_two_process_compressed_merge_cell():
    """A real 2-process localhost cell: each process ingests its own host
    shard, the merge travels as wire_pack bytes through the coordinator KV
    store, and every process ends with the bit-identical merged state (f32
    wire == the locally computed two-shard merge)."""
    from tests.dist.helpers import run_multiprocess
    outs = run_multiprocess("""
    import hashlib
    import jax, jax.numpy as jnp, numpy as np
    from repro import core
    from repro.dist import multihost

    assert multihost.initialize() is True
    pid, nproc = multihost.process_topology()
    assert nproc == 2

    key = jax.random.PRNGKey(7)
    d, na, nb = 90, 8, 6
    A = jax.random.normal(key, (d, na))              # same data every proc
    B = jax.random.normal(jax.random.fold_in(key, 1), (d, nb))
    summ = core.StreamingSummarizer(12, probes=4, cosketch=4)

    merged = multihost.sharded_ingest(
        summ, key, (d, na, nb),
        lambda lo, hi: (A[lo:hi], B[lo:hi]), chunk=16)

    # every proc can rebuild both partials locally: the f32-wire merge must
    # equal the local tree_merge of them, bitwise
    parts = []
    for h in range(nproc):
        lo, hi = multihost.host_shard_range(d, hosts=nproc, host=h)
        st = summ.init(key, (d, na, nb))
        for off in range(lo, hi, 16):
            st = summ.update(st, A[off:min(off+16, hi)],
                             B[off:min(off+16, hi)], off)
        parts.append(st)
    expect = core.tree_merge(parts)
    assert int(merged.rows_seen) == d
    for a, b in zip(jax.tree_util.tree_leaves(merged),
                    jax.tree_util.tree_leaves(expect)):
        assert np.array_equal(np.asarray(a), np.asarray(b))

    # conservative spec voting: pid 0 votes f32, pid 1 votes int8 -> the
    # cell settles on f32 and the result stays the bitwise f32 merge
    voted = multihost.cross_host_merge(
        parts[pid], wire=("f32" if pid == 0 else "int8"))
    assert np.array_equal(np.asarray(voted.A_acc), np.asarray(merged.A_acc))

    # bf16 wire: norm blocks ride f32 (bit-exact), sketch blocks within
    # the probe-measured quantisation tolerance
    lossy = multihost.cross_host_merge(parts[pid], wire="bf16")
    assert np.array_equal(np.asarray(lossy.na2), np.asarray(merged.na2))
    rel = (np.abs(np.asarray(lossy.A_acc) - np.asarray(merged.A_acc)).max()
           / np.abs(np.asarray(merged.A_acc)).max())
    assert 0 < rel <= 2e-2, rel

    digest = hashlib.sha256(
        np.ascontiguousarray(np.asarray(merged.A_acc)).tobytes()).hexdigest()
    print("MULTIHOST_OK", digest, flush=True)
    """, n_procs=2)
    lines = [ln for out in outs for ln in out.splitlines()
             if ln.startswith("MULTIHOST_OK")]
    assert len(lines) == 2
    assert lines[0] == lines[1]        # bit-identical merge on every host
