"""Path-based sharding rules, in-process.

``param_spec`` / ``cache_spec`` only read ``mesh.shape``, so the rule
table — including every divisibility fallback — is checkable without
spawning a multi-device subprocess; the ``*_shardings`` tree walkers run
on a real 1x1 mesh.
"""
import types

import jax
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist import sharding


def _mesh_shape(data=4, model=2):
    # param_spec/cache_spec duck-type the mesh: only .shape is read
    return types.SimpleNamespace(shape={"data": data, "model": model})


def test_param_spec_fsdp_tp_weight():
    mesh = _mesh_shape()
    assert sharding.param_spec(mesh, "/mlp/up/w", (8, 6)) == \
        P(("data",), "model")


def test_param_spec_divisibility_fallbacks():
    mesh = _mesh_shape(data=4, model=16)
    # 12 heads do not divide a 16-way model axis: d_out replicated
    assert sharding.param_spec(mesh, "/attn/wq/w", (8, 12)) == \
        P(("data",), None)
    # d_in not divisible by dp either: fully replicated
    assert sharding.param_spec(mesh, "/attn/wq/w", (6, 12)) == P(None, None)


def test_param_spec_bias_and_stacked_dims():
    mesh = _mesh_shape()
    assert sharding.param_spec(mesh, "/mlp/up/b", (6,)) == P(None)
    # stacked layer-group leading dim stays unsharded
    assert sharding.param_spec(mesh, "/groups/0/0/mlp/up/w", (3, 8, 6)) == \
        P(None, ("data",), "model")


def test_param_spec_embed_is_vocab_tp_dmodel_dp():
    mesh = _mesh_shape()
    assert sharding.param_spec(mesh, "/embed/table", (10, 8)) == \
        P("model", ("data",))
    # ragged vocab replicates the vocab dim only
    assert sharding.param_spec(mesh, "/embed/table", (11, 8)) == \
        P(None, ("data",))


def test_cache_spec_prefers_kv_heads_then_head_dim():
    mesh = _mesh_shape(data=2, model=4)
    # (B, S, KV, Dh): KV=8 divides model=4 -> KV takes TP
    assert sharding.cache_spec(mesh, "/cache/k", (4, 16, 8, 6)) == \
        P(("data",), None, "model", None)
    # KV=3 ragged -> falls back to head_dim
    assert sharding.cache_spec(mesh, "/cache/k", (4, 16, 3, 8)) == \
        P(("data",), None, None, "model")


def test_tree_walkers_build_namedshardings_on_real_mesh():
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    shapes = {"mlp": {"up": {"w": jax.ShapeDtypeStruct((8, 6), np.float32)}}}
    out = sharding.params_shardings(mesh, shapes)
    sh = out["mlp"]["up"]["w"]
    assert isinstance(sh, NamedSharding) and sh.mesh is mesh
    cache = {"k": jax.ShapeDtypeStruct((2, 4, 2, 2), np.float32)}
    csh = sharding.cache_shardings(mesh, cache)["k"]
    assert isinstance(csh, NamedSharding)


@pytest.mark.parametrize("axes,expect", [("data", 4), (("data", "model"), 8)])
def test_axes_size_accepts_str_or_tuple(axes, expect):
    assert sharding._axes_size(_mesh_shape(data=4, model=2), axes) == expect
