"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode runs
the exact TPU kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # hypothesis is optional; see tests/_hyp.py
    from tests._hyp import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.hadamard import hadamard_matrix


# ---------------------------------------------------------------------------
# sketch_fused
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k,d,n", [
    (8, 256, 128), (32, 512, 256), (64, 1000, 300),   # unaligned d/n
    (128, 128, 64), (16, 2048, 512), (4, 96, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sketch_fused_sweep(k, d, n, dtype):
    kk = jax.random.PRNGKey(k * 1000 + d + n)
    Pi = jax.random.normal(kk, (k, d), jnp.float32).astype(dtype)
    A = jax.random.normal(jax.random.fold_in(kk, 1), (d, n), jnp.float32).astype(dtype)
    out, norms = ops.sketch_fused(Pi, A)
    out_r, n2_r = ref.sketch_fused_ref(Pi, A)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_r),
                               rtol=tol, atol=tol * np.abs(np.asarray(out_r)).max())
    np.testing.assert_allclose(np.asarray(norms), np.sqrt(np.asarray(n2_r)),
                               rtol=tol)


def test_sketch_fused_block_shape_independence():
    """Different BlockSpec tilings must produce identical results."""
    kk = jax.random.PRNGKey(3)
    Pi = jax.random.normal(kk, (16, 640))
    A = jax.random.normal(jax.random.fold_in(kk, 1), (640, 192))
    o1, n1 = ops.sketch_fused(Pi, A, bn=64, bd=128)
    o2, n2 = ops.sketch_fused(Pi, A, bn=256, bd=512)
    # different tilings reassociate the f32 d-accumulation; with d=640 terms
    # of magnitude O(1) the roundoff floor is a few e-5 absolute
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-5,
                               atol=5e-5)
    np.testing.assert_allclose(np.asarray(n1), np.asarray(n2), rtol=1e-6)


def test_sketch_summary_fused_matches_core():
    """Kernel-backed summary is a valid SketchSummary for the full pipeline."""
    kk = jax.random.PRNGKey(0)
    A = jax.random.normal(kk, (500, 60))
    B = jax.random.normal(jax.random.fold_in(kk, 1), (500, 40))
    s = ops.sketch_summary_fused(kk, A, B, k=32)
    np.testing.assert_allclose(np.asarray(s.norm_A),
                               np.linalg.norm(np.asarray(A), axis=0), rtol=1e-4)
    assert s.A_sketch.shape == (32, 60) and s.B_sketch.shape == (32, 40)


# ---------------------------------------------------------------------------
# sampled_dot
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n1,n2,k,m", [
    (20, 30, 8, 17), (100, 50, 64, 128), (7, 9, 16, 5), (64, 64, 128, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sampled_dot_sweep(n1, n2, k, m, dtype):
    kk = jax.random.PRNGKey(n1 + n2 + k + m)
    As = jax.random.normal(kk, (n1, k), jnp.float32).astype(dtype)
    Bs = jax.random.normal(jax.random.fold_in(kk, 1), (n2, k), jnp.float32).astype(dtype)
    na = jnp.abs(jax.random.normal(jax.random.fold_in(kk, 2), (n1,))) + 0.5
    nb = jnp.abs(jax.random.normal(jax.random.fold_in(kk, 3), (n2,))) + 0.5
    rows = jax.random.randint(jax.random.fold_in(kk, 4), (m,), 0, n1)
    cols = jax.random.randint(jax.random.fold_in(kk, 5), (m,), 0, n2)
    got = ops.sampled_rescaled_dot(As, Bs, na, nb, rows, cols)
    want = ref.sampled_rescaled_dot_ref(As, Bs, na, nb, rows, cols)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol,
                               atol=tol)


def test_sampled_dot_duplicate_indices():
    kk = jax.random.PRNGKey(0)
    As = jax.random.normal(kk, (10, 8))
    Bs = jax.random.normal(jax.random.fold_in(kk, 1), (10, 8))
    ones = jnp.ones((10,))
    rows = jnp.array([3, 3, 3, 0], jnp.int32)
    cols = jnp.array([5, 5, 2, 0], jnp.int32)
    got = ops.sampled_rescaled_dot(As, Bs, ones, ones, rows, cols)
    want = ref.sampled_rescaled_dot_ref(As, Bs, ones, ones, rows, cols)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_sampled_dot_empty_sample_set():
    """m = 0 (an empty Omega) returns an empty result instead of tripping a
    zero-size grid slice; kernel and oracle agree on the shape."""
    kk = jax.random.PRNGKey(0)
    As = jax.random.normal(kk, (5, 8))
    Bs = jax.random.normal(jax.random.fold_in(kk, 1), (4, 8))
    na, nb = jnp.ones((5,)), jnp.ones((4,))
    empty = jnp.zeros((0,), jnp.int32)
    got = ops.sampled_rescaled_dot(As, Bs, na, nb, empty, empty)
    want = ref.sampled_rescaled_dot_ref(As, Bs, na, nb, empty, empty)
    assert got.shape == want.shape == (0,)
    assert got.dtype == jnp.float32


def test_sampled_dot_more_samples_than_entries():
    """m > n1 * n2: the sample necessarily repeats entries — every duplicate
    gathers the identical sketch rows and the kernel matches the oracle
    exactly (parity, not tolerance: same f32 ops per grid step)."""
    kk = jax.random.PRNGKey(7)
    As = jax.random.normal(kk, (5, 8))
    Bs = jax.random.normal(jax.random.fold_in(kk, 1), (4, 8))
    na = jnp.abs(jax.random.normal(jax.random.fold_in(kk, 2), (5,))) + 0.5
    nb = jnp.abs(jax.random.normal(jax.random.fold_in(kk, 3), (4,))) + 0.5
    m = 3 * 5 * 4                       # 3x the number of distinct entries
    rows = jax.random.randint(jax.random.fold_in(kk, 4), (m,), 0, 5)
    cols = jax.random.randint(jax.random.fold_in(kk, 5), (m,), 0, 4)
    got = ops.sampled_rescaled_dot(As, Bs, na, nb, rows, cols)
    want = ref.sampled_rescaled_dot_ref(As, Bs, na, nb, rows, cols)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # duplicates really occurred and agree among themselves
    pairs = np.stack([np.asarray(rows), np.asarray(cols)], 1)
    _, inv = np.unique(pairs, axis=0, return_inverse=True)
    for g in range(inv.max() + 1):
        vals = np.asarray(got)[inv == g]
        assert np.all(vals == vals[0])


# ---------------------------------------------------------------------------
# hadamard
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d,b,n", [
    (128, 128, 64),     # a == 1, single stage
    (256, 64, 100),     # unaligned n
    (512, 128, 256),
    (1024, 32, 96),
])
def test_blocked_fwht_sweep(d, b, n):
    kk = jax.random.PRNGKey(d + b + n)
    X = jax.random.normal(kk, (d, n))
    signs = jax.random.rademacher(jax.random.fold_in(kk, 1), (d,),
                                  dtype=jnp.float32)
    got = ops.blocked_fwht(X, signs, b=b)
    want = ref.blocked_fwht_ref(X, signs)
    scale = np.abs(np.asarray(want)).max()
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4 * scale)


def test_fwht_butterfly_equals_sylvester_matrix():
    """Cross-check both references against the explicit H matrix."""
    d = 64
    kk = jax.random.PRNGKey(0)
    X = jax.random.normal(kk, (d, 5))
    H = np.asarray(hadamard_matrix(d))
    want = H @ np.asarray(X)
    got = np.asarray(ref.blocked_fwht_ref(X, jnp.ones((d,))))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


def test_srht_kernel_preserves_geometry():
    """Kernel-backed SRHT is a valid subspace embedding (norm preservation)."""
    kk = jax.random.PRNGKey(0)
    X = jax.random.normal(kk, (777, 40))     # non-power-of-two d
    S = ops.srht_sketch_kernel(kk, X, k=512)
    norms_in = np.linalg.norm(np.asarray(X), axis=0)
    norms_out = np.linalg.norm(np.asarray(S), axis=0)
    assert np.mean(np.abs(norms_out - norms_in) / norms_in) < 0.1


@settings(deadline=None, max_examples=8)
@given(logd=st.integers(5, 9), seed=st.integers(0, 2**31 - 1))
def test_property_fwht_parseval(logd, seed):
    """H/sqrt(d) is orthogonal: the kernel must preserve Frobenius norm."""
    d = 2 ** logd
    kk = jax.random.PRNGKey(seed)
    X = jax.random.normal(kk, (d, 3))
    out = ops.blocked_fwht(X, jnp.ones((d,)), b=min(128, d)) / np.sqrt(d)
    np.testing.assert_allclose(float(jnp.linalg.norm(out)),
                               float(jnp.linalg.norm(X)), rtol=1e-4)


def test_hadamard_matrix_non_pow2_raises_named_valueerror():
    """hadamard_matrix rejects non-power-of-two sizes with a ValueError
    naming n, never a strippable assert."""
    with pytest.raises(ValueError, match="n=12"):
        hadamard_matrix(12)
    with pytest.raises(ValueError, match="power of two"):
        hadamard_matrix(0)
    assert hadamard_matrix(8).shape == (8, 8)
