"""Flash-attention Pallas kernel vs naive oracle (interpret mode runs the
exact TPU kernel body; scratch-based online softmax across k-blocks)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # hypothesis is optional; see tests/_hyp.py
    from tests._hyp import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention as raw_flash


def _oracle(q, k, v, causal=True):
    B, S, H, Dh = q.shape
    Hkv = k.shape[2]
    rep = H // Hkv
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, S, Dh)
    out = ref.flash_attention_ref(fold(q), fold(jnp.repeat(k, rep, axis=2)),
                                  fold(jnp.repeat(v, rep, axis=2)),
                                  causal=causal)
    return out.reshape(B, H, S, Dh).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("B,S,H,Hkv,Dh", [
    (1, 128, 2, 2, 32), (2, 256, 4, 2, 64), (1, 512, 2, 1, 128),
    (1, 384, 3, 3, 64),     # S not a multiple of 256
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_matches_oracle(B, S, H, Hkv, Dh, dtype):
    key = jax.random.PRNGKey(S + H)
    q = jax.random.normal(key, (B, S, H, Dh), jnp.float32).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, Dh),
                          jnp.float32).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, Dh),
                          jnp.float32).astype(dtype)
    got = ops.flash_attention(q, k, v, causal=True)
    want = _oracle(q, k, v, causal=True)
    tol = 5e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, jnp.float32),
                               np.asarray(want, jnp.float32),
                               rtol=tol, atol=tol)


def test_flash_noncausal():
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 256, 2, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 256, 2, 32))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 256, 2, 32))
    got = ops.flash_attention(q, k, v, causal=False)
    want = _oracle(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-5, atol=5e-5)


def test_flash_block_shape_independence():
    key = jax.random.PRNGKey(3)
    BH, S, Dh = 2, 512, 64
    q = jax.random.normal(key, (BH, S, Dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (BH, S, Dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (BH, S, Dh))
    o1 = raw_flash(q, k, v, causal=True, bq=128, bk=128)
    o2 = raw_flash(q, k, v, causal=True, bq=64, bk=256)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-5, atol=1e-5)


@settings(deadline=None, max_examples=6)
@given(s_blocks=st.integers(1, 4), dh=st.sampled_from([32, 64]),
       seed=st.integers(0, 2**31 - 1))
def test_property_flash_rows_are_convex_combinations(s_blocks, dh, seed):
    """Causal flash output rows lie in the convex hull of V rows (softmax
    weights sum to 1) — checked via max-bound."""
    key = jax.random.PRNGKey(seed)
    S = 128 * s_blocks
    q = jax.random.normal(key, (1, S, dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, S, dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, S, dh))
    out = raw_flash(q, k, v, causal=True)
    vmax = float(jnp.max(jnp.abs(v)))
    assert float(jnp.max(jnp.abs(out))) <= vmax + 1e-4
