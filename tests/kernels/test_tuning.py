"""KernelConfig / autotuner tests: block-size invariance sweeps (a legal
config may change wall time, never results — bit-identical where the
accumulation order is unchanged, reassociation tolerance otherwise),
candidate enumeration under the VMEM budget, deterministic roofline
ranking, tuning-table round-trips, and the tuning thread through the
PipelineEngine (default path bit-identical, warm traffic trace-free
under a pinned non-default TuningSpec)."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import summary_engine
from repro.core.pipeline import (
    PipelineEngine, PipelinePlan, RankPolicy, SketchSpec, validate_plan)
from repro.kernels import ops, tuning
from repro.kernels.tuning import (
    DEFAULTS, KernelConfig, TuningSpec, TuningTable, candidate_configs,
    rank_candidates, table_key, validate_config, vmem_bytes)

from tests.conftest import gaussian_pair


def _sk(bn, bd, **kw):
    return KernelConfig("sketch_fused", (bn, bd), **kw)


def _fw(b, bn, **kw):
    return KernelConfig("blocked_fwht", (b, bn), **kw)


# ---------------------------------------------------------------------------
# Block-size invariance sweeps: configs tune, they never change answers
# ---------------------------------------------------------------------------

@pytest.mark.kernel
@pytest.mark.parametrize("bn", [128, 256, 512])
def test_sketch_fused_bn_sweep_bit_identical(bn):
    """Fixed bd: every output element sums the same bd-chunks in the same
    order whatever bn tiles the columns, so the sweep is bit-identical."""
    kk = jax.random.PRNGKey(1)
    Pi = jax.random.normal(kk, (16, 512))
    A = jax.random.normal(jax.random.fold_in(kk, 1), (512, 512))
    base, nbase = ops.sketch_fused(Pi, A, config=_sk(512, 256))
    got, ngot = ops.sketch_fused(Pi, A, config=_sk(bn, 256))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(base))
    np.testing.assert_array_equal(np.asarray(ngot), np.asarray(nbase))


@pytest.mark.kernel
@pytest.mark.parametrize("bd", [128, 256, 512])
def test_sketch_fused_bd_sweep_reassociation_tolerance(bd):
    """Changing bd re-chunks the d-accumulation (different f32
    reassociation); the sweep agrees to the roundoff floor only."""
    kk = jax.random.PRNGKey(1)
    Pi = jax.random.normal(kk, (16, 512))
    A = jax.random.normal(jax.random.fold_in(kk, 1), (512, 256))
    base, nbase = ops.sketch_fused(Pi, A, config=_sk(256, 512))
    got, ngot = ops.sketch_fused(Pi, A, config=_sk(256, bd))
    np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                               rtol=1e-5, atol=5e-5)
    np.testing.assert_allclose(np.asarray(ngot), np.asarray(nbase),
                               rtol=1e-5)


@pytest.mark.kernel
@pytest.mark.parametrize("bn", [128, 256])
@pytest.mark.parametrize("grid_order", [None, "n_inner", "p_inner"])
def test_blocked_fwht_bn_and_grid_order_bit_identical(bn, grid_order):
    """Stage-1 outputs are write-once (no revisited block), so both grid
    traversals and any column tiling must be bit-identical."""
    kk = jax.random.PRNGKey(2)
    X = jax.random.normal(kk, (512, 384))
    signs = jax.random.rademacher(jax.random.fold_in(kk, 1), (512,),
                                  dtype=jnp.float32)
    base = ops.blocked_fwht(X, signs, config=_fw(128, 128))
    got = ops.blocked_fwht(X, signs,
                           config=_fw(128, bn, grid_order=grid_order))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(base))


@pytest.mark.kernel
@pytest.mark.parametrize("b", [32, 64, 256])
def test_blocked_fwht_b_sweep_reassociation_tolerance(b):
    """Changing b re-factors the butterfly (H_d = (H_a (x) I) (I (x) H_b)
    at a different split) — same transform, different f32 order."""
    kk = jax.random.PRNGKey(2)
    X = jax.random.normal(kk, (512, 192))
    signs = jax.random.rademacher(jax.random.fold_in(kk, 1), (512,),
                                  dtype=jnp.float32)
    base = ops.blocked_fwht(X, signs, config=_fw(128, 256))
    got = ops.blocked_fwht(X, signs, config=_fw(b, 256))
    scale = np.abs(np.asarray(base)).max()
    np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                               rtol=1e-4, atol=1e-4 * scale)


@pytest.mark.kernel
def test_sampled_dot_precision_sweep():
    """precision=None and 'f32' are the same kernel on f32 inputs
    (bit-identical); 'bf16' halves the gathered-row DMA and only loosens
    to bf16 accuracy; unknown precision is rejected by name."""
    kk = jax.random.PRNGKey(3)
    As = jax.random.normal(kk, (64, 32))
    Bs = jax.random.normal(jax.random.fold_in(kk, 1), (64, 32))
    na = jnp.abs(jax.random.normal(jax.random.fold_in(kk, 2), (64,))) + 0.5
    nb = jnp.abs(jax.random.normal(jax.random.fold_in(kk, 3), (64,))) + 0.5
    rows = jax.random.randint(jax.random.fold_in(kk, 4), (50,), 0, 64)
    cols = jax.random.randint(jax.random.fold_in(kk, 5), (50,), 0, 64)
    base = ops.sampled_rescaled_dot(As, Bs, na, nb, rows, cols)
    cfg = KernelConfig("sampled_dot", (), precision="f32")
    same = ops.sampled_rescaled_dot(As, Bs, na, nb, rows, cols, config=cfg)
    np.testing.assert_array_equal(np.asarray(same), np.asarray(base))
    half = ops.sampled_rescaled_dot(
        As, Bs, na, nb, rows, cols,
        config=KernelConfig("sampled_dot", (), precision="bf16"))
    np.testing.assert_allclose(np.asarray(half), np.asarray(base),
                               rtol=5e-2, atol=5e-2)
    with pytest.raises(ValueError, match="precision"):
        ops.sampled_rescaled_dot(As, Bs, na, nb, rows, cols,
                                 precision="f64")


# ---------------------------------------------------------------------------
# Config validation + the assert-to-ValueError bugfixes
# ---------------------------------------------------------------------------

def test_validate_config_rejects_bad_configs():
    with pytest.raises(ValueError, match="unknown kernel"):
        validate_config(KernelConfig("nope", (128, 128)))
    with pytest.raises(ValueError, match="block"):
        validate_config(KernelConfig("sketch_fused", (128,)))
    with pytest.raises(ValueError, match="128"):
        validate_config(_sk(100, 256))            # bn not lane-aligned
    with pytest.raises(ValueError, match="power of two"):
        validate_config(_fw(96, 128))             # b not a power of two
    with pytest.raises(ValueError, match="grid_order"):
        validate_config(_sk(128, 256, grid_order="p_inner"))
    with pytest.raises(ValueError, match="precision"):
        validate_config(_sk(128, 256, precision="f64"))
    with pytest.raises(TypeError):
        validate_config(("sketch_fused", (128, 256)))


def test_tuning_spec_rejects_duplicate_kernels():
    with pytest.raises(ValueError, match="more than once"):
        TuningSpec((_sk(128, 256), _sk(256, 256))).validate()
    ts = TuningSpec((_sk(128, 256), _fw(128, 128)))
    ts.validate()
    assert ts.config_for("sketch_fused") == _sk(128, 256)
    assert ts.config_for("sampled_dot") is None


def test_shape_errors_are_valueerrors_not_asserts():
    """The -O-strippable asserts are gone: bad shapes raise ValueErrors
    that name the offending dims even under python -O."""
    from repro.kernels import flash_attention as fa
    from repro.kernels import sketch_fused as sf
    kk = jax.random.PRNGKey(0)
    X = jax.random.normal(kk, (100, 8))           # d=100 not a power of two
    with pytest.raises(ValueError, match="power of two"):
        ops.blocked_fwht(X, jnp.ones((100,)))
    Pi = jax.random.normal(kk, (8, 256))
    A = jax.random.normal(kk, (128, 64))
    with pytest.raises(ValueError, match="disagree on d"):
        sf.sketch_fused(Pi, A, bn=64, bd=128)
    A2 = jax.random.normal(kk, (256, 100))        # n=100 not divisible by bn
    with pytest.raises(ValueError, match="divisible"):
        sf.sketch_fused(Pi, A2, bn=64, bd=128)
    qkv = jax.random.normal(kk, (3, 2, 100, 16))  # S=100, bq=64: 100 % 64
    with pytest.raises(ValueError, match="divisible"):
        fa.flash_attention(qkv[0], qkv[1], qkv[2], bq=64, bk=50)


def test_ops_kwarg_overrides_config_and_kernel_mismatch_rejected():
    kk = jax.random.PRNGKey(4)
    Pi = jax.random.normal(kk, (8, 256))
    A = jax.random.normal(jax.random.fold_in(kk, 1), (256, 128))
    got, _ = ops.sketch_fused(Pi, A, bd=128, config=_sk(128, 256))
    want, _ = ops.sketch_fused(Pi, A, bn=128, bd=128)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    with pytest.raises(ValueError, match="sketch_fused"):
        ops.sketch_fused(Pi, A, config=_fw(128, 128))


# ---------------------------------------------------------------------------
# Autotuner: candidates, ranking, tables, fallback
# ---------------------------------------------------------------------------

def test_candidates_respect_vmem_budget_and_alignment():
    shape = (128, 4096, 512)
    cands = candidate_configs("sketch_fused", shape)
    assert cands
    for cfg in cands:
        validate_config(cfg)                      # alignment-legal
        assert vmem_bytes(cfg, shape) <= tuning.VMEM_BUDGET_BYTES


def test_candidates_tiny_budget_falls_back_to_min_footprint():
    """An impossible budget still yields one candidate (the smallest
    footprint) instead of an empty sweep."""
    shape = (128, 4096, 512)
    cands = candidate_configs("sketch_fused", shape, vmem_budget=1)
    assert len(cands) == 1
    full = candidate_configs("sketch_fused", shape)
    assert min(vmem_bytes(c, shape) for c in full) == \
        vmem_bytes(cands[0], shape)


def test_ranking_is_deterministic():
    shape = (64, 2048, 512)
    r1 = rank_candidates("sketch_fused", shape)
    r2 = rank_candidates("sketch_fused", shape)
    assert r1 == r2 and len(r1) >= 2
    costs = [tuning.roofline_cost(c, shape).t_total for c in r1]
    assert costs == sorted(costs)


def test_autotune_static_mode_returns_ranking_head():
    shape = (64, 2048, 512)
    winner, records = tuning.autotune("sketch_fused", shape)
    assert winner == rank_candidates("sketch_fused", shape)[0]
    assert records and "t_total" in records[0]
    assert "us_per_call" not in records[0]        # static: nothing measured


def test_table_round_trip_and_version_check(tmp_path):
    t = TuningTable(backend="cpu")
    cfg = _sk(128, 256)
    t.put("sketch_fused", (100, 3000, 400), cfg, stats={"us_per_call": 7.0})
    # pow2 bucketing: any shape in the same bucket hits the same entry
    assert t.get("sketch_fused", (128, 4096, 512)) == cfg
    assert t.get("sketch_fused", (128, 8192, 512)) is None
    path = str(tmp_path / "cpu.json")
    t.save(path)
    back = TuningTable.load(path)
    assert back.get("sketch_fused", (100, 3000, 400)) == cfg
    assert back.backend == "cpu" and back.version == tuning.TABLE_VERSION
    with open(path) as f:
        blob = json.load(f)
    blob["version"] = tuning.TABLE_VERSION + 1
    with open(path, "w") as f:
        json.dump(blob, f)
    with pytest.raises(ValueError, match="version"):
        TuningTable.load(path)


def test_lookup_unknown_shape_falls_back_to_defaults():
    assert table_key("sketch_fused", (100, 3000, 400)) == \
        table_key("sketch_fused", (128, 4096, 512))
    for kernel in tuning.KERNELS:
        shape = {"sketch_fused": (3, 5, 7), "blocked_fwht": (17, 3),
                 "sampled_dot": (3, 3, 3, 3),
                 "flash_attention": (1, 3, 3)}[kernel]
        assert tuning.lookup(kernel, shape) == DEFAULTS[kernel]


# ---------------------------------------------------------------------------
# The tuning thread: plans, engine cache keys, default parity
# ---------------------------------------------------------------------------

def test_plan_rejects_bad_tuning(key):
    with pytest.raises(ValueError, match="TuningSpec"):
        validate_plan(PipelinePlan(rank=RankPolicy(r=2),
                                   tuning=("sketch_fused",)))
    with pytest.raises(ValueError, match="more than once"):
        validate_plan(PipelinePlan(
            rank=RankPolicy(r=2),
            tuning=TuningSpec((_sk(128, 256), _sk(256, 256)))))


def test_default_tuning_bitwise_parity(key):
    """tuning=None must reproduce the pre-tuner pallas path bit-for-bit:
    the frozen DEFAULTS are the historical hard-coded blocks."""
    A, B = gaussian_pair(key, d=384, n1=12, n2=9)
    base = summary_engine.build_summary(key, A, B, 16, backend="pallas")
    pinned = summary_engine.build_summary(
        key, A, B, 16, backend="pallas",
        tuning=TuningSpec((DEFAULTS["sketch_fused"],)))
    for name in ("A_sketch", "B_sketch", "norm_A", "norm_B"):
        np.testing.assert_array_equal(np.asarray(getattr(base, name)),
                                      np.asarray(getattr(pinned, name)))


def test_nondefault_tuning_close_and_separately_cached(key):
    """A non-default TuningSpec changes only float reassociation — and gets
    its own executable-cache entry (the spec is part of the plan key)."""
    A, B = gaussian_pair(key, d=384, n1=10, n2=8)
    eng = PipelineEngine()
    spec = SketchSpec(backend="pallas", k=16, block=64)
    ts = TuningSpec((_sk(128, 256),))
    s_def = eng.summarize(spec, key, A, B)
    s_tun = eng.summarize(spec, key, A, B, ts)
    assert eng.stats.misses == 2                  # distinct cache entries
    np.testing.assert_allclose(np.asarray(s_tun.A_sketch),
                               np.asarray(s_def.A_sketch),
                               rtol=1e-5, atol=5e-5)
    eng.summarize(spec, key, A, B, ts)            # warm: pure hit
    assert eng.stats.hits == 1


def test_warm_traffic_zero_retraces_with_nondefault_tuning(key):
    """Acceptance gate: a warm engine under a pinned non-default tuning
    never re-traces on repeat-shape traffic."""
    from repro.serve.engine import SketchService
    eng = PipelineEngine()
    ts = TuningSpec((_sk(128, 256), _fw(64, 128, grid_order="p_inner")))
    svc = SketchService(k=16, backend="pallas", block=64, engine=eng,
                        tuning=ts)

    def flush_once():
        for i in range(3):
            kk = jax.random.fold_in(key, i)
            A = jax.random.normal(kk, (256, 12))
            B = jax.random.normal(jax.random.fold_in(kk, 9), (256, 12))
            svc.submit(kk, A, B)
        return svc.flush_factors(r=2, m=80, T=2)

    cold = flush_once()
    traces0 = eng.stats.traces
    warm = flush_once()
    assert eng.stats.traces == traces0            # zero new traces
    for t_c, t_w in zip(cold, warm):
        np.testing.assert_array_equal(
            np.asarray(cold[t_c].factors.U), np.asarray(warm[t_w].factors.U))


def test_srht_pipeline_with_tuned_fwht(key):
    """The srht sketch path threads the blocked_fwht config end-to-end and
    stays a valid subspace embedding under a non-default tiling."""
    A, B = gaussian_pair(key, d=300, n1=9, n2=6)   # non-pow2 d: pad + fwht
    ts = TuningSpec((_fw(64, 128, grid_order="p_inner"),))
    s = summary_engine.build_summary(key, A, B, 64, method="srht",
                                     backend="pallas", tuning=ts)
    ref_s = summary_engine.build_summary(key, A, B, 64, method="srht",
                                         backend="pallas")
    np.testing.assert_allclose(np.asarray(s.A_sketch),
                               np.asarray(ref_s.A_sketch),
                               rtol=1e-4, atol=1e-4)
