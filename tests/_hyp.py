"""Minimal stand-in for the optional ``hypothesis`` dependency.

Provides exactly the API surface this suite uses — ``given``, ``settings``,
``strategies.integers``, ``strategies.sampled_from`` — as a deterministic
property loop, so the tier-1 command runs on a clean interpreter. When real
hypothesis is installed the tests import it instead (each usage site does
``try: from hypothesis import ... except ImportError: from tests._hyp ...``).

The fallback draws ``max_examples`` samples per strategy from a PRNG seeded
by the test name: deterministic across runs, no shrinking, no example
database — a property *loop*, not a property *search*.
"""
from __future__ import annotations

import random
import types


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def _integers(min_value, max_value):
    return _Strategy(lambda rnd: rnd.randint(min_value, max_value))


def _sampled_from(elements):
    opts = list(elements)
    return _Strategy(lambda rnd: rnd.choice(opts))


strategies = types.SimpleNamespace(integers=_integers,
                                   sampled_from=_sampled_from)

_DEFAULT_MAX_EXAMPLES = 10


def given(**strats):
    """Decorator: run the test once per drawn example (deterministic seed).

    The wrapper takes no arguments (all parameters are drawn), matching how
    this suite uses @given — property tests here never mix in fixtures.
    """
    def deco(fn):
        def wrapper():
            n = getattr(wrapper, "_max_examples", _DEFAULT_MAX_EXAMPLES)
            rnd = random.Random(f"repro-hyp:{fn.__module__}:{fn.__name__}")
            for _ in range(n):
                drawn = {name: s.draw(rnd) for name, s in strats.items()}
                fn(**drawn)
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco


def settings(deadline=None, max_examples=_DEFAULT_MAX_EXAMPLES, **_kw):
    """Records max_examples on the (already-wrapped) test function."""
    del deadline

    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco
