"""Multi-host streaming ingest: process topology + compressed cross-host merge.

Scales the one-pass summary beyond a single process. Each host streams its
own contiguous shard of the global rows through the double-buffered
``StreamingSummarizer.ingest`` (rows never leave the host that read them),
then ONE exchange of compressed ``StreamState`` wire images replicates the
merged global state everywhere — the mergeable-summary contract applied to
comms: what crosses hosts is the probe-gated ``wire_pack`` bytes, never the
data.

Three layers:

* ``initialize`` — gated ``jax.distributed`` setup. Resolves the
  coordinator cell from arguments or the ``REPRO_COORDINATOR`` /
  ``REPRO_NUM_PROCESSES`` / ``REPRO_PROCESS_ID`` environment (the
  tests/dist/helpers.py launch convention) and is a ``False``-returning
  no-op in single-process runs, so the same entry point serves laptops and
  fleets.
* ``host_mesh`` / ``host_shard_range`` — the process topology: a
  ``(host, device)`` 2-D mesh for the hierarchical tree-reduce in
  ``core.distributed`` (intra-host psum over local devices, then one
  inter-host all-reduce per accumulator block), and the balanced contiguous
  row range each host ingests (ragged-tolerant: the first ``d % hosts``
  hosts take one extra row).
* ``cross_host_merge`` / ``sharded_ingest`` — the inter-host exchange.
  States travel through the distributed coordinator's key-value store as
  ``wire_pack`` bytes (XLA cross-process collectives are unavailable on the
  CPU backend, and the KV store is exactly a byte wire); every host gathers
  all wire images and ``tree_merge``s them in ascending process order, so
  the merged state is **bit-identical on every host**. With ``tol`` set,
  each host votes a probe-gated ``WireSpec`` and the most conservative
  (highest-precision) vote wins — the gate stays collective-consistent.

>>> import jax
>>> host_shard_range(10, hosts=4, host=0)   # balanced, ragged-tolerant
(0, 3)
>>> host_shard_range(10, hosts=4, host=3)
(8, 10)
>>> initialize()        # no coordinator configured: single-process no-op
False
>>> process_topology()
(0, 1)
>>> from repro.core.streaming import StreamingSummarizer
>>> key = jax.random.PRNGKey(0)
>>> A = jax.random.normal(key, (40, 6))
>>> B = jax.random.normal(jax.random.fold_in(key, 1), (40, 4))
>>> state = sharded_ingest(StreamingSummarizer(k=8), key, (40, 6, 4),
...                        lambda lo, hi: (A[lo:hi], B[lo:hi]), chunk=16)
>>> int(state.rows_seen)        # single process ingests the whole range
40
"""
from __future__ import annotations

import itertools
import os
from typing import Callable, Iterator, Optional, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh


def _env_int(name: str) -> Optional[int]:
    raw = os.environ.get(name)
    return int(raw) if raw else None


def initialize(coordinator_address: Optional[str] = None,
               num_processes: Optional[int] = None,
               process_id: Optional[int] = None,
               local_device_ids=None) -> bool:
    """Initialize ``jax.distributed`` when a multi-process cell is configured.

    Arguments fall back to the ``REPRO_COORDINATOR`` (host:port),
    ``REPRO_NUM_PROCESSES``, and ``REPRO_PROCESS_ID`` environment. Without
    a coordinator, or with a single process, this is a no-op returning
    ``False`` — the caller's code path is identical either way
    (``process_topology`` then reports ``(0, 1)``).
    """
    if coordinator_address is None:
        coordinator_address = os.environ.get("REPRO_COORDINATOR")
    if num_processes is None:
        num_processes = _env_int("REPRO_NUM_PROCESSES")
    if process_id is None:
        process_id = _env_int("REPRO_PROCESS_ID")
    if coordinator_address is None or not num_processes \
            or int(num_processes) <= 1:
        return False
    jax.distributed.initialize(
        coordinator_address, int(num_processes), int(process_id or 0),
        local_device_ids=local_device_ids)
    return True


def process_topology() -> Tuple[int, int]:
    """``(process_index, process_count)`` of the running cell."""
    return jax.process_index(), jax.process_count()


def host_mesh(hosts: Optional[int] = None, *, host_axis: str = "host",
              device_axis: str = "device") -> Mesh:
    """The ``(host, device)`` 2-D mesh over all global devices.

    Pass ``axis=(host_axis, device_axis)`` into ``core.distributed`` for
    the hierarchical tree-reduce. ``hosts`` defaults to the cell's process
    count; overriding it emulates a multi-host hierarchy on one process's
    devices (how tests/dist exercise the reduce on 4 fake CPU devices).
    """
    hosts = jax.process_count() if hosts is None else int(hosts)
    devices = np.array(jax.devices())
    if hosts < 1 or len(devices) % hosts != 0:
        raise ValueError(
            f"{len(devices)} devices do not split over {hosts} hosts")
    return Mesh(devices.reshape(hosts, -1), (host_axis, device_axis))


def host_shard_range(d: int, *, hosts: Optional[int] = None,
                     host: Optional[int] = None) -> Tuple[int, int]:
    """Contiguous global row range ``[lo, hi)`` that ``host`` ingests.

    Balanced to within one row (the first ``d % hosts`` hosts take the
    extra), covering ``0..d`` exactly once across the cell — the per-host
    shard map of ``sharded_ingest``. Defaults describe the calling process.
    """
    hosts = jax.process_count() if hosts is None else int(hosts)
    host = jax.process_index() if host is None else int(host)
    if hosts < 1 or not 0 <= host < hosts:
        raise ValueError(f"host {host} outside a {hosts}-host cell")
    if d < 0:
        raise ValueError(f"row count must be non-negative, got {d}")
    base, extra = divmod(d, hosts)
    lo = host * base + min(host, extra)
    return lo, lo + base + (1 if host < extra else 0)


# one monotone sequence per process: cross_host_merge is a collective —
# every host calls it the same number of times, so sequence numbers agree
# and KV keys never collide across rounds
_MERGE_SEQ = itertools.count()


def _kv_client():
    from jax._src import distributed
    client = distributed.global_state.client
    if client is None:
        raise RuntimeError(
            "cross_host_merge needs the jax.distributed coordinator "
            "(call dist.multihost.initialize first)")
    return client


def cross_host_merge(state, *, wire: Union[str, None] = None,
                     tol: Optional[float] = None,
                     timeout: float = 60.0):
    """Merge per-host partial ``StreamState``s into the global state.

    A collective: every process calls it with its local partial state and
    every process returns the same merged state, bit-identical across the
    cell (all hosts decompress the same wire images and reduce them with
    the same ascending-process ``tree_merge``). The transfer is the
    compressed wire format — ``wire`` names a ``WireSpec`` precision
    (default lossless f32), or ``tol`` turns on the probe-measured gate:
    each host runs ``choose_wire_spec`` on its own partial state, votes,
    and the most conservative vote is used by everyone (quantized merge
    error stays within every host's measured bound). Single-process cells
    return the state unchanged — the local path stays wire-free.
    """
    if jax.process_count() == 1:
        return state
    from repro.core import streaming
    client = _kv_client()
    seq = next(_MERGE_SEQ)
    pid, nproc = jax.process_index(), jax.process_count()
    t_ms = max(1, int(timeout * 1000))
    if tol is not None:
        spec, _ = streaming.choose_wire_spec(state, tol)
    else:
        spec = streaming._as_wire_spec("f32" if wire is None else wire)
    # vote: highest precision wins, so no host's measured gate is violated
    rank = {name: i for i, name in enumerate(streaming.WIRE_DTYPES)}
    client.key_value_set(f"repro/merge/{seq}/spec/{pid}", spec.sketch)
    votes = [client.blocking_key_value_get(f"repro/merge/{seq}/spec/{i}",
                                           t_ms) for i in range(nproc)]
    spec = streaming.WireSpec(min(votes, key=lambda v: rank[v]))
    blob = streaming.wire_pack(streaming.compress_state(state, spec))
    client.key_value_set_bytes(f"repro/merge/{seq}/state/{pid}", blob)
    parts = [
        streaming.decompress_state(streaming.wire_unpack(
            client.blocking_key_value_get_bytes(
                f"repro/merge/{seq}/state/{i}", t_ms)))
        for i in range(nproc)]
    return streaming.tree_merge(parts)


def sharded_ingest(summarizer, key, shapes: Tuple[int, int, int],
                   fetch: Callable[[int, int], tuple], *,
                   chunk: int = 4096, prefetch: int = 2,
                   wire: Union[str, None] = None,
                   tol: Optional[float] = None,
                   timeout: float = 60.0):
    """Full multi-host pass: ingest this host's shard, then merge the cell.

    ``fetch(lo, hi)`` returns the ``(A_rows, B_rows)`` slab of global rows
    ``[lo, hi)`` — each host only ever fetches its own ``host_shard_range``,
    in ``chunk``-row pieces driven through the double-buffered
    ``StreamingSummarizer.ingest`` (``prefetch`` chunks staged
    host->device ahead of the fused update). The final ``cross_host_merge``
    replicates the global state on every host; ``wire``/``tol`` choose the
    transfer precision as documented there.
    """
    if not isinstance(chunk, int) or isinstance(chunk, bool) or chunk < 1:
        raise ValueError(f"chunk must be a positive row count, got {chunk!r}")
    d = shapes[0]
    lo, hi = host_shard_range(d)
    state = summarizer.init(key, shapes)

    def _chunks() -> Iterator[tuple]:
        for off in range(lo, hi, chunk):
            yield fetch(off, min(off + chunk, hi))

    state = summarizer.ingest(state, _chunks(), row_offset=lo,
                              prefetch=prefetch)
    return cross_host_merge(state, wire=wire, tol=tol, timeout=timeout)
