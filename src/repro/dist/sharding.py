"""Path-based sharding rules: parameter and KV-cache PartitionSpecs.

Rules are keyed on the pytree *path* (e.g. ``/mlp/up/w``, ``/embed/table``,
``/groups/0/0/attn/wo/w``) so model code never mentions a mesh. Every rule
applies a divisibility fallback: an axis that does not divide its mesh axes
is replicated on that dim instead (e.g. whisper's 12 heads on a 16-way
model axis).

Conventions:
* 2D weights are (d_in, d_out): d_in shards over the data-parallel axes
  (FSDP, ``policy='fsdp_tp'``), d_out over the tensor-parallel axis.
* ``embed`` tables are (vocab, d_model): vocab over TP, d_model over DP.
* Stacked layer-group leading dims (scan-over-layers) are never sharded.
* KV caches (..., B, S, KV, Dh): batch over DP; the TP axis prefers the KV
  head dim and falls back to head_dim when KV heads do not divide it.
"""
from __future__ import annotations

import math
from typing import Tuple, Union

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

Axes = Union[str, Tuple[str, ...]]


def _as_tuple(axes: Axes) -> Tuple[str, ...]:
    return (axes,) if isinstance(axes, str) else tuple(axes)


def _axes_size(mesh, axes: Axes) -> int:
    return math.prod(mesh.shape[a] for a in _as_tuple(axes))


def param_spec(mesh, path: str, shape: Tuple[int, ...], *,
               policy: str = "fsdp_tp", dp: Axes = ("data",),
               tp: str = "model") -> P:
    """PartitionSpec for one parameter leaf at ``path`` with ``shape``."""
    dp = _as_tuple(dp)
    ndim = len(shape)
    if ndim < 2:
        return P(*([None] * ndim))         # biases/scales: replicated
    spec = [None] * ndim
    din, dout = ndim - 2, ndim - 1         # leading stacked dims stay None
    if "embed" in path:
        if shape[din] % mesh.shape[tp] == 0:
            spec[din] = tp
        if shape[dout] % _axes_size(mesh, dp) == 0:
            spec[dout] = dp
        return P(*spec)
    if policy == "fsdp_tp" and shape[din] % _axes_size(mesh, dp) == 0:
        spec[din] = dp
    if shape[dout] % mesh.shape[tp] == 0:
        spec[dout] = tp
    return P(*spec)


def cache_spec(mesh, path: str, shape: Tuple[int, ...], *,
               dp: Axes = ("data",), tp: str = "model") -> P:
    """PartitionSpec for a KV-cache leaf shaped (..., B, S, KV, Dh)."""
    del path
    dp = _as_tuple(dp)
    ndim = len(shape)
    spec = [None] * ndim
    bdim, kv_dim, dh_dim = ndim - 4, ndim - 2, ndim - 1
    if bdim >= 0 and shape[bdim] % _axes_size(mesh, dp) == 0:
        spec[bdim] = dp
    tp_size = mesh.shape[tp]
    if shape[kv_dim] % tp_size == 0:
        spec[kv_dim] = tp
    elif shape[dh_dim] % tp_size == 0:
        spec[dh_dim] = tp
    return P(*spec)


def _path_str(key_path) -> str:
    parts = []
    for k in key_path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/" + "/".join(parts)


def params_shardings(mesh, shapes, *, policy: str = "fsdp_tp",
                     dp: Axes = ("data",), tp: str = "model"):
    """NamedShardings for a whole param-shapes pytree (path-based rules)."""
    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: NamedSharding(
            mesh, param_spec(mesh, _path_str(kp), leaf.shape,
                             policy=policy, dp=dp, tp=tp)),
        shapes)


def cache_shardings(mesh, cache, *, dp: Axes = ("data",), tp: str = "model"):
    """NamedShardings for a KV-cache pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: NamedSharding(
            mesh, cache_spec(mesh, _path_str(kp), leaf.shape, dp=dp, tp=tp)),
        cache)
