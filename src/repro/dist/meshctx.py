"""Process-global mesh registry.

Model code stays mesh-agnostic: launch code calls ``set_mesh`` once and
optional activation-sharding constraints look the mesh up here (returning
None — a no-op — when nothing is registered, e.g. in single-device tests).
"""
from __future__ import annotations


_MESH = None


def set_mesh(mesh) -> None:
    global _MESH
    _MESH = mesh


def get_mesh():
    return _MESH


def clear_mesh() -> None:
    global _MESH
    _MESH = None
