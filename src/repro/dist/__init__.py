"""repro.dist — mesh context + path-based sharding rules.

``meshctx``   registers the active mesh for activation constraints
              (models.transformer.constrain_act) without threading it
              through every call signature.
``sharding``  maps parameter / cache pytree paths to PartitionSpecs
              (fsdp_tp / tp_only policies, divisibility fallbacks).
"""
from repro.dist import meshctx, sharding
