"""repro.dist — mesh context, sharding rules, and multi-host ingest.

``meshctx``   registers the active mesh for activation constraints
              (models.transformer.constrain_act) without threading it
              through every call signature.
``sharding``  maps parameter / cache pytree paths to PartitionSpecs
              (fsdp_tp / tp_only policies, divisibility fallbacks).
``multihost`` jax.distributed init gate, (host, device) process topology,
              per-host shard ingestion, and the compressed cross-host
              StreamState merge (docs/streaming.md "Scale-out ingest").
"""
from repro.dist import meshctx, multihost, sharding
