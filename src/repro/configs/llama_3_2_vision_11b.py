"""llama-3.2-vision-11b [hf:meta-llama/Llama-3.2-11B-Vision; unverified].

Text backbone: 40 decoder layers with 8 gated cross-attention layers
interleaved 1-per-4 self-attn (pattern (self x4, xattn) x 8). The vision
tower is a STUB per the assignment: input_specs() provides precomputed patch
embeddings (B, n_img_tokens=1600, d)."""
from repro.configs.base import ArchConfig, register


@register("llama-3.2-vision-11b")
def config() -> ArchConfig:
    return ArchConfig(
        name="llama-3.2-vision-11b", family="vlm",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab_size=128256,
        groups=((("attn", "attn", "attn", "attn", "xattn"), 8),),
        n_img_tokens=1600,
        act="silu", gated_mlp=True, rope_theta=500000.0,
        source="hf:meta-llama/Llama-3.2-11B-Vision",
    )
