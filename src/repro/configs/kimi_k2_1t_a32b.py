"""kimi-k2-1t-a32b [arXiv:2501.kimi2; unverified] — trillion-param MoE.

Assigned spec: 61L, d=7168, 64H (GQA kv=8), expert d_ff=2048, vocab 163840,
384 experts top-8. DeepSeek-lineage details we adopt: first layer dense
(dense_d_ff=18432), 1 shared expert. The real K2 uses MLA attention; the
assignment specifies GQA kv=8, which we follow (deviation noted here and in
DESIGN.md)."""
from repro.configs.base import ArchConfig, register


@register("kimi-k2-1t-a32b")
def config() -> ArchConfig:
    return ArchConfig(
        name="kimi-k2-1t-a32b", family="moe",
        n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
        d_ff=2048, vocab_size=163840,
        groups=((("attn_dense_first",), 1), (("attn_moe",), 60)),
        head_dim=112, n_experts=384, top_k=8, n_shared_experts=1,
        dense_d_ff=18432,
        act="silu", gated_mlp=True, rope_theta=50000.0,
        source="arXiv:2501.kimi2",
    )
