"""Architecture config schema + registry.

Each assigned architecture gets one file in repro/configs/ defining an
``ArchConfig`` exactly matching the assigned hyperparameters, plus a
``reduced()`` variant used by CPU smoke tests.

Block patterns: the model is a sequence of *groups*; each group is
``(pattern, count)`` where pattern is a tuple of block-type names executed in
order, and the group repeats ``count`` times via ``lax.scan`` over stacked
params (compile time stays O(pattern), not O(layers)).
Block types: "attn" (self-attn + MLP), "attn_moe" (self-attn + MoE),
"enc" (bidirectional attn + MLP), "dec_xattn" (self + cross + MLP),
"xattn" (gated cross-attn + MLP), "rglru" (RG-LRU + MLP),
"local_attn" (windowed self-attn + MLP), "mlstm", "slstm".
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

Pattern = Tuple[Tuple[str, ...], int]


def _pad256(v: int) -> int:
    return ((v + 255) // 256) * 256


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | audio | vlm | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    groups: Tuple[Pattern, ...]       # block-pattern groups (see module doc)

    head_dim: int = 0                 # 0 -> d_model // n_heads
    norm: str = "rmsnorm"
    act: str = "silu"
    gated_mlp: bool = True
    attn_bias: bool = False
    rope_theta: Optional[float] = 10000.0
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    dense_d_ff: int = 0               # first dense layer(s) of MoE stacks
    capacity_factor: float = 1.25

    # hybrid / ssm
    window: int = 0                   # sliding-window size for local attn
    lru_width: int = 0
    proj_factor: float = 2.0          # xLSTM up-projection

    # enc-dec / vlm frontends (stubs provide precomputed embeddings)
    n_enc_layers: int = 0
    enc_context: int = 0              # whisper: 1500 frames
    n_img_tokens: int = 0             # vlm: image patch tokens

    # runtime
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"        # full | save_attn_out (hillclimb lever)
    attn_scores_dtype: str = "float32"  # float32 | bfloat16 (hillclimb lever)
    sketched_mlp: bool = False        # SMP-PCA gradient taps on MLP matmuls
    constrain_activations: bool = False  # sharding constraints in scans
    loss_chunk: int = 512             # seq-chunked softmax-xent (vocab is big)
    aux_loss_weight: float = 0.01

    # citation / provenance
    source: str = ""

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        return _pad256(self.vocab_size)

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    @property
    def supports_long_context(self) -> bool:
        """True if decode cost is O(1) in context (SSM / hybrid-window)."""
        return self.family in ("hybrid", "ssm")

    def n_params(self) -> int:
        """Analytic parameter count (embedding included once)."""
        d, dh = self.d_model, self.head_dim_
        attn = d * dh * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * dh * d
        mlp_mult = 3 if self.gated_mlp else 2
        counts = 0
        for pattern, cnt in self.groups:
            for blk in pattern:
                if blk in ("attn", "enc", "local_attn"):
                    counts += cnt * (attn + mlp_mult * d * self.d_ff)
                elif blk == "dec_xattn":
                    counts += cnt * (2 * attn + mlp_mult * d * self.d_ff)
                elif blk == "xattn":
                    counts += cnt * (attn + mlp_mult * d * self.d_ff)
                elif blk == "attn_moe":
                    e = self.n_experts * mlp_mult * d * self.d_ff
                    sh = self.n_shared_experts * mlp_mult * d * self.d_ff
                    counts += cnt * (attn + e + sh + d * self.n_experts)
                elif blk == "attn_dense_first":
                    counts += cnt * (attn + mlp_mult * d * self.dense_d_ff)
                elif blk == "rglru":
                    w = self.lru_width or d
                    counts += cnt * (2 * d * w + 2 * w * w + w * d
                                     + mlp_mult * d * self.d_ff)
                elif blk == "mlstm":
                    di = int(d * self.proj_factor)
                    counts += cnt * (2 * d * di + 3 * di * di + di * d)
                elif blk == "slstm":
                    counts += cnt * (8 * d * d + 3 * d * int(d * 4 / 3))
                else:
                    raise ValueError(blk)
        if self.n_enc_layers:
            counts += self.n_enc_layers * (attn + mlp_mult * d * self.d_ff)
        if self.n_img_tokens:
            counts += d * d           # img_proj
        embed = self.vocab_padded * d * (1 if self.tie_embeddings else 2)
        return counts + embed

    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if self.n_experts == 0:
            return self.n_params()
        full = self.n_params()
        d = self.d_model
        mlp_mult = 3 if self.gated_mlp else 2
        moe_layers = sum(cnt * pattern.count("attn_moe")
                         for pattern, cnt in self.groups)
        inactive = moe_layers * (self.n_experts - self.top_k) * mlp_mult * d * self.d_ff
        return full - inactive

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        scale = {}
        scale["d_model"] = 64
        scale["n_heads"] = 4
        scale["n_kv_heads"] = min(self.n_kv_heads, 4) if self.n_kv_heads > 1 else 1
        scale["head_dim"] = 16
        scale["d_ff"] = 128 if self.d_ff else 0
        scale["vocab_size"] = 512
        scale["groups"] = tuple((pat, min(cnt, 2)) for pat, cnt in self.groups)
        scale["n_layers"] = sum(len(p) * c for p, c in scale["groups"])
        if self.n_experts:
            scale["n_experts"] = 8
            scale["top_k"] = min(self.top_k, 2)
            scale["dense_d_ff"] = 128
        if self.window:
            scale["window"] = 32
        if self.lru_width:
            scale["lru_width"] = 64
        if self.n_enc_layers:
            scale["n_enc_layers"] = 2
            scale["enc_context"] = 16
        if self.n_img_tokens:
            scale["n_img_tokens"] = 8
        scale["loss_chunk"] = 64
        scale["remat"] = False
        return dataclasses.replace(self, **scale)


_REGISTRY: Dict[str, Callable[[], ArchConfig]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ArchConfig:
    import repro.configs.archs  # noqa: F401  (populates registry)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> Tuple[str, ...]:
    import repro.configs.archs  # noqa: F401
    return tuple(sorted(_REGISTRY))
