"""whisper-small [arXiv:2212.04356; unverified] — enc-dec audio backbone.

The conv frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings (B, 1500, d). Shapes (train/prefill/decode seq
lens) apply to the DECODER stream; the encoder always sees 1500 frames.
12 heads do not divide the 16-way model axis -> attention params replicate on
"model"; d_ff (3072 = 16*192) carries the TP (DESIGN.md §6)."""
from repro.configs.base import ArchConfig, register


@register("whisper-small")
def config() -> ArchConfig:
    return ArchConfig(
        name="whisper-small", family="audio",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
        d_ff=3072, vocab_size=51865,
        groups=((("dec_xattn",), 12),),
        n_enc_layers=12, enc_context=1500,
        norm="layernorm", act="gelu", gated_mlp=False, attn_bias=True,
        rope_theta=None,   # sinusoidal absolute positions
        source="arXiv:2212.04356",
    )
