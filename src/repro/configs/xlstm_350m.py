"""xlstm-350m [arXiv:2405.04517; unverified] — sLSTM + mLSTM blocks.

24 residual blocks alternating (mLSTM, sLSTM); d_ff=0 per the assignment
(blocks carry their own up/down projections, proj_factor=2). Linear-time
recurrence: runs the long_500k shape."""
from repro.configs.base import ArchConfig, register


@register("xlstm-350m")
def config() -> ArchConfig:
    return ArchConfig(
        name="xlstm-350m", family="ssm",
        n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab_size=50304,
        groups=((("mlstm", "slstm"), 12),),
        head_dim=256, proj_factor=2.0,
        act="gelu", gated_mlp=False, rope_theta=None,
        source="arXiv:2405.04517",
    )
