"""mistral-large-123b [hf:mistralai/Mistral-Large-Instruct-2407; unverified]."""
from repro.configs.base import ArchConfig, register


@register("mistral-large-123b")
def config() -> ArchConfig:
    return ArchConfig(
        name="mistral-large-123b", family="dense",
        n_layers=88, d_model=12288, n_heads=96, n_kv_heads=8,
        d_ff=28672, vocab_size=32768,
        groups=((("attn",), 88),),
        head_dim=128, act="silu", gated_mlp=True, rope_theta=1000000.0,
        source="hf:mistralai/Mistral-Large-Instruct-2407",
    )
