"""starcoder2-15b [arXiv:2402.19173; hf] — dense, GQA kv=4, RoPE, gelu MLP,
layernorm + attention bias (per the HF config)."""
from repro.configs.base import ArchConfig, register


@register("starcoder2-15b")
def config() -> ArchConfig:
    return ArchConfig(
        name="starcoder2-15b", family="dense",
        n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4,
        d_ff=24576, vocab_size=49152,
        groups=((("attn",), 40),),
        norm="layernorm", act="gelu_tanh", gated_mlp=False, attn_bias=True,
        rope_theta=100000.0,
        source="arXiv:2402.19173",
    )
