"""granite-3-8b [hf:ibm-granite/granite-3.0; hf] — dense GQA kv=8.

vocab 49155 is not divisible by the 16-way model axis; padded to 49408
(ArchConfig.vocab_padded) with logits masked — see DESIGN.md §6."""
from repro.configs.base import ArchConfig, register


@register("granite-3-8b")
def config() -> ArchConfig:
    return ArchConfig(
        name="granite-3-8b", family="dense",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=12800, vocab_size=49155,
        groups=((("attn",), 40),),
        act="silu", gated_mlp=True, rope_theta=10000.0,
        source="hf:ibm-granite/granite-3.0-2b-base",
    )
