"""Assigned input-shape suites (one set, shared by all 10 LM-family archs).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache / recurrent state of ``seq_len``), NOT ``train_step``; ``prefill_*``
lowers the cache-building forward. ``long_500k`` requires sub-quadratic
attention and only runs for hybrid/ssm archs (DESIGN.md §6)."""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def cell_applicable(arch_family: str, shape_name: str) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped) for an (arch, shape) cell."""
    if shape_name == "long_500k" and arch_family not in ("hybrid", "ssm"):
        return False, ("full quadratic attention at 524288 ctx "
                       "(skip per assignment; sub-quadratic archs only)")
    return True, ""
