"""moonshot-v1-16b-a3b [hf:moonshotai/Moonlight-16B-A3B; hf] — MoE 64e top-6.

Moonlight follows the DeepSeek lineage: first layer dense (dense_d_ff=11264),
2 shared experts."""
from repro.configs.base import ArchConfig, register


@register("moonshot-v1-16b-a3b")
def config() -> ArchConfig:
    return ArchConfig(
        name="moonshot-v1-16b-a3b", family="moe",
        n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1408, vocab_size=163840,
        groups=((("attn_dense_first",), 1), (("attn_moe",), 47)),
        n_experts=64, top_k=6, n_shared_experts=2, dense_d_ff=11264,
        act="silu", gated_mlp=True, rope_theta=50000.0,
        source="hf:moonshotai/Moonlight-16B-A3B",
    )
