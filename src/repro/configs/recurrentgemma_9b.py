"""recurrentgemma-9b [arXiv:2402.19427; unverified] — RG-LRU + local attn.

Griffin pattern (R, R, A) tiled 12x (36 layers) + 2 trailing recurrent
layers = 38L (the assigned count; deviation from exact-(RRA)*k noted in
DESIGN.md). Local attention window 2048, MQA (kv=1, replicated on "model").
Long-context decode is O(window + state): runs the long_500k shape."""
from repro.configs.base import ArchConfig, register


@register("recurrentgemma-9b")
def config() -> ArchConfig:
    return ArchConfig(
        name="recurrentgemma-9b", family="hybrid",
        n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
        d_ff=12288, vocab_size=256000,
        groups=((("rglru", "rglru", "local_attn"), 12), (("rglru",), 2)),
        head_dim=256, lru_width=4096, window=2048,
        act="gelu_tanh", gated_mlp=True, rope_theta=10000.0,
        source="arXiv:2402.19427",
    )
