"""phi3-mini-3.8b [arXiv:2404.14219; unverified] — dense, RoPE SwiGLU GQA."""
from repro.configs.base import ArchConfig, register


@register("phi3-mini-3.8b")
def config() -> ArchConfig:
    return ArchConfig(
        name="phi3-mini-3.8b", family="dense",
        n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab_size=32064,
        groups=((("attn",), 32),),
        act="silu", gated_mlp=True, rope_theta=10000.0,
        source="arXiv:2404.14219",
    )
