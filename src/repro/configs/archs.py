"""Imports every per-arch module so the registry is populated."""
import repro.configs.phi3_mini_3_8b      # noqa: F401
import repro.configs.starcoder2_15b      # noqa: F401
import repro.configs.granite_3_8b        # noqa: F401
import repro.configs.mistral_large_123b  # noqa: F401
import repro.configs.whisper_small       # noqa: F401
import repro.configs.kimi_k2_1t_a32b     # noqa: F401
import repro.configs.moonshot_v1_16b_a3b # noqa: F401
import repro.configs.llama_3_2_vision_11b # noqa: F401
import repro.configs.recurrentgemma_9b   # noqa: F401
import repro.configs.xlstm_350m          # noqa: F401
