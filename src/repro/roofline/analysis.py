"""Roofline terms from a compiled dry-run artifact (TPU v5e targets).

    compute    = HLO_FLOPs / (chips * 197e12)        [bf16 MXU peak]
    memory     = HLO_bytes / (chips * 819e9)         [HBM bandwidth]
    collective = coll_bytes / (chips * 50e9)         [per-link ICI]

``cost_analysis`` on the SPMD-partitioned module reports per-device flops /
bytes, so terms divide by ONE chip's peak; we cross-check against analytic
6*N*D (the MODEL_FLOPS utility column catches remat recompute and padding
waste). Collective bytes are not in cost_analysis: we parse the partitioned
HLO text and sum operand bytes over all-gather / all-reduce / reduce-scatter
/ all-to-all / collective-permute ops.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of all tensors in an HLO shape string (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    total_bytes: int
    by_op: Dict[str, int]
    count: int

    def as_dict(self):
        return {"total_bytes": self.total_bytes, "by_op": self.by_op,
                "count": self.count}


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum output-shape bytes of every collective op in partitioned HLO.

    HLO line form:  %name = <shape> <op>(<operands>), ...
    The output shape of an all-gather/all-reduce equals (or bounds) the
    moved payload per device; start-ops (async) are counted, done-ops
    skipped (same buffer, avoids double counting).
    """
    by_op: Dict[str, int] = {}
    count = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        if not s or "=" not in s:
            continue
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[^\s]+)\s+([\w\-]+)", s)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        base = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-start") or op == c + "-start":
                base = c
                break
            if op.startswith(c) and "done" in op:
                base = None
                break
        if base is None:
            continue
        b = _shape_bytes(shape_str)
        by_op[base] = by_op.get(base, 0) + b
        count += 1
    return CollectiveStats(sum(by_op.values()), by_op, count)


@dataclasses.dataclass
class Roofline:
    flops: float                 # per-device
    bytes_accessed: float        # per-device
    coll_bytes: float            # per-device
    model_flops_per_device: float
    chips: int

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        ts = {"compute": self.t_compute, "memory": self.t_memory,
              "collective": self.t_collective}
        return max(ts, key=ts.get)

    @property
    def step_time(self) -> float:
        """Lower-bound step time assuming perfect overlap: max of terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (remat & padding waste show up here)."""
        return self.model_flops_per_device / max(self.flops, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """Achievable MFU bound: useful compute time / step time."""
        t_useful = self.model_flops_per_device / PEAK_FLOPS
        return t_useful / max(self.step_time, 1e-30)

    def as_dict(self):
        return {
            "flops_per_device": self.flops,
            "bytes_per_device": self.bytes_accessed,
            "coll_bytes_per_device": self.coll_bytes,
            "model_flops_per_device": self.model_flops_per_device,
            "chips": self.chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "step_time_lb_s": self.step_time,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops(kind: str, n_active_params: int, tokens: int,
                enc_extra: int = 0) -> float:
    """Analytic MODEL_FLOPS: 6*N*D train, 2*N*D inference (per step)."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active_params * tokens + enc_extra


def kernel_time_lb(flops: float, hbm_bytes: float, *,
                   peak_flops: float = PEAK_FLOPS, hbm_bw: float = HBM_BW,
                   steps: int = 1, step_overhead: float = 0.0) -> float:
    """Roofline lower bound for ONE kernel call: perfect compute/memory
    overlap (max of the two terms, same assumption as ``Roofline.step_time``)
    plus a fixed per-grid-step dispatch overhead. This is the scalar the
    kernel autotuner (``repro.kernels.tuning``) ranks candidate block
    configs on — callers derate ``peak_flops`` by MXU tile occupancy."""
    return max(flops / peak_flops, hbm_bytes / hbm_bw) \
        + steps * step_overhead
