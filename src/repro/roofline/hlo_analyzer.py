"""Trip-count-aware static cost analysis of optimized HLO text.

XLA's built-in ``compiled.cost_analysis()`` counts a ``while`` body ONCE —
with scan-over-layers (and chunked attention / loss chunking / microbatch
scans) that undercounts flops, bytes, and collective payloads by the trip
counts. This analyzer walks the HLO text, recovers static trip counts from
each loop's condition (induction variable compared against a constant), and
accumulates per-op costs with the correct multipliers:

  flops:  dot = 2 * prod(out) * prod(contracting dims of lhs);
          elementwise/reduce = output (resp. input) element count
          (counted inside fusion computations too);
  bytes:  operands + outputs of *top-level* ops (fusion internals excluded —
          they live in registers/VMEM), with dynamic-update-slice, gather and
          scatter special-cased to the slice/update size (XLA in-places them);
  collectives: payload bytes per op kind, x trip counts of enclosing loops.

Validated against XLA's own cost analysis on loop-free programs and against
hand-counted scanned matmuls (tests/launch/test_hlo_analyzer.py).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(
    r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\](?:\{[^}]*\})?")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "tanh", "rsqrt", "sqrt", "power", "and", "or", "xor", "not", "select",
    "compare", "convert", "floor", "ceil", "round-nearest-afz", "sign",
    "cosine", "sine", "clamp", "remainder", "atan2", "erf", "logistic",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "cbrt", "is-finite", "expm1", "log1p",
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-_]+)\s*\(")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-_]+)\s*=\s*((?:\([^=]*?\)|[^\s]+))\s+"
    r"([\w\-]+)\((.*)$")


def _shape_elems_bytes(shape_str: str) -> Tuple[int, int]:
    elems = 0
    nbytes = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


@dataclasses.dataclass
class Op:
    name: str
    shape_str: str
    opcode: str
    rest: str       # operand list + attributes (raw tail of the line)

    @property
    def out_elems(self) -> int:
        return _shape_elems_bytes(self.shape_str)[0]

    @property
    def out_bytes(self) -> int:
        return _shape_elems_bytes(self.shape_str)[1]


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    is_fusion_body: bool = False


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_op: Dict[str, float] = dataclasses.field(default_factory=dict)

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.coll_bytes += o.coll_bytes
        for k, v in o.coll_by_op.items():
            self.coll_by_op[k] = self.coll_by_op.get(k, 0.0) + v
        return self

    def scaled(self, f: float) -> "Cost":
        return Cost(self.flops * f, self.bytes * f, self.coll_bytes * f,
                    {k: v * f for k, v in self.coll_by_op.items()})


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        if cur is None:
            s = line.rstrip()
            # computation headers start at column 0 and end with '{'
            if s.endswith("{") and "->" in s and not line.startswith(" "):
                m = _COMP_HDR.match(s)
                if m:
                    name = m.group(2)
                    cur = Computation(name, [],
                                      is_fusion_body="fused" in name)
            continue
        s = line.strip()
        if s == "}" or s.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        # tuple shapes embed /*index=N*/ comments whose '=' breaks parsing
        if "/*" in line:
            line = re.sub(r"/\*.*?\*/", "", line)
        m = _OP_RE.match(line)
        if m:
            cur.ops.append(Op(m.group(1), m.group(2), m.group(3), m.group(4)))
    if cur is not None:
        comps[cur.name] = cur
    return comps


def _called_comp(rest: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w.\-_]+)", rest)
    return m.group(1) if m else None


def _operand_section(rest: str) -> str:
    """The operand list: everything before the closing paren of the op."""
    depth = 0
    end = len(rest)
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            if depth == 0:
                end = i
                break
            depth -= 1
    return rest[:end]


def _operand_shapes(rest: str, symtab: Optional[Dict[str, str]] = None
                    ) -> List[str]:
    """Shape strings of the operands. The optimized-HLO printer usually
    omits inline operand shapes, so fall back to the computation's symbol
    table (op name -> result shape)."""
    args = _operand_section(rest)
    inline = [m.group(0) for m in _SHAPE_RE.finditer(args)]
    if inline:
        return inline
    if symtab is None:
        return []
    names = re.findall(r"%([\w.\-_]+)", args)
    return [symtab[n] for n in names if n in symtab]


def _dot_flops(op: Op, symtab: Dict[str, str]) -> float:
    shapes = _operand_shapes(op.rest, symtab)
    lhs = shapes[0] if shapes else ""
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    contract = 1
    if m and lhs:
        dims_m = _SHAPE_RE.search(lhs)
        if dims_m and dims_m.group(2):
            lhs_dims = [int(x) for x in dims_m.group(2).split(",")]
            for ci in m.group(1).split(","):
                if ci:
                    contract *= lhs_dims[int(ci)]
    return 2.0 * op.out_elems * contract


def _trip_count(while_op: Op, comps: Dict[str, Computation]) -> int:
    """Trip count: prefer the backend_config known_trip_count annotation,
    else the largest positive constant in the condition computation (jax
    scans compare the 0-based induction variable against the length)."""
    m = re.search(r'known_trip_count[^0-9]*(\d+)', while_op.rest)
    if m:
        return int(m.group(1))
    cond_name = _called_comp(while_op.rest, "condition")
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    best = 1
    for op in cond.ops:
        if op.opcode == "constant":
            mm = re.match(r"\(?\s*(-?\d+)", op.rest)
            if mm and int(mm.group(1)) > best:
                best = int(mm.group(1))
    return best


class Analyzer:
    def __init__(self, hlo: str):
        self.comps = parse_computations(hlo)
        self.entry = next((c for c in self.comps.values()
                           if re.match(r"main", c.name)), None)
        if self.entry is None:  # fall back: the last computation
            names = list(self.comps)
            self.entry = self.comps[names[-1]] if names else Computation("", [])
        self._memo: Dict[Tuple[str, bool], Cost] = {}
        self._fusion_reads: Dict[str, Dict[int, float]] = {}

    # ------------------------------------------------------------------
    def _param_reads(self, comp_name: str) -> Dict[int, float]:
        """Per-parameter read-byte estimate for a fused computation.

        XLA fuses dynamic-slice/gather into consumers: the fusion's parameter
        is the WHOLE buffer but only a slice is read per execution. If every
        use of a parameter inside the fusion is a slicing op, charge the
        slice bytes; otherwise the full parameter. A parameter that is the
        in-place target of a root dynamic-update-slice is aliased: charge the
        update size (write side is handled by the caller via out bytes)."""
        if comp_name in self._fusion_reads:
            return self._fusion_reads[comp_name]
        comp = self.comps.get(comp_name)
        reads: Dict[int, float] = {}
        if comp is None:
            self._fusion_reads[comp_name] = reads
            return reads
        params: Dict[str, Tuple[int, int]] = {}   # name -> (index, bytes)
        for op in comp.ops:
            if op.opcode == "parameter":
                m = re.match(r"\s*(\d+)", op.rest)
                idx = int(m.group(1)) if m else len(params)
                params[op.name] = (idx, op.out_bytes)
        slicing = {"dynamic-slice", "slice", "gather"}
        # convert/bitcast/copy are aliases on TPU (fused into consumers):
        # track them so a param read only through alias->slice chains is
        # charged the slice size, not the full buffer.
        alias_of: Dict[str, str] = {}
        use_bytes: Dict[str, List[float]] = {n: [] for n in params}
        full: Dict[str, bool] = {n: False for n in params}

        def resolve(n: str) -> Optional[str]:
            seen = set()
            while n in alias_of and n not in seen:
                seen.add(n)
                n = alias_of[n]
            return n if n in params else None

        for op in comp.ops:
            if op.opcode == "parameter":
                continue
            names = re.findall(r"%([\w.\-_]+)", _operand_section(op.rest))
            if op.opcode in ("convert", "bitcast", "copy") and len(names) == 1:
                root = names[0] if names[0] in params else \
                    (resolve(names[0]) or names[0])
                alias_of[op.name] = names[0]
                continue
            for n in names:
                root = n if n in params else resolve(n)
                if root is None:
                    continue
                if op.opcode in slicing:
                    use_bytes[root].append(float(op.out_bytes))
                elif op.opcode == "dynamic-update-slice" and \
                        names and (names[0] == n):
                    # aliased in-place target: reads ~ update size
                    use_bytes[root].append(0.0)
                else:
                    full[root] = True
        for n, (idx, nbytes) in params.items():
            if full[n]:
                reads[idx] = float(nbytes)
            elif use_bytes[n]:
                reads[idx] = float(sum(use_bytes[n]))
            else:
                reads[idx] = float(nbytes)   # unused/unknown: conservative
        self._fusion_reads[comp_name] = reads
        return reads

    def _fusion_io_bytes(self, op: Op, called: Optional[str],
                         symtab: Dict[str, str]) -> float:
        reads = self._param_reads(called) if called else {}
        names = re.findall(r"%([\w.\-_]+)", _operand_section(op.rest))
        total = 0.0
        for i, n in enumerate(names):
            if i in reads:
                total += reads[i]
            elif n in symtab:
                total += _shape_elems_bytes(symtab[n])[1]
        # output: a root dynamic-update-slice is in-placed -> update bytes
        # (following convert/copy/bitcast wrappers around the root)
        comp = self.comps.get(called or "")
        root_dus = None
        if comp and comp.ops:
            by_name = {o.name: o for o in comp.ops}
            root = comp.ops[-1]
            for _ in range(4):
                if root.opcode in ("convert", "copy", "bitcast"):
                    names = re.findall(r"%([\w.\-_]+)",
                                       _operand_section(root.rest))
                    if names and names[0] in by_name:
                        root = by_name[names[0]]
                        continue
                break
            if root.opcode == "dynamic-update-slice":
                shapes = _operand_shapes(
                    root.rest, {o.name: o.shape_str for o in comp.ops})
                if len(shapes) > 1:
                    root_dus = _shape_elems_bytes(shapes[1])[1]
        total += root_dus if root_dus is not None else op.out_bytes
        return total

    def cost(self) -> Cost:
        return self._comp_cost(self.entry.name, top_level=True)

    # ------------------------------------------------------------------
    def _comp_cost(self, name: str, top_level: bool) -> Cost:
        key = (name, top_level)
        if key in self._memo:
            return self._memo[key]
        comp = self.comps.get(name)
        total = Cost()
        if comp is None:
            return total
        symtab = {op.name: op.shape_str for op in comp.ops}
        for op in comp.ops:
            total += self._op_cost(op, symtab, top_level)
        self._memo[key] = total
        return total

    def _op_cost(self, op: Op, symtab: Dict[str, str],
                 top_level: bool) -> Cost:
        oc = op.opcode
        c = Cost()

        if oc == "while":
            body = _called_comp(op.rest, "body")
            trip = _trip_count(op, self.comps)
            inner = self._comp_cost(body, top_level=True) if body else Cost()
            return inner.scaled(trip)
        if oc == "fusion":
            called = _called_comp(op.rest, "calls")
            inner = self._comp_cost(called, top_level=False) if called else Cost()
            c.flops = inner.flops
            c.coll_bytes = inner.coll_bytes
            c.coll_by_op = dict(inner.coll_by_op)
            if top_level:
                c.bytes = self._fusion_io_bytes(op, called, symtab)
            return c
        if oc in ("call", "conditional", "async-start"):
            for keyn in ("to_apply", "calls", "branch_computations",
                         "called_computation"):
                called = _called_comp(op.rest, keyn)
                if called:
                    return self._comp_cost(called, top_level)
            return c

        # ---- collectives -------------------------------------------------
        for coll in _COLLECTIVES:
            if oc == coll or oc == coll + "-start":
                c.coll_bytes = float(op.out_bytes)
                c.coll_by_op[coll] = float(op.out_bytes)
                if top_level:
                    c.bytes = float(op.out_bytes) * 2
                return c
        if any(oc.startswith(coll) and oc.endswith("-done")
               for coll in _COLLECTIVES):
            return c

        # ---- flops -------------------------------------------------------
        if oc == "dot":
            c.flops = _dot_flops(op, symtab)
        elif oc in _ELEMENTWISE:
            c.flops = float(op.out_elems)
        elif oc in ("reduce", "reduce-window"):
            ins = sum(_shape_elems_bytes(s)[0]
                      for s in _operand_shapes(op.rest, symtab)) / 2
            c.flops = float(max(ins, op.out_elems))
        elif oc == "convolution":
            # rough: 2 * out_elems * (kernel elems) — no convs in our models
            c.flops = 2.0 * op.out_elems

        # ---- bytes (top level only; fusion internals are on-chip) --------
        if top_level:
            if oc == "dynamic-update-slice":
                shapes = _operand_shapes(op.rest, symtab)
                upd = _shape_elems_bytes(shapes[1])[1] if len(shapes) > 1 else 0
                c.bytes = 2.0 * upd
            elif oc in ("gather", "dynamic-slice"):
                c.bytes = 2.0 * op.out_bytes
            elif oc == "scatter":
                shapes = _operand_shapes(op.rest, symtab)
                upd = _shape_elems_bytes(shapes[-1])[1] if shapes else 0
                c.bytes = 2.0 * upd
            elif oc in ("dot", "concatenate", "pad", "sort", "reverse",
                        "convolution", "select-and-scatter"):
                # genuine HBM movers even under TPU fusion: matmul operands/
                # outputs and data-rearranging ops
                opb = sum(_shape_elems_bytes(s)[1]
                          for s in _operand_shapes(op.rest, symtab))
                c.bytes = float(opb + op.out_bytes)
            else:
                # TPU fusion model: elementwise / select / reduce / broadcast
                # / transpose / reshape / convert / copy chains fuse into
                # producers+consumers and never round-trip HBM. The CPU
                # backend materializes them as top-level ops; charging them
                # would triple-count every dot-adjacent tensor (documented
                # CPU-vs-TPU delta; see DESIGN.md §9 and tests).
                c.bytes = 0.0
        return c


def analyze(hlo: str) -> Cost:
    return Analyzer(hlo).cost()
