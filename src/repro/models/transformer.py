"""Unified LM assembly: decoder-only / enc-dec / VLM / hybrid / ssm.

A model is a sequence of *groups*; each group is (pattern, count) where the
pattern is a tuple of block types. Params for a group are stacked over count
and executed with ``lax.scan`` (compile time O(|pattern|), not O(layers) —
essential for the 88-layer/61-layer dry-runs on this 1-core container).

Block interface (see BLOCKS):
    init(key, cfg)                       -> params
    seq(p, cfg, x, ctx)                  -> (x, aux_loss)          # no cache
    prefill(p, cfg, x, ctx)              -> (x, aux, cache)
    cache_init(cfg, batch, max_len)      -> cache
    step(p, cfg, x_t, cache, pos, ctx)   -> (x_t, new_cache)

ctx carries positions and the cross-attention context (encoder output or
image patch embeddings — both stubs feed precomputed embeddings by
assignment).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec
from jax.ad_checkpoint import checkpoint_name as _checkpoint_name
from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import common, moe, rglru, xlstm
from repro.train import sketched_dense as sd

Params = Dict[str, Any]


def _cdtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32


def _pdtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32


def _sdtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.attn_scores_dtype == "bfloat16" else jnp.float32


def constrain_act(cfg: ArchConfig, x, spec):
    """Optional activation sharding constraint (hillclimb lever: keeps the
    batch axis sharded through recurrent scans where GSPMD otherwise
    replicates; no-op unless cfg.constrain_activations and a mesh is
    registered via repro.dist.meshctx)."""
    if not cfg.constrain_activations:
        return x
    from repro.dist import meshctx
    from jax.sharding import NamedSharding
    mesh = meshctx.get_mesh()
    if mesh is None:
        return x
    resolved = tuple(s if (s is None or s in mesh.axis_names) else None
                     for s in spec)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec(*resolved)))


# ===========================================================================
# Block implementations
# ===========================================================================

class _AttnBlock:
    """Pre-norm self-attention + MLP. Variants: causal/bidirectional/windowed,
    dense-MLP-size override (MoE stacks' first dense layer)."""

    def __init__(self, causal=True, window_attr=None, d_ff_attr="d_ff"):
        self.causal = causal
        self.window_attr = window_attr
        self.d_ff_attr = d_ff_attr

    def _window(self, cfg):
        return getattr(cfg, self.window_attr) if self.window_attr else None

    def init(self, key, cfg: ArchConfig) -> Params:
        k1, k2, k3, k4 = jax.random.split(key, 4)
        d_ff = getattr(cfg, self.d_ff_attr) or cfg.d_ff
        mlp = common.mlp_init(k2, cfg.d_model, d_ff, gated=cfg.gated_mlp,
                              dtype=_pdtype(cfg), bias=cfg.attn_bias)
        if cfg.sketched_mlp:
            # SMP-PCA gradient taps on the (flop-dominant) MLP matmuls: the
            # backward pass emits one-pass (X, dY) sketches instead of dW
            tk = sd.TapConfig().sketch_k
            mlp["up"]["taps"] = sd.tap_init(cfg.d_model, d_ff, tk)
            mlp["down"]["taps"] = sd.tap_init(d_ff, cfg.d_model, tk)
        return {
            "norm1": common.norm_init(cfg.norm, cfg.d_model),
            "attn": attn.attn_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                   cfg.head_dim_, dtype=_pdtype(cfg),
                                   bias=cfg.attn_bias),
            "norm2": common.norm_init(cfg.norm, cfg.d_model),
            "mlp": mlp,
        }

    def _attend(self, p, cfg, x, ctx, cache=None, pos=None, build_cache=False):
        cd = _cdtype(cfg)
        h = common.norm_apply(cfg.norm, p["norm1"], x)
        q, k, v = attn.qkv_project(p["attn"], h.astype(cd), cfg.n_heads,
                                   cfg.n_kv_heads, cfg.head_dim_,
                                   ctx["positions"], cfg.rope_theta, cd)
        if cache is not None and not build_cache:        # decode
            cache = attn.cache_update(cache, k, v, pos,
                                      ring=self._window(cfg) is not None)
            o = attn.decode_attention(q, cache, pos, window=self._window(cfg))
        else:
            o = attn.attention(q, k, v, causal=self.causal,
                               window=self._window(cfg),
                               scores_dtype=_sdtype(cfg))
        B, S = x.shape[:2]
        o = o.reshape(B, S, cfg.n_heads * cfg.head_dim_)
        o = common.dense_apply(p["attn"]["wo"], o.astype(cd), cd)
        new_cache = cache
        if build_cache:
            # write prompt KV into the preallocated cache at offset 0. For
            # ring (window) caches we keep the last `window` tokens; ring
            # slots align because the shape suites use S % window == 0 (or
            # S < window, where the ring is simply partially filled).
            w = self._window(cfg)
            L = cache["k"].shape[1]
            kk, vv = (k[:, -L:], v[:, -L:]) if (w and S > L) else (k, v)
            new_cache = {
                "k": jax.lax.dynamic_update_slice(
                    cache["k"], kk.astype(cache["k"].dtype), (0, 0, 0, 0)),
                "v": jax.lax.dynamic_update_slice(
                    cache["v"], vv.astype(cache["v"].dtype), (0, 0, 0, 0)),
            }
        return o, new_cache

    def seq(self, p, cfg, x, ctx):
        o, _ = self._attend(p, cfg, x, ctx)
        o = _checkpoint_name(o, "attn_out")
        x = x + o
        h = common.norm_apply(cfg.norm, p["norm2"], x).astype(_cdtype(cfg))
        if cfg.sketched_mlp and "taps" in p["mlp"]["up"]:
            d_ff_mlp = _sketched_mlp_apply(p["mlp"], h, cfg, ctx)
        else:
            d_ff_mlp = common.mlp_apply(p["mlp"], h, cfg.act, _cdtype(cfg))
        return x + d_ff_mlp, jnp.float32(0.0)

    def prefill(self, p, cfg, x, ctx, cache):
        o, cache = self._attend(p, cfg, x, ctx, cache=cache, build_cache=True)
        x = x + o
        x = x + common.mlp_apply(
            p["mlp"], common.norm_apply(cfg.norm, p["norm2"], x).astype(_cdtype(cfg)),
            cfg.act, _cdtype(cfg))
        return x, jnp.float32(0.0), cache

    def cache_init(self, cfg, batch, max_len):
        L = min(self._window(cfg) or max_len, max_len)
        return attn.init_kv_cache(batch, L, cfg.n_kv_heads, cfg.head_dim_,
                                  _cdtype(cfg))

    def step(self, p, cfg, x, cache, pos, ctx):
        o, cache = self._attend(p, cfg, x, ctx, cache=cache, pos=pos)
        x = x + o
        x = x + common.mlp_apply(
            p["mlp"], common.norm_apply(cfg.norm, p["norm2"], x).astype(_cdtype(cfg)),
            cfg.act, _cdtype(cfg))
        return x, cache


class _MoEBlock(_AttnBlock):
    """Self-attention + MoE FFN (expert-parallel)."""

    def init(self, key, cfg: ArchConfig) -> Params:
        k1, k2 = jax.random.split(key)
        return {
            "norm1": common.norm_init(cfg.norm, cfg.d_model),
            "attn": attn.attn_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                   cfg.head_dim_, dtype=_pdtype(cfg),
                                   bias=cfg.attn_bias),
            "norm2": common.norm_init(cfg.norm, cfg.d_model),
            "moe": moe.moe_init(k2, cfg.d_model, cfg.d_ff, cfg.n_experts,
                                n_shared=cfg.n_shared_experts,
                                gated=cfg.gated_mlp, dtype=_pdtype(cfg)),
        }

    def _ffn(self, p, cfg, x):
        h = common.norm_apply(cfg.norm, p["norm2"], x)
        out, aux = moe.moe_apply(p["moe"], h, top_k=cfg.top_k,
                                 capacity_factor=cfg.capacity_factor,
                                 act=cfg.act, compute_dtype=_cdtype(cfg))
        return x + out, aux

    def seq(self, p, cfg, x, ctx):
        o, _ = self._attend(p, cfg, x, ctx)
        return self._ffn(p, cfg, x + o)

    def prefill(self, p, cfg, x, ctx, cache):
        o, cache = self._attend(p, cfg, x, ctx, cache=cache, build_cache=True)
        x, aux = self._ffn(p, cfg, x + o)
        return x, aux, cache

    def step(self, p, cfg, x, cache, pos, ctx):
        o, cache = self._attend(p, cfg, x, ctx, cache=cache, pos=pos)
        x, _ = self._ffn(p, cfg, x + o)
        return x, cache


class _CrossBlock:
    """Gated cross-attention + MLP (VLM interleaved layers). The KV side is a
    static context (image patches); its projections are cached at prefill."""

    def init(self, key, cfg: ArchConfig) -> Params:
        k1, k2 = jax.random.split(key)
        return {
            "norm1": common.norm_init(cfg.norm, cfg.d_model),
            "attn": attn.attn_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                   cfg.head_dim_, dtype=_pdtype(cfg)),
            "gate_attn": jnp.zeros((), jnp.float32),
            "norm2": common.norm_init(cfg.norm, cfg.d_model),
            "mlp": common.mlp_init(k2, cfg.d_model, cfg.d_ff,
                                   gated=cfg.gated_mlp, dtype=_pdtype(cfg)),
            "gate_mlp": jnp.zeros((), jnp.float32),
        }

    def _cross_kv(self, p, cfg, ctx_seq):
        cd = _cdtype(cfg)
        B, L, _ = ctx_seq.shape
        k = common.dense_apply(p["attn"]["wk"], ctx_seq.astype(cd), cd) \
            .reshape(B, L, cfg.n_kv_heads, cfg.head_dim_)
        v = common.dense_apply(p["attn"]["wv"], ctx_seq.astype(cd), cd) \
            .reshape(B, L, cfg.n_kv_heads, cfg.head_dim_)
        return k.astype(cd), v.astype(cd)

    def _cross(self, p, cfg, x, k, v):
        cd = _cdtype(cfg)
        B, S, _ = x.shape
        h = common.norm_apply(cfg.norm, p["norm1"], x)
        q = common.dense_apply(p["attn"]["wq"], h.astype(cd), cd) \
            .reshape(B, S, cfg.n_heads, cfg.head_dim_)
        o = attn.dense_attention(q, k, v, causal=False)
        o = o.reshape(B, S, cfg.n_heads * cfg.head_dim_)
        o = common.dense_apply(p["attn"]["wo"], o.astype(cd), cd)
        return jnp.tanh(p["gate_attn"]) * o

    def _mlp(self, p, cfg, x):
        h = common.mlp_apply(
            p["mlp"], common.norm_apply(cfg.norm, p["norm2"], x).astype(_cdtype(cfg)),
            cfg.act, _cdtype(cfg))
        return jnp.tanh(p["gate_mlp"]) * h

    def seq(self, p, cfg, x, ctx):
        k, v = self._cross_kv(p, cfg, ctx["xattn_ctx"])
        x = x + self._cross(p, cfg, x, k, v)
        return x + self._mlp(p, cfg, x), jnp.float32(0.0)

    def prefill(self, p, cfg, x, ctx, cache):
        k, v = self._cross_kv(p, cfg, ctx["xattn_ctx"])
        x = x + self._cross(p, cfg, x, k, v)
        x = x + self._mlp(p, cfg, x)
        return x, jnp.float32(0.0), {"k": k.astype(cache["k"].dtype),
                                     "v": v.astype(cache["v"].dtype)}

    def cache_init(self, cfg, batch, max_len):
        L = cfg.n_img_tokens or cfg.enc_context
        return attn.init_kv_cache(batch, L, cfg.n_kv_heads, cfg.head_dim_,
                                  _cdtype(cfg))

    def step(self, p, cfg, x, cache, pos, ctx):
        x = x + self._cross(p, cfg, x, cache["k"], cache["v"])
        return x + self._mlp(p, cfg, x), cache


class _DecXAttnBlock(_AttnBlock):
    """Whisper decoder layer: causal self-attn + cross-attn(enc) + MLP."""

    def init(self, key, cfg: ArchConfig) -> Params:
        p = super().init(key, cfg)
        k = jax.random.fold_in(key, 99)
        p["normx"] = common.norm_init(cfg.norm, cfg.d_model)
        p["xattn"] = attn.attn_init(k, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                    cfg.head_dim_, dtype=_pdtype(cfg),
                                    bias=cfg.attn_bias)
        return p

    def _enc_kv(self, p, cfg, enc):
        cd = _cdtype(cfg)
        B, L, _ = enc.shape
        k = common.dense_apply(p["xattn"]["wk"], enc.astype(cd), cd) \
            .reshape(B, L, cfg.n_kv_heads, cfg.head_dim_)
        v = common.dense_apply(p["xattn"]["wv"], enc.astype(cd), cd) \
            .reshape(B, L, cfg.n_kv_heads, cfg.head_dim_)
        return k, v

    def _xattend(self, p, cfg, x, k, v):
        cd = _cdtype(cfg)
        B, S, _ = x.shape
        h = common.norm_apply(cfg.norm, p["normx"], x)
        q = common.dense_apply(p["xattn"]["wq"], h.astype(cd), cd) \
            .reshape(B, S, cfg.n_heads, cfg.head_dim_)
        o = attn.dense_attention(q, k, v, causal=False)
        o = o.reshape(B, S, cfg.n_heads * cfg.head_dim_)
        return common.dense_apply(p["xattn"]["wo"], o.astype(cd), cd)

    def seq(self, p, cfg, x, ctx):
        o, _ = self._attend(p, cfg, x, ctx)
        x = x + o
        k, v = self._enc_kv(p, cfg, ctx["xattn_ctx"])
        x = x + self._xattend(p, cfg, x, k, v)
        x = x + common.mlp_apply(
            p["mlp"], common.norm_apply(cfg.norm, p["norm2"], x).astype(_cdtype(cfg)),
            cfg.act, _cdtype(cfg))
        return x, jnp.float32(0.0)

    def prefill(self, p, cfg, x, ctx, cache):
        o, self_cache = self._attend(p, cfg, x, ctx, cache=cache["self"],
                                     build_cache=True)
        x = x + o
        k, v = self._enc_kv(p, cfg, ctx["xattn_ctx"])
        x = x + self._xattend(p, cfg, x, k, v)
        x = x + common.mlp_apply(
            p["mlp"], common.norm_apply(cfg.norm, p["norm2"], x).astype(_cdtype(cfg)),
            cfg.act, _cdtype(cfg))
        cross = {"k": k.astype(cache["cross"]["k"].dtype),
                 "v": v.astype(cache["cross"]["v"].dtype)}
        return x, jnp.float32(0.0), {"self": self_cache, "cross": cross}

    def cache_init(self, cfg, batch, max_len):
        return {"self": attn.init_kv_cache(batch, max_len, cfg.n_kv_heads,
                                           cfg.head_dim_, _cdtype(cfg)),
                "cross": attn.init_kv_cache(batch, cfg.enc_context,
                                            cfg.n_kv_heads, cfg.head_dim_,
                                            _cdtype(cfg))}

    def step(self, p, cfg, x, cache, pos, ctx):
        o, self_cache = self._attend(p, cfg, x, ctx, cache=cache["self"], pos=pos)
        x = x + o
        x = x + self._xattend(p, cfg, x, cache["cross"]["k"], cache["cross"]["v"])
        x = x + common.mlp_apply(
            p["mlp"], common.norm_apply(cfg.norm, p["norm2"], x).astype(_cdtype(cfg)),
            cfg.act, _cdtype(cfg))
        return x, {"self": self_cache, "cross": cache["cross"]}


class _RGLRUBlock:
    """RecurrentGemma block: RG-LRU mixer + MLP, both pre-norm residual."""

    def init(self, key, cfg: ArchConfig) -> Params:
        k1, k2 = jax.random.split(key)
        return {
            "norm1": common.norm_init(cfg.norm, cfg.d_model),
            "lru": rglru.rglru_init(k1, cfg.d_model, cfg.lru_width or cfg.d_model,
                                    dtype=_pdtype(cfg)),
            "norm2": common.norm_init(cfg.norm, cfg.d_model),
            "mlp": common.mlp_init(k2, cfg.d_model, cfg.d_ff,
                                   gated=cfg.gated_mlp, dtype=_pdtype(cfg)),
        }

    def seq(self, p, cfg, x, ctx):
        h = common.norm_apply(cfg.norm, p["norm1"], x)
        x = x + rglru.rglru_block_seq(p["lru"], h, _cdtype(cfg))
        x = x + common.mlp_apply(
            p["mlp"], common.norm_apply(cfg.norm, p["norm2"], x).astype(_cdtype(cfg)),
            cfg.act, _cdtype(cfg))
        return x, jnp.float32(0.0)

    def prefill(self, p, cfg, x, ctx, cache):
        # run the sequence in parallel form, hand the final state to decode
        h = common.norm_apply(cfg.norm, p["norm1"], x)
        cd = _cdtype(cfg)
        gate = jax.nn.gelu(common.dense_apply(p["lru"]["w_gate_branch"], h, cd))
        xin = common.dense_apply(p["lru"]["w_in"], h, cd)
        xc, conv_state = rglru._causal_conv(
            p["lru"]["conv_w"].astype(jnp.float32), xin)
        y, h_final = rglru.rglru_seq(p["lru"], xc, compute_dtype=cd)
        o = common.dense_apply(p["lru"]["w_out"], (y * gate).astype(cd), cd)
        x = x + o
        x = x + common.mlp_apply(
            p["mlp"], common.norm_apply(cfg.norm, p["norm2"], x).astype(cd),
            cfg.act, cd)
        new_cache = {"h": h_final, "conv": conv_state.astype(cache["conv"].dtype)}
        return x, jnp.float32(0.0), new_cache

    def cache_init(self, cfg, batch, max_len):
        return rglru.rglru_block_cache_init(batch, cfg.lru_width or cfg.d_model,
                                            _cdtype(cfg))

    def step(self, p, cfg, x, cache, pos, ctx):
        h = common.norm_apply(cfg.norm, p["norm1"], x)
        o, cache = rglru.rglru_block_step(p["lru"], h, cache, _cdtype(cfg))
        x = x + o
        x = x + common.mlp_apply(
            p["mlp"], common.norm_apply(cfg.norm, p["norm2"], x).astype(_cdtype(cfg)),
            cfg.act, _cdtype(cfg))
        return x, cache


class _MLSTMBlock:
    def init(self, key, cfg: ArchConfig) -> Params:
        return {"norm": common.norm_init(cfg.norm, cfg.d_model),
                "core": xlstm.mlstm_init(key, cfg.d_model, cfg.n_heads,
                                         proj_factor=cfg.proj_factor,
                                         dtype=_pdtype(cfg))}

    def seq(self, p, cfg, x, ctx):
        h = common.norm_apply(cfg.norm, p["norm"], x)
        return x + xlstm.mlstm_block_seq(p["core"], h, cfg.n_heads,
                                         _cdtype(cfg)), jnp.float32(0.0)

    def prefill(self, p, cfg, x, ctx, cache):
        h = common.norm_apply(cfg.norm, p["norm"], x)
        o, state = xlstm.mlstm_block_seq(p["core"], h, cfg.n_heads,
                                         _cdtype(cfg), return_state=True)
        return x + o, jnp.float32(0.0), state

    def cache_init(self, cfg, batch, max_len):
        di = int(cfg.d_model * cfg.proj_factor)
        return xlstm.mlstm_cache_init(batch, cfg.n_heads, di // cfg.n_heads, di)

    def step(self, p, cfg, x, cache, pos, ctx):
        h = common.norm_apply(cfg.norm, p["norm"], x)
        o, cache = xlstm.mlstm_block_step(p["core"], h, cache, cfg.n_heads,
                                          _cdtype(cfg))
        return x + o, cache


class _SLSTMBlock:
    def init(self, key, cfg: ArchConfig) -> Params:
        return {"norm": common.norm_init(cfg.norm, cfg.d_model),
                "core": xlstm.slstm_init(key, cfg.d_model, cfg.n_heads,
                                         dtype=_pdtype(cfg))}

    def seq(self, p, cfg, x, ctx):
        h = common.norm_apply(cfg.norm, p["norm"], x)
        cons = (lambda t, spec: constrain_act(cfg, t, spec)) \
            if cfg.constrain_activations else None
        return x + xlstm.slstm_block_seq(p["core"], h, _cdtype(cfg),
                                         constrain=cons), jnp.float32(0.0)

    def prefill(self, p, cfg, x, ctx, cache):
        h = common.norm_apply(cfg.norm, p["norm"], x)
        o, state = xlstm.slstm_block_seq(p["core"], h, _cdtype(cfg),
                                         return_state=True)
        return x + o, jnp.float32(0.0), state

    def cache_init(self, cfg, batch, max_len):
        return xlstm.slstm_cache_init(batch, cfg.d_model)

    def step(self, p, cfg, x, cache, pos, ctx):
        h = common.norm_apply(cfg.norm, p["norm"], x)
        o, cache = xlstm.slstm_block_step(p["core"], h, cache, _cdtype(cfg))
        return x + o, cache


BLOCKS = {
    "attn": _AttnBlock(causal=True),
    "attn_dense_first": _AttnBlock(causal=True, d_ff_attr="dense_d_ff"),
    "enc": _AttnBlock(causal=False),
    "local_attn": _AttnBlock(causal=True, window_attr="window"),
    "attn_moe": _MoEBlock(causal=True),
    "xattn": _CrossBlock(),
    "dec_xattn": _DecXAttnBlock(causal=True),
    "rglru": _RGLRUBlock(),
    "mlstm": _MLSTMBlock(),
    "slstm": _SLSTMBlock(),
}


def _sketched_mlp_apply(p, h, cfg, ctx):
    """MLP with gradient-tap dense layers on up/down (gate stays plain —
    its grad shares X with up and adds little information)."""
    cd = _cdtype(cfg)
    key = ctx.get("sketch_key")
    if key is None:
        key = jax.random.PRNGKey(0)
    tk = sd.TapConfig().sketch_k
    up = sd.sketched_dense(p["up"]["w"], p["up"]["taps"], h.astype(cd),
                           key, tk, 2048)
    if "gate" in p:
        g = common.dense_apply(p["gate"], h, cd)
        hidden = common.ACTIVATIONS[cfg.act](g) * up
    else:
        hidden = common.ACTIVATIONS[cfg.act](up)
    return sd.sketched_dense(p["down"]["w"], p["down"]["taps"],
                             hidden.astype(cd), jax.random.fold_in(key, 1),
                             tk, 2048)


# ===========================================================================
# Groups: init / seq / prefill / decode over stacked params
# ===========================================================================

def _group_init(key, pattern, count, cfg):
    def slot(k):
        ks = jax.random.split(k, len(pattern))
        return tuple(BLOCKS[b].init(kk, cfg) for b, kk in zip(pattern, ks))
    return jax.vmap(slot)(jax.random.split(key, count))


def _group_seq(gp, pattern, cfg, x, ctx):
    def body(carry, slot_params):
        x, aux = carry
        for b, p in zip(pattern, slot_params):
            x, a = BLOCKS[b].seq(p, cfg, x, ctx)
            aux = aux + a
        return (x, aux), None
    if cfg.remat:
        if cfg.remat_policy == "save_attn_out":
            # keep each layer's attention output: the backward pass never
            # recomputes the S^2 score work (memory-term hillclimb lever)
            policy = jax.checkpoint_policies.save_only_these_names("attn_out")
        else:
            policy = jax.checkpoint_policies.nothing_saveable
        body = jax.checkpoint(body, policy=policy)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), gp)
    return x, aux


def _group_prefill(gp, caches_in, pattern, cfg, x, ctx):
    def body(x, inputs):
        slot_params, slot_caches = inputs
        caches = []
        for b, p, c in zip(pattern, slot_params, slot_caches):
            x, _, cn = BLOCKS[b].prefill(p, cfg, x, ctx, c)
            caches.append(cn)
        return x, tuple(caches)

    x, caches = jax.lax.scan(body, x, (gp, caches_in))
    return x, caches


def _group_cache_init(pattern, count, cfg, batch, max_len):
    def one(_):
        return tuple(BLOCKS[b].cache_init(cfg, batch, max_len) for b in pattern)
    return jax.vmap(one)(jnp.arange(count))


def _group_step(gp, caches, pattern, cfg, x, pos, ctx):
    def body(x, inputs):
        slot_params, slot_caches = inputs
        new = []
        for b, p, c in zip(pattern, slot_params, slot_caches):
            x, cn = BLOCKS[b].step(p, cfg, x, c, pos, ctx)
            new.append(cn)
        return x, tuple(new)
    x, new_caches = jax.lax.scan(body, x, (gp, caches))
    return x, new_caches


# ===========================================================================
# Whole-model init / forward / loss / prefill / decode
# ===========================================================================

def init_params(key: jax.Array, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 8)
    pd = _pdtype(cfg)
    params: Params = {
        "embed": common.embed_init(ks[0], cfg.vocab_padded, cfg.d_model, pd),
        "final_norm": common.norm_init(cfg.norm, cfg.d_model),
        "groups": [
            _group_init(jax.random.fold_in(ks[1], gi), pattern, count, cfg)
            for gi, (pattern, count) in enumerate(cfg.groups)
        ],
    }
    if not cfg.tie_embeddings:
        params["head"] = common.dense_init(ks[2], cfg.d_model,
                                           cfg.vocab_padded, dtype=pd)
    if cfg.is_encdec:
        params["enc"] = {
            "groups": [_group_init(ks[3], ("enc",), cfg.n_enc_layers, cfg)],
            "final_norm": common.norm_init(cfg.norm, cfg.d_model),
        }
    if cfg.n_img_tokens:
        params["img_proj"] = common.dense_init(ks[4], cfg.d_model, cfg.d_model,
                                               dtype=pd)
    return params


def _encode(params, cfg, enc_input):
    """Whisper encoder over stubbed frame embeddings (B, enc_context, d)."""
    S = enc_input.shape[1]
    x = enc_input.astype(jnp.float32) + common.sinusoidal_positions(S, cfg.d_model)
    ctx = {"positions": jnp.arange(S), "xattn_ctx": None}
    x, _ = _group_seq(params["enc"]["groups"][0], ("enc",), cfg, x, ctx)
    return common.norm_apply(cfg.norm, params["enc"]["final_norm"], x)


def _xattn_context(params, cfg, aux_inputs):
    if cfg.is_encdec:
        return _encode(params, cfg, aux_inputs["enc_frames"])
    if cfg.n_img_tokens:
        img = aux_inputs["img_embeds"]
        return common.dense_apply(params["img_proj"], img, _cdtype(cfg))
    return None


def _backbone(params, cfg, x, ctx, mode="seq", caches=None, pos=None):
    aux_total = jnp.float32(0.0)
    new_caches = []
    for gi, (pattern, count) in enumerate(cfg.groups):
        gp = params["groups"][gi]
        if mode == "seq":
            x, aux = _group_seq(gp, pattern, cfg, x, ctx)
            aux_total = aux_total + aux
        elif mode == "prefill":
            x, cache = _group_prefill(gp, caches[gi], pattern, cfg, x, ctx)
            new_caches.append(cache)
        elif mode == "step":
            x, cache = _group_step(gp, caches[gi], pattern, cfg, x, pos, ctx)
            new_caches.append(cache)
    x = common.norm_apply(cfg.norm, params["final_norm"], x)
    return x, aux_total, new_caches


def _embed_tokens(params, cfg, tokens, positions=None):
    x = common.embed_apply(params["embed"], tokens).astype(jnp.float32)
    if cfg.rope_theta is None:   # absolute sinusoidal positions
        S = tokens.shape[1]
        if positions is None:
            x = x + common.sinusoidal_positions(S, cfg.d_model)
        else:
            # decode: single position embedding computed directly
            pos = positions.reshape(-1)[0]
            dimh = jnp.arange(0, cfg.d_model, 2, dtype=jnp.float32)
            ang = pos.astype(jnp.float32) / (10000.0 ** (dimh / cfg.d_model))
            pe = jnp.zeros((cfg.d_model,), jnp.float32)
            pe = pe.at[0::2].set(jnp.sin(ang)).at[1::2].set(jnp.cos(ang))
            x = x + pe
    return x


def _logits(params, cfg, x):
    if cfg.tie_embeddings:
        logits = common.unembed_apply(params["embed"], x, _cdtype(cfg))
    else:
        logits = common.dense_apply(params["head"], x, _cdtype(cfg))
    # mask vocab padding
    if cfg.vocab_padded != cfg.vocab_size:
        neg = jnp.full((cfg.vocab_padded - cfg.vocab_size,), -1e30, jnp.float32)
        logits = logits.at[..., cfg.vocab_size:].set(neg)
    return logits


def lm_loss(params: Params, cfg: ArchConfig, batch: Dict[str, jax.Array]
            ) -> jax.Array:
    """Mean next-token cross entropy, sequence-chunked over the (huge) vocab
    projection so peak memory is O(B * loss_chunk * vocab)."""
    tokens, labels = batch["tokens"], batch["labels"]
    B, S = tokens.shape
    ctx = {"positions": jnp.arange(S),
           "xattn_ctx": _xattn_context(params, cfg, batch),
           "sketch_key": jax.random.PRNGKey(17)}
    x = _embed_tokens(params, cfg, tokens)
    x, aux, _ = _backbone(params, cfg, x, ctx, mode="seq")

    ck = min(cfg.loss_chunk, S)
    assert S % ck == 0, (S, ck)
    xc = x.reshape(B, S // ck, ck, cfg.d_model).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, S // ck, ck).transpose(1, 0, 2)

    def chunk(carry, inp):
        xb, lb = inp
        logits = _logits(params, cfg, xb)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, lb[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(nll), None

    total, _ = jax.lax.scan(chunk, jnp.float32(0.0), (xc, lc))
    loss = total / (B * S)
    if cfg.n_experts:
        loss = loss + cfg.aux_loss_weight * aux
    return loss


def lm_prefill(params: Params, cfg: ArchConfig, batch: Dict[str, jax.Array],
               caches):
    """Forward over the prompt, writing KV/state into the *preallocated*
    caches (serving engines allocate max_len up front and prefill fills the
    prefix). Returns (last-token logits, filled caches)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    ctx = {"positions": jnp.arange(S),
           "xattn_ctx": _xattn_context(params, cfg, batch)}
    x = _embed_tokens(params, cfg, tokens)
    x, _, caches = _backbone(params, cfg, x, ctx, mode="prefill",
                             caches=caches)
    return _logits(params, cfg, x[:, -1:, :]), caches


def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    return [
        _group_cache_init(pattern, count, cfg, batch, max_len)
        for pattern, count in cfg.groups
    ]


def lm_decode_step(params: Params, cfg: ArchConfig, caches,
                   token: jax.Array, pos: jax.Array,
                   aux_inputs: Optional[Dict[str, jax.Array]] = None):
    """One decode step. token: (B, 1) int32; pos: scalar int32 (current
    position). Returns (logits (B, 1, vocab), new caches)."""
    positions = pos[None] if jnp.ndim(pos) == 0 else pos
    ctx = {"positions": positions, "xattn_ctx": None}
    x = _embed_tokens(params, cfg, token, positions=positions)
    x, _, new_caches = _backbone(params, cfg, x, ctx, mode="step",
                                 caches=caches, pos=pos)
    return _logits(params, cfg, x), new_caches
