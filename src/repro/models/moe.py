"""Mixture-of-Experts layer: top-k routing, capacity-bounded sort-based
dispatch, expert-parallel sharding.

Dispatch strategy (pjit/GSPMD-friendly, scales to 384 experts):
  1. router logits -> top-k (expert ids, gates) per token;
  2. flatten (T*k) assignments, sort by expert id;
  3. rank-within-expert via a cumulative count over the *sorted* list; drop
     ranks >= capacity C (static, C = ceil(T*k/E * capacity_factor));
  4. scatter tokens into an (E, C, d) buffer — indices are unique and sorted,
     so XLA lowers to an efficient scatter;
  5. batched expert matmuls einsum('ecd,edf->ecf') with E sharded over the
     "model" axis (expert parallelism);
  6. gather back, weight by gates, add shared-expert and residual paths.

The (T, E, C) one-hot dispatch einsum used by Switch/GShard is O(T*E*C) and
intractable at E=384; the sort+scatter form is O(T*k log(T*k) + T*k*d).
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import common

Params = Dict[str, Any]


def moe_init(key: jax.Array, d: int, d_ff: int, n_experts: int, *,
             n_shared: int = 0, shared_d_ff: int | None = None,
             gated: bool = True, dtype=jnp.float32) -> Params:
    kr, ke, ks = jax.random.split(key, 3)
    scale = 1.0 / math.sqrt(d)
    p: Params = {
        "router": {"w": (jax.random.normal(kr, (d, n_experts), jnp.float32)
                         * scale).astype(jnp.float32)},   # router stays f32
        "w_up": (jax.random.normal(jax.random.fold_in(ke, 0),
                                   (n_experts, d, d_ff), jnp.float32)
                 * scale).astype(dtype),
        "w_gate": (jax.random.normal(jax.random.fold_in(ke, 1),
                                     (n_experts, d, d_ff), jnp.float32)
                   * scale).astype(dtype) if gated else None,
        "w_down": (jax.random.normal(jax.random.fold_in(ke, 2),
                                     (n_experts, d_ff, d), jnp.float32)
                   * (1.0 / math.sqrt(d_ff))).astype(dtype),
    }
    if n_shared > 0:
        p["shared"] = common.mlp_init(
            ks, d, (shared_d_ff or d_ff) * n_shared, gated=gated, dtype=dtype)
    return {k: v for k, v in p.items() if v is not None}


def moe_apply(p: Params, x: jax.Array, *, top_k: int,
              capacity_factor: float = 1.25, act: str = "silu",
              compute_dtype=jnp.bfloat16) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    B, S, d = x.shape
    T = B * S
    E = p["w_up"].shape[0]
    xt = x.reshape(T, d)

    # --- routing -----------------------------------------------------------
    logits = xt.astype(jnp.float32) @ p["router"]["w"]          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eids = jax.lax.top_k(probs, top_k)                   # (T, k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(eids[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)

    # --- sort-based capacity assignment -------------------------------------
    C = int(math.ceil(T * top_k / E * capacity_factor))
    flat_e = eids.reshape(-1)                                    # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), top_k)
    flat_g = gates.reshape(-1)
    order = jnp.argsort(flat_e)                                  # stable
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    # rank within expert on the sorted list
    idx = jnp.arange(T * top_k, dtype=jnp.int32)
    counts = jnp.bincount(se, length=E)
    starts = jnp.cumsum(counts) - counts
    rank = idx - starts[se]
    keep = rank < C
    slot = jnp.where(keep, se * C + rank, E * C)                 # E*C = dropped

    # --- dispatch ------------------------------------------------------------
    # Kept slots are unique; dropped assignments all collide on row E*C with a
    # zero contribution, so scatter-add is deterministic and exact.
    buf = jnp.zeros((E * C + 1, d), compute_dtype)
    buf = buf.at[slot].add((xt[st] * keep[:, None]).astype(compute_dtype))
    h = buf[:E * C].reshape(E, C, d)

    # --- expert FFNs (E sharded over "model") --------------------------------
    up = jnp.einsum("ecd,edf->ecf", h, p["w_up"].astype(compute_dtype),
                    preferred_element_type=jnp.float32)
    if "w_gate" in p:
        g = jnp.einsum("ecd,edf->ecf", h, p["w_gate"].astype(compute_dtype),
                       preferred_element_type=jnp.float32)
        hidden = common.ACTIVATIONS[act](g) * up
    else:
        hidden = common.ACTIVATIONS[act](up)
    out_e = jnp.einsum("ecf,efd->ecd", hidden.astype(compute_dtype),
                       p["w_down"].astype(compute_dtype),
                       preferred_element_type=jnp.float32)       # (E, C, d)

    # --- combine -------------------------------------------------------------
    out_flat = jnp.concatenate(
        [out_e.reshape(E * C, d), jnp.zeros((1, d), jnp.float32)], axis=0)
    back = out_flat[slot] * (sg * keep)[:, None]                 # (T*k, d)
    out = jax.ops.segment_sum(back, st, num_segments=T)          # (T, d)

    if "shared" in p:
        out = out + common.mlp_apply(p["shared"], xt, act, compute_dtype)
    return out.reshape(B, S, d).astype(jnp.float32), aux
