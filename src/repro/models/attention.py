"""Attention: GQA with RoPE, chunked online-softmax (flash-style) for long
sequences, sliding-window variants, and KV-cache decode.

Memory honesty: the naive (S x S) score matrix at the assigned shapes (e.g.
prefill_32k) is multi-GB per head; ``chunked_attention`` computes attention
with an online-softmax scan over KV chunks so the compiled dry-run's
memory_analysis reflects a deployable kernel schedule (this is the pure-JAX
equivalent of flash attention; XLA fuses each chunk's matmul+softmax update).
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import common

Params = Dict[str, Any]

_NEG_INF = -1e30
CHUNK_THRESHOLD = 2048       # below this, dense masked attention is cheaper
Q_CHUNK = 1024
KV_CHUNK = 1024


# ---------------------------------------------------------------------------
# projections
# ---------------------------------------------------------------------------

def attn_init(key: jax.Array, d: int, n_heads: int, n_kv: int, head_dim: int,
              *, dtype=jnp.float32, bias: bool = False) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": common.dense_init(kq, d, n_heads * head_dim, dtype=dtype, bias=bias),
        "wk": common.dense_init(kk, d, n_kv * head_dim, dtype=dtype, bias=bias),
        "wv": common.dense_init(kv, d, n_kv * head_dim, dtype=dtype, bias=bias),
        "wo": common.dense_init(ko, n_heads * head_dim, d, dtype=dtype, bias=bias),
    }


def qkv_project(p: Params, x: jax.Array, n_heads: int, n_kv: int,
                head_dim: int, positions: jax.Array, rope_theta: float | None,
                compute_dtype=jnp.bfloat16):
    B, S, _ = x.shape
    q = common.dense_apply(p["wq"], x, compute_dtype).reshape(B, S, n_heads, head_dim)
    k = common.dense_apply(p["wk"], x, compute_dtype).reshape(B, S, n_kv, head_dim)
    v = common.dense_apply(p["wv"], x, compute_dtype).reshape(B, S, n_kv, head_dim)
    if rope_theta is not None:
        q = common.apply_rope(q, positions, rope_theta)
        k = common.apply_rope(k, positions, rope_theta)
    return q, k, v


# ---------------------------------------------------------------------------
# dense masked attention (short sequences / references)
# ---------------------------------------------------------------------------

def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B, S, Hkv, Dh) -> (B, S, H, Dh) by group broadcast."""
    B, S, hkv, dh = k.shape
    rep = n_heads // hkv
    return jnp.broadcast_to(k[:, :, :, None, :], (B, S, hkv, rep, dh)) \
        .reshape(B, S, n_heads, dh)


def dense_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool, window: int | None = None,
                    q_offset: int = 0, kv_valid_len: jax.Array | None = None,
                    scores_dtype=jnp.float32) -> jax.Array:
    """q: (B, Sq, H, Dh), k/v: (B, Skv, Hkv, Dh). Returns (B, Sq, H, Dh).

    scores_dtype=bf16 halves the HBM traffic of the materialized score /
    probability tensors (the §Perf memory-term lever); softmax statistics
    stay in f32 via the preferred accumulator."""
    B, Sq, H, Dh = q.shape
    Skv = k.shape[1]
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(scores_dtype),
                        k.astype(scores_dtype),
                        preferred_element_type=scores_dtype) / math.sqrt(Dh)
    scores = scores.astype(jnp.float32) if scores_dtype == jnp.float32 \
        else scores
    qpos = q_offset + jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    if kv_valid_len is not None:
        mask &= kpos < kv_valid_len
    scores = jnp.where(mask[None, None], scores.astype(jnp.float32), _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(scores_dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    return out


# ---------------------------------------------------------------------------
# chunked online-softmax attention (flash-style, pure JAX)
# ---------------------------------------------------------------------------

def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: int | None = None,
                      q_chunk: int = Q_CHUNK, kv_chunk: int = KV_CHUNK,
                      scores_dtype=jnp.float32) -> jax.Array:
    """Streaming attention: never materializes more than (q_chunk x kv_chunk)
    of scores per head. q/k/v as in dense_attention, Sq == Skv == S."""
    B, S, H, Dh = q.shape
    Hkv = k.shape[2]
    assert S % q_chunk == 0 and S % kv_chunk == 0, (S, q_chunk, kv_chunk)
    nq, nk = S // q_chunk, S // kv_chunk
    scale = 1.0 / math.sqrt(Dh)

    qc = q.reshape(B, nq, q_chunk, H, Dh).transpose(1, 0, 3, 2, 4)  # (nq,B,H,qc,Dh)
    kc = k.reshape(B, nk, kv_chunk, Hkv, Dh).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(B, nk, kv_chunk, Hkv, Dh).transpose(1, 0, 3, 2, 4)
    rep = H // Hkv

    def process_q_chunk(qi, q_blk):
        # q_blk: (B, H, qc, Dh)
        q32 = q_blk.astype(jnp.float32) * scale
        qpos = qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, inputs):
            m, l, acc = carry
            ki, k_blk, v_blk = inputs
            kb = jnp.repeat(k_blk, rep, axis=1).astype(scores_dtype)
            vb = jnp.repeat(v_blk, rep, axis=1)
            s = jnp.einsum("bhqd,bhkd->bhqk", q32.astype(scores_dtype), kb,
                           preferred_element_type=scores_dtype
                           ).astype(jnp.float32)
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            msk = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                msk &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                msk &= kpos[None, :] > qpos[:, None] - window
            s = jnp.where(msk[None, None], s, _NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None]).astype(scores_dtype)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p.astype(jnp.float32), axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p, vb,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        init = (jnp.full((B, H, q_chunk), _NEG_INF, jnp.float32),
                jnp.zeros((B, H, q_chunk), jnp.float32),
                jnp.zeros((B, H, q_chunk, Dh), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(
            kv_step, init, (jnp.arange(nk), kc, vc))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out        # (B, H, qc, Dh)

    outs = jax.lax.map(lambda args: process_q_chunk(*args),
                       (jnp.arange(nq), qc))    # (nq, B, H, qc, Dh)
    return outs.transpose(1, 0, 3, 2, 4).reshape(B, S, H, Dh).astype(q.dtype)


def attention(q, k, v, *, causal=True, window=None,
              scores_dtype=jnp.float32):
    S = q.shape[1]
    if S <= CHUNK_THRESHOLD or S % Q_CHUNK or S % KV_CHUNK:
        return dense_attention(q, k, v, causal=causal, window=window,
                               scores_dtype=scores_dtype)
    return chunked_attention(q, k, v, causal=causal, window=window,
                             scores_dtype=scores_dtype)


# ---------------------------------------------------------------------------
# KV cache (decode)
# ---------------------------------------------------------------------------

def init_kv_cache(batch: int, max_len: int, n_kv: int, head_dim: int,
                  dtype=jnp.bfloat16) -> Params:
    return {"k": jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
            "v": jnp.zeros((batch, max_len, n_kv, head_dim), dtype)}


def cache_update(cache: Params, k_new: jax.Array, v_new: jax.Array,
                 pos: jax.Array, *, ring: bool = False) -> Params:
    """Insert (B, 1, Hkv, Dh) at position ``pos`` (ring=True wraps — used by
    sliding-window caches whose length is the window size)."""
    L = cache["k"].shape[1]
    idx = (pos % L) if ring else pos
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, idx, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, idx, 0, 0))
    return {"k": k, "v": v}


def decode_attention(q: jax.Array, cache: Params, pos: jax.Array, *,
                     window: int | None = None) -> jax.Array:
    """Single-token attention against the cache. q: (B, 1, H, Dh).

    For ring caches (window), every slot written so far is valid (<= pos) and
    RoPE was already applied at insert time, so ordering inside the ring is
    irrelevant to the softmax — only validity matters.
    """
    B, _, H, Dh = q.shape
    L = cache["k"].shape[1]
    k = _expand_kv(cache["k"], H).astype(jnp.float32)
    v = _expand_kv(cache["v"], H).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k) / math.sqrt(Dh)
    slot = jnp.arange(L)
    if window is None:
        valid = slot <= pos
    else:
        # ring: once pos >= L every slot holds an in-window token; before
        # that only slots <= pos have been written.
        valid = (slot <= pos) | (pos >= L)
    s = jnp.where(valid[None, None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v)
    return out.astype(q.dtype)
