"""Model factory: bundles an ArchConfig with its init/loss/prefill/decode
closures — the single entry point used by train, serve, and the dry-run."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, get_config
from repro.models import transformer


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    def init_params(self, key: jax.Array):
        return transformer.init_params(key, self.cfg)

    def param_shapes(self):
        """Abstract param pytree (no allocation) for the dry-run."""
        return jax.eval_shape(
            lambda k: transformer.init_params(k, self.cfg),
            jax.random.PRNGKey(0))

    def loss(self, params, batch: Dict[str, jax.Array]) -> jax.Array:
        return transformer.lm_loss(params, self.cfg, batch)

    def prefill(self, params, batch: Dict[str, jax.Array], caches):
        return transformer.lm_prefill(params, self.cfg, batch, caches)

    def init_cache(self, batch_size: int, max_len: int):
        return transformer.init_cache(self.cfg, batch_size, max_len)

    def cache_shapes(self, batch_size: int, max_len: int):
        return jax.eval_shape(
            lambda: transformer.init_cache(self.cfg, batch_size, max_len))

    def decode_step(self, params, caches, token, pos):
        return transformer.lm_decode_step(params, self.cfg, caches, token, pos)

    def aux_input_shapes(self, batch_size: int) -> Dict[str, Any]:
        """Stub-frontend inputs (precomputed embeddings) per the assignment."""
        cfg = self.cfg
        out: Dict[str, Any] = {}
        if cfg.is_encdec:
            out["enc_frames"] = jax.ShapeDtypeStruct(
                (batch_size, cfg.enc_context, cfg.d_model), jnp.bfloat16)
        if cfg.n_img_tokens:
            out["img_embeds"] = jax.ShapeDtypeStruct(
                (batch_size, cfg.n_img_tokens, cfg.d_model), jnp.bfloat16)
        return out


def build(name_or_cfg, **overrides) -> Model:
    cfg = (get_config(name_or_cfg) if isinstance(name_or_cfg, str)
           else name_or_cfg)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return Model(cfg)
