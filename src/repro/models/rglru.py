"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
    a_t = exp(-c * softplus(Lambda) * sigmoid(r_t))        (c = 8)

The recurrence is a per-channel *linear* scan, so training/prefill uses
``jax.lax.associative_scan`` (O(log S) depth — the TPU-native answer to the
paper-era sequential CUDA scan); decode is a single fused elementwise update.
The block is: x -> [gelu(W_gate x)] * [RG-LRU(conv1d(W_in x))] -> W_out.

Sharding: the recurrence is elementwise over channels, so the lru_width axis
shards perfectly over the "model" axis with zero recurrent communication —
noted in DESIGN.md as the hybrid arch's TP story.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import common

Params = Dict[str, Any]

_C = 8.0
CONV_K = 4


def rglru_init(key: jax.Array, d: int, width: int, *, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 6)
    # Lambda parameterized so a in (0.9, 0.999) at sigmoid(r)=0.5 (paper init)
    lam_init = jax.random.uniform(ks[0], (width,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.exp(-jnp.log(lam_init) / (0.5 * _C)) - 1.0)  # inv softplus
    return {
        "w_in": common.dense_init(ks[1], d, width, dtype=dtype),
        "w_gate_branch": common.dense_init(ks[2], d, width, dtype=dtype),
        "conv_w": (jax.random.normal(ks[3], (CONV_K, width), jnp.float32)
                   * (1.0 / math.sqrt(CONV_K))).astype(dtype),
        "gate_r": common.dense_init(ks[4], width, width, dtype=dtype),
        "gate_i": common.dense_init(jax.random.fold_in(ks[4], 1), width, width,
                                    dtype=dtype),
        "lam": lam,
        "w_out": common.dense_init(ks[5], width, d, dtype=dtype),
    }


def _causal_conv(w: jax.Array, x: jax.Array,
                 state: jax.Array | None = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv, kernel CONV_K. x: (B, S, W). Returns (y, new
    state (B, CONV_K-1, W)) for streaming decode."""
    B, S, W = x.shape
    if state is None:
        state = jnp.zeros((B, CONV_K - 1, W), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)            # (B, S+K-1, W)
    y = sum(xp[:, i:i + S, :] * w[i][None, None, :] for i in range(CONV_K))
    return y, xp[:, -(CONV_K - 1):, :]


def _gates(p: Params, xc: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """log(a_t) and input gate i_t, all f32. xc: (..., W)."""
    r = jax.nn.sigmoid(common.dense_apply(p["gate_r"], xc))
    i = jax.nn.sigmoid(common.dense_apply(p["gate_i"], xc))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r       # (..., W), < 0
    return log_a, i


def rglru_seq(p: Params, x: jax.Array, h0: jax.Array | None = None,
              compute_dtype=jnp.bfloat16) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence RG-LRU core. x: (B, S, W) (post-conv input).
    Returns (y (B, S, W) f32, final state (B, W))."""
    B, S, W = x.shape
    log_a, gate_i = _gates(p, x.astype(jnp.float32))
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12, 1.0)) \
        * gate_i * x.astype(jnp.float32)
    if h0 is not None:
        b = b.at[:, 0, :].add(a[:, 0, :] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_s, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h, h[:, -1, :]


def rglru_step(p: Params, x_t: jax.Array, h: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One decode step. x_t: (B, W) post-conv; h: (B, W) -> (y_t, h_new)."""
    log_a, gate_i = _gates(p, x_t.astype(jnp.float32))
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12, 1.0)) \
        * gate_i * x_t.astype(jnp.float32)
    h_new = a * h + b
    return h_new, h_new


def rglru_block_seq(p: Params, x: jax.Array, compute_dtype=jnp.bfloat16
                    ) -> jax.Array:
    """Full block, training/prefill path (no carried state). x: (B, S, d)."""
    gate = jax.nn.gelu(common.dense_apply(p["w_gate_branch"], x, compute_dtype))
    xin = common.dense_apply(p["w_in"], x, compute_dtype)
    xc, _ = _causal_conv(p["conv_w"].astype(jnp.float32), xin)
    y, _ = rglru_seq(p, xc, compute_dtype=compute_dtype)
    return common.dense_apply(p["w_out"], (y * gate).astype(compute_dtype),
                              compute_dtype)


def rglru_block_cache_init(batch: int, width: int, dtype=jnp.float32) -> Params:
    return {"h": jnp.zeros((batch, width), jnp.float32),
            "conv": jnp.zeros((batch, CONV_K - 1, width), dtype)}


def rglru_block_step(p: Params, x_t: jax.Array, cache: Params,
                     compute_dtype=jnp.bfloat16) -> Tuple[jax.Array, Params]:
    """One decode step of the full block. x_t: (B, 1, d)."""
    gate = jax.nn.gelu(common.dense_apply(p["w_gate_branch"], x_t, compute_dtype))
    xin = common.dense_apply(p["w_in"], x_t, compute_dtype)
    xc, conv_state = _causal_conv(p["conv_w"].astype(jnp.float32),
                                  xin, cache["conv"].astype(jnp.float32))
    y, h_new = rglru_step(p, xc[:, 0, :], cache["h"])
    out = common.dense_apply(p["w_out"],
                             (y[:, None, :] * gate).astype(compute_dtype),
                             compute_dtype)
    return out, {"h": h_new, "conv": conv_state.astype(cache["conv"].dtype)}
