"""Shared layer primitives: norms, dense, RoPE, activations, embeddings.

Pure-functional: ``*_init(key, ...) -> params`` and ``*_apply(params, x)``.
Parameters are plain nested dicts; sharding rules are derived from dict paths
in repro.dist.sharding (path-based rules keep the model code mesh-agnostic).
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def dense_init(key: jax.Array, d_in: int, d_out: int, *,
               dtype=jnp.float32, scale: float | None = None,
               bias: bool = False) -> Params:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32)
               * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense_apply(p: Params, x: jax.Array, compute_dtype=jnp.bfloat16) -> jax.Array:
    y = jax.lax.dot_general(
        x.astype(compute_dtype), p["w"].astype(compute_dtype),
        dimension_numbers=(((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    if "b" in p:
        y = y + p["b"].astype(jnp.float32)
    return y


def rmsnorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_apply(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)) * p["scale"].astype(jnp.float32)


def layernorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm_apply(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32) \
        + p["bias"].astype(jnp.float32)


def norm_init(kind: str, d: int) -> Params:
    return layernorm_init(d) if kind == "layernorm" else rmsnorm_init(d)


def norm_apply(kind: str, p: Params, x: jax.Array) -> jax.Array:
    return layernorm_apply(p, x) if kind == "layernorm" else rmsnorm_apply(p, x)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)                      # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(angles)[..., None, :]                      # (..., S, 1, Dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x1 * sin + x2 * cos], axis=-1).astype(x.dtype)


def sinusoidal_positions(seq_len: int, d: int) -> jax.Array:
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    angle = pos / (10000.0 ** (dim / d))
    out = jnp.zeros((seq_len, d), jnp.float32)
    out = out.at[:, 0::2].set(jnp.sin(angle))
    out = out.at[:, 1::2].set(jnp.cos(angle))
    return out


ACTIVATIONS: Dict[str, Callable[[jax.Array], jax.Array]] = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
}


# ---------------------------------------------------------------------------
# MLP (gated / plain)
# ---------------------------------------------------------------------------

def mlp_init(key: jax.Array, d: int, d_ff: int, *, gated: bool,
             dtype=jnp.float32, bias: bool = False) -> Params:
    ks = jax.random.split(key, 3)
    p = {"up": dense_init(ks[0], d, d_ff, dtype=dtype, bias=bias),
         "down": dense_init(ks[1], d_ff, d, dtype=dtype, bias=bias)}
    if gated:
        p["gate"] = dense_init(ks[2], d, d_ff, dtype=dtype, bias=bias)
    return p


def mlp_apply(p: Params, x: jax.Array, act: str,
              compute_dtype=jnp.bfloat16) -> jax.Array:
    f = ACTIVATIONS[act]
    up = dense_apply(p["up"], x, compute_dtype)
    if "gate" in p:
        h = f(dense_apply(p["gate"], x, compute_dtype)) * up
    else:
        h = f(up)
    return dense_apply(p["down"], h.astype(compute_dtype), compute_dtype)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------

def embed_init(key: jax.Array, vocab: int, d: int, dtype=jnp.float32) -> Params:
    return {"table": (jax.random.normal(key, (vocab, d), jnp.float32)
                      * 0.02).astype(dtype)}


def embed_apply(p: Params, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["table"], tokens, axis=0)


def unembed_apply(p: Params, x: jax.Array,
                  compute_dtype=jnp.bfloat16) -> jax.Array:
    """Tied read-out: logits = x @ table^T (vocab-sharded matmul)."""
    return jax.lax.dot_general(
        x.astype(compute_dtype), p["table"].astype(compute_dtype),
        dimension_numbers=(((x.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
