"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, fully
parallelizable) and sLSTM (scalar memory with exponential gating).

mLSTM recurrence per head (state C: Dh x Dh, normalizer n: Dh, stabilizer m):
    f_t = exp gate (forget, log-space), i_t = exp gate (input)
    m_t = max(log f_t + m_{t-1}, log i_t)
    C_t = exp(log f_t + m_{t-1} - m_t) C_{t-1} + exp(log i_t - m_t) v_t k_t^T
    n_t = exp(log f_t + m_{t-1} - m_t) n_{t-1} + exp(log i_t - m_t) k_t
    h_t = C_t q_t / max(|n_t^T q_t|, 1)

Training/prefill uses the chunkwise-parallel form: within a chunk the decays
are cumulative products applied as a (chunk x chunk) masked attention-like
matmul; across chunks a scan carries (C, n, m). This is the TPU adaptation:
MXU-friendly chunk matmuls instead of the paper's fused CUDA scan.

sLSTM keeps per-head scalar state (c, n, m) and is inherently sequential; we
scan over time (cheap: state is (B, H) scalars; the block's cost is in its
projections, which batch over S).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import common

Params = Dict[str, Any]

MLSTM_CHUNK = 256


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key: jax.Array, d: int, n_heads: int, *, proj_factor: float = 2.0,
               dtype=jnp.float32) -> Params:
    di = int(d * proj_factor)
    ks = jax.random.split(key, 8)
    return {
        "w_up": common.dense_init(ks[0], d, 2 * di, dtype=dtype),   # x and gate
        "wq": common.dense_init(ks[1], di, di, dtype=dtype),
        "wk": common.dense_init(ks[2], di, di, dtype=dtype),
        "wv": common.dense_init(ks[3], di, di, dtype=dtype),
        "w_if": common.dense_init(ks[4], di, 2 * n_heads, dtype=jnp.float32),
        "conv_w": (jax.random.normal(ks[5], (4, di), jnp.float32) * 0.5).astype(dtype),
        "norm": common.rmsnorm_init(di),
        "w_down": common.dense_init(ks[6], di, d, dtype=dtype),
    }


def _mlstm_chunk_parallel(q, k, v, log_f, log_i):
    """Chunkwise-parallel mLSTM. q,k,v: (B, H, S, Dh); gates: (B, H, S).
    Returns h: (B, H, S, Dh)."""
    B, H, S, Dh = q.shape
    nc = S // MLSTM_CHUNK
    L = MLSTM_CHUNK
    qc = q.reshape(B, H, nc, L, Dh).transpose(2, 0, 1, 3, 4)
    kc = k.reshape(B, H, nc, L, Dh).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(B, H, nc, L, Dh).transpose(2, 0, 1, 3, 4)
    fc = log_f.reshape(B, H, nc, L).transpose(2, 0, 1, 3)
    ic = log_i.reshape(B, H, nc, L).transpose(2, 0, 1, 3)

    def chunk_step(carry, inp):
        C, n, m = carry                      # (B,H,Dh,Dh), (B,H,Dh), (B,H)
        qb, kb, vb, fb, ib = inp
        csum_f = jnp.cumsum(fb, axis=-1)     # (B,H,L) inclusive
        # decay from chunk start to t (exclusive of t's own f? include):
        # state contribution: C_{t} includes prod_{s<=t} f_s from chunk start
        b = csum_f                            # log prod f_1..t
        # intra-chunk weights: for s <= t: prod_{u=s+1..t} f_u * i_s
        #   = exp(b_t - b_s + i_s)
        log_w = b[..., :, None] - b[..., None, :] + ib[..., None, :]  # (B,H,L,L)
        mask = jnp.tril(jnp.ones((L, L), bool))
        log_w = jnp.where(mask, log_w, -jnp.inf)
        # inter-chunk: exp(b_t + m_prev) applied to carried state
        m_intra = jnp.max(log_w, axis=-1)                  # (B,H,L)
        m_inter = b + m[..., None]                          # (B,H,L)
        m_t = jnp.maximum(m_intra, m_inter)
        w = jnp.exp(log_w - m_t[..., None])                 # (B,H,L,L)
        scale_inter = jnp.exp(m_inter - m_t)                # (B,H,L)
        scores = jnp.einsum("bhtd,bhsd->bhts", qb.astype(jnp.float32),
                            kb.astype(jnp.float32)) / math.sqrt(Dh)
        h_intra = jnp.einsum("bhts,bhsd->bhtd", w * scores, vb.astype(jnp.float32))
        h_inter = jnp.einsum("bhtd,bhde->bhte", qb.astype(jnp.float32), C) \
            * scale_inter[..., None] / math.sqrt(Dh)
        num = h_intra + h_inter
        # denominator: n_t^T q_t with the same weighting
        den_intra = jnp.einsum("bhts,bhsd,bhtd->bht", w, kb.astype(jnp.float32),
                               qb.astype(jnp.float32)) / math.sqrt(Dh)
        den_inter = jnp.einsum("bhd,bhtd->bht", n, qb.astype(jnp.float32)) \
            * scale_inter / math.sqrt(Dh)
        den = jnp.maximum(jnp.abs(den_intra + den_inter), jnp.exp(-m_t))
        h = num / den[..., None]
        # ---- carry update to end of chunk ----
        tot_f = b[..., -1]                                  # (B,H)
        m_end = jnp.maximum(tot_f + m, jnp.max(ib + (tot_f[..., None] - b),
                                               axis=-1))
        decay_old = jnp.exp(tot_f + m - m_end)
        wk_end = jnp.exp(ib + (tot_f[..., None] - b) - m_end[..., None])
        C_new = decay_old[..., None, None] * C + jnp.einsum(
            "bhs,bhsd,bhse->bhde", wk_end, kb.astype(jnp.float32),
            vb.astype(jnp.float32))
        n_new = decay_old[..., None] * n + jnp.einsum(
            "bhs,bhsd->bhd", wk_end, kb.astype(jnp.float32))
        return (C_new, n_new, m_end), h

    init = (jnp.zeros((B, H, Dh, Dh), jnp.float32),
            jnp.zeros((B, H, Dh), jnp.float32),
            jnp.full((B, H), -1e30, jnp.float32))
    final, hs = jax.lax.scan(chunk_step, init, (qc, kc, vc, fc, ic))
    return hs.transpose(1, 2, 0, 3, 4).reshape(B, H, S, Dh), final


def mlstm_block_seq(p: Params, x: jax.Array, n_heads: int,
                    compute_dtype=jnp.bfloat16, return_state: bool = False):
    """Full mLSTM block over a sequence. x: (B, S, d).

    return_state=True additionally returns the decode cache holding the
    end-of-sequence (C, n, m) carry and conv state (exact prefill handoff)."""
    B, S, d = x.shape
    up = common.dense_apply(p["w_up"], x, compute_dtype)
    xi, gate = jnp.split(up, 2, axis=-1)                    # (B, S, di)
    di = xi.shape[-1]
    dh = di // n_heads
    # causal conv front (as in the paper's block)
    state = jnp.zeros((B, 3, di), xi.dtype)
    xp = jnp.concatenate([state, xi.astype(jnp.float32)], axis=1)
    xc = sum(xp[:, i:i + S, :] * p["conv_w"][i][None, None, :].astype(jnp.float32)
             for i in range(4))
    xc = jax.nn.silu(xc)
    q = common.dense_apply(p["wq"], xc, compute_dtype).reshape(B, S, n_heads, dh)
    k = common.dense_apply(p["wk"], xc, compute_dtype).reshape(B, S, n_heads, dh)
    v = common.dense_apply(p["wv"], xi, compute_dtype).reshape(B, S, n_heads, dh)
    if_gates = common.dense_apply(p["w_if"], xc)            # (B, S, 2H) f32
    log_i, log_f = jnp.split(if_gates, 2, axis=-1)
    log_f = jax.nn.log_sigmoid(log_f)
    q, k, v = (t.transpose(0, 2, 1, 3) for t in (q, k, v))
    log_f = log_f.transpose(0, 2, 1)
    log_i = log_i.transpose(0, 2, 1)
    if S % MLSTM_CHUNK == 0 and S > MLSTM_CHUNK:
        h, state = _mlstm_chunk_parallel(q, k, v, log_f, log_i)
    else:
        h, state = _mlstm_chunk_parallel_single(q, k, v, log_f, log_i)
    h = h.transpose(0, 2, 1, 3).reshape(B, S, di)
    h = common.rmsnorm_apply(p["norm"], h)
    out = h * jax.nn.silu(gate.astype(jnp.float32))
    out = common.dense_apply(p["w_down"], out.astype(compute_dtype),
                             compute_dtype)
    if return_state:
        C, n, m = state
        return out, {"C": C, "n": n, "m": m, "conv": xp[:, -3:, :]}
    return out


def _mlstm_chunk_parallel_single(q, k, v, log_f, log_i):
    """Single-chunk (full-sequence) stabilized parallel form."""
    B, H, S, Dh = q.shape
    b = jnp.cumsum(log_f, axis=-1)
    log_w = b[..., :, None] - b[..., None, :] + log_i[..., None, :]
    mask = jnp.tril(jnp.ones((S, S), bool))
    log_w = jnp.where(mask, log_w, -jnp.inf)
    m_t = jnp.max(log_w, axis=-1)
    w = jnp.exp(log_w - m_t[..., None])
    scores = jnp.einsum("bhtd,bhsd->bhts", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(Dh)
    num = jnp.einsum("bhts,bhsd->bhtd", w * scores, v.astype(jnp.float32))
    den = jnp.maximum(jnp.abs(jnp.einsum("bhts,bhsd,bhtd->bht", w,
                                         k.astype(jnp.float32),
                                         q.astype(jnp.float32))
                              / math.sqrt(Dh)), jnp.exp(-m_t))
    # end-of-sequence carry (same algebra as chunk_step with m_prev = -inf)
    tot_f = b[..., -1]
    m_end = jnp.max(log_i + (tot_f[..., None] - b), axis=-1)
    wk_end = jnp.exp(log_i + (tot_f[..., None] - b) - m_end[..., None])
    C = jnp.einsum("bhs,bhsd,bhse->bhde", wk_end, k.astype(jnp.float32),
                   v.astype(jnp.float32))
    n = jnp.einsum("bhs,bhsd->bhd", wk_end, k.astype(jnp.float32))
    return num / den[..., None], (C, n, m_end)


def mlstm_cache_init(batch: int, n_heads: int, head_dim: int, di: int) -> Params:
    return {"C": jnp.zeros((batch, n_heads, head_dim, head_dim), jnp.float32),
            "n": jnp.zeros((batch, n_heads, head_dim), jnp.float32),
            "m": jnp.full((batch, n_heads), -1e30, jnp.float32),
            "conv": jnp.zeros((batch, 3, di), jnp.float32)}


def mlstm_block_step(p: Params, x_t: jax.Array, cache: Params, n_heads: int,
                     compute_dtype=jnp.bfloat16) -> Tuple[jax.Array, Params]:
    """One decode step. x_t: (B, 1, d)."""
    B = x_t.shape[0]
    up = common.dense_apply(p["w_up"], x_t, compute_dtype)
    xi, gate = jnp.split(up, 2, axis=-1)
    di = xi.shape[-1]
    dh = di // n_heads
    xp = jnp.concatenate([cache["conv"], xi.astype(jnp.float32)], axis=1)
    xc = sum(xp[:, i:i + 1, :] * p["conv_w"][i][None, None, :].astype(jnp.float32)
             for i in range(4))
    xc = jax.nn.silu(xc)
    q = common.dense_apply(p["wq"], xc, compute_dtype).reshape(B, n_heads, dh)
    k = common.dense_apply(p["wk"], xc, compute_dtype).reshape(B, n_heads, dh)
    v = common.dense_apply(p["wv"], xi, compute_dtype).reshape(B, n_heads, dh)
    if_g = common.dense_apply(p["w_if"], xc)[:, 0]           # (B, 2H)
    log_i, log_f = jnp.split(if_g, 2, axis=-1)
    log_f = jax.nn.log_sigmoid(log_f)
    C, n, m = cache["C"], cache["n"], cache["m"]
    m_new = jnp.maximum(log_f + m, log_i)
    df = jnp.exp(log_f + m - m_new)
    di_ = jnp.exp(log_i - m_new)
    C_new = df[..., None, None] * C + di_[..., None, None] * jnp.einsum(
        "bhd,bhe->bhde", k.astype(jnp.float32), v.astype(jnp.float32))
    n_new = df[..., None] * n + di_[..., None] * k.astype(jnp.float32)
    num = jnp.einsum("bhd,bhde->bhe", q.astype(jnp.float32), C_new) / math.sqrt(dh)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n_new,
                                         q.astype(jnp.float32)) / math.sqrt(dh)),
                      jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(B, 1, di)
    h = common.rmsnorm_apply(p["norm"], h)
    out = h * jax.nn.silu(gate.astype(jnp.float32))
    out = common.dense_apply(p["w_down"], out.astype(compute_dtype), compute_dtype)
    return out, {"C": C_new, "n": n_new, "m": m_new,
                 "conv": xp[:, -3:, :]}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key: jax.Array, d: int, n_heads: int, *, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "w_gates": common.dense_init(ks[0], d, 4 * d, dtype=dtype),   # z i f o
        "r_gates": common.dense_init(ks[1], d, 4 * d, dtype=dtype),   # recurrent
        "norm": common.rmsnorm_init(d),
        "w_ff": common.mlp_init(ks[2], d, int(d * 4 / 3), gated=True, dtype=dtype),
    }


def _slstm_cell(p, x_gates, h_prev, state):
    """x_gates: (B, 4d) precomputed input projections; state: (c, n, m)."""
    c, n, m = state
    r = common.dense_apply(p["r_gates"], h_prev)             # (B, 4d)
    z, i, f, o = jnp.split(x_gates + r, 4, axis=-1)
    z = jnp.tanh(z)
    o = jax.nn.sigmoid(o)
    log_f = jax.nn.log_sigmoid(f)
    m_new = jnp.maximum(log_f + m, i)
    ig = jnp.exp(i - m_new)
    fg = jnp.exp(log_f + m - m_new)
    c_new = fg * c + ig * z
    n_new = fg * n + ig
    h = o * c_new / jnp.maximum(n_new, 1.0)
    return h, (c_new, n_new, m_new)


def slstm_block_seq(p: Params, x: jax.Array, compute_dtype=jnp.bfloat16,
                    return_state: bool = False, constrain=None):
    """sLSTM block over a sequence (scan over time). x: (B, S, d).

    ``constrain(t, spec)``: optional activation-sharding hook — without it
    GSPMD replicates the (S, B, 4d) gate buffer across the data axis inside
    the time scan (the collective-term pathology found in the xlstm-350m
    baseline dry-run; see EXPERIMENTS.md §Perf)."""
    B, S, d = x.shape
    gates = common.dense_apply(p["w_gates"], x, compute_dtype)  # (B, S, 4d)
    if constrain is not None:
        gates = constrain(gates, ("data", None, None))

    def step(carry, g_t):
        h_prev, state = carry
        h, state = _slstm_cell(p, g_t, h_prev, state)
        return (h, state), h

    init = (jnp.zeros((B, d), jnp.float32),
            (jnp.zeros((B, d), jnp.float32), jnp.zeros((B, d), jnp.float32),
             jnp.full((B, d), -1e30, jnp.float32)))
    gates_t = gates.transpose(1, 0, 2)
    if constrain is not None:
        gates_t = constrain(gates_t, (None, "data", None))
    (h_last, (c, n, m)), hs = jax.lax.scan(step, init, gates_t)
    h = hs.transpose(1, 0, 2)                                # (B, S, d)
    h = common.rmsnorm_apply(p["norm"], h)
    out = common.mlp_apply(p["w_ff"], h.astype(compute_dtype), "silu",
                           compute_dtype)
    if return_state:
        return out, {"h": h_last, "c": c, "n": n, "m": m}
    return out


def slstm_cache_init(batch: int, d: int) -> Params:
    return {"h": jnp.zeros((batch, d), jnp.float32),
            "c": jnp.zeros((batch, d), jnp.float32),
            "n": jnp.zeros((batch, d), jnp.float32),
            "m": jnp.full((batch, d), -1e30, jnp.float32)}


def slstm_block_step(p: Params, x_t: jax.Array, cache: Params,
                     compute_dtype=jnp.bfloat16) -> Tuple[jax.Array, Params]:
    g = common.dense_apply(p["w_gates"], x_t, compute_dtype)[:, 0]  # (B, 4d)
    h, (c, n, m) = _slstm_cell(p, g, cache["h"],
                               (cache["c"], cache["n"], cache["m"]))
    hn = common.rmsnorm_apply(p["norm"], h)[:, None, :]
    out = common.mlp_apply(p["w_ff"], hn.astype(compute_dtype), "silu",
                           compute_dtype)
    return out, {"h": h, "c": c, "n": n, "m": m}
