from repro.models.factory import Model, build
