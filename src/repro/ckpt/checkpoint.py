"""Mesh-agnostic checkpointing: atomic, keep-N, async-capable, resharding.

Layout:  <dir>/step_<n>/arrays.npz + manifest.json, written to a tmp dir and
atomically renamed (a crash mid-write never corrupts the latest checkpoint —
the RDD-lineage fault-tolerance story of the paper's Spark runtime mapped to
the TPU-native mechanism, DESIGN.md §8).

Arrays are saved device-agnostic (plain npy buffers keyed by pytree path);
``restore`` rebuilds the pytree and, when given a ``sharding_fn``, re-shards
every leaf onto the *current* mesh — restoring onto a different topology
(elastic scaling) is exercised in tests/dist/test_checkpoint_reshard.py."""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"

# ml_dtypes registers bfloat16 with numpy by name, but np.savez cannot
# serialise it — bf16 leaves are stored as their uint16 bit pattern and the
# manifest records which keys to view back on restore
_BF16 = np.dtype("bfloat16")


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out[key] = np.asarray(leaf)
    return out, treedef


def save(ckpt_dir: str, step: int, tree: Any, *, keep: int = 3,
         extra: Optional[dict] = None) -> str:
    """Atomic checkpoint write. Returns the final directory path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays, _ = _flatten(tree)
    bf16 = sorted(k for k, v in arrays.items() if v.dtype == _BF16)
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{k: (v.view(np.uint16) if k in bf16 else v)
                for k, v in arrays.items()})
    manifest = {
        "step": step,
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in arrays.items()},
        "bf16_leaves": bf16,
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)           # atomic publish
    _gc(ckpt_dir, keep)
    return final


def save_async(ckpt_dir: str, step: int, tree: Any, *, keep: int = 3,
               extra: Optional[dict] = None) -> threading.Thread:
    """Snapshot to host memory synchronously, write in a background thread
    (training continues; join the returned thread before process exit)."""
    snapshot = jax.tree.map(np.asarray, tree)   # sync device->host copy
    t = threading.Thread(
        target=save, args=(ckpt_dir, step, snapshot),
        kwargs={"keep": keep, "extra": extra}, daemon=True)
    t.start()
    return t


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for d in os.listdir(ckpt_dir)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, like: Any, step: Optional[int] = None,
            sharding_fn: Optional[Callable[[str, Any], Any]] = None) -> Any:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). sharding_fn(path_str, np_array) -> jax.Array lets the
    caller place each leaf on the current mesh (reshard-on-restore)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(d, "arrays.npz"))
    try:
        with open(os.path.join(d, "manifest.json")) as f:
            bf16 = set(json.load(f).get("bf16_leaves", []))
    except FileNotFoundError:
        bf16 = set()
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        if key not in data:
            raise ValueError(
                f"checkpoint has no leaf {key!r} — the restore template's "
                f"pytree structure does not match the saved state (e.g. a "
                f"decayed template against an undecayed checkpoint)")
        arr = data[key]
        if key in bf16:
            arr = arr.view(_BF16)
        expect = tuple(leaf.shape)
        if tuple(arr.shape) != expect:
            raise ValueError(
                f"checkpoint leaf {key!r} has shape {tuple(arr.shape)} but "
                f"the restore template expects {expect} — was this "
                f"checkpoint written with a different config?")
        if sharding_fn is not None:
            leaves.append(sharding_fn(key, arr))
        else:
            leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def read_manifest(ckpt_dir: str, step: Optional[int] = None) -> dict:
    if step is None:
        step = latest_step(ckpt_dir)
    with open(os.path.join(ckpt_dir, f"step_{step:08d}", "manifest.json")) as f:
        return json.load(f)


def save_stream_state(ckpt_dir: str, step: int, state, *, keep: int = 3,
                      extra: Optional[dict] = None,
                      wire: Optional[str] = None,
                      tol: Optional[float] = None) -> str:
    """Checkpoint a ``streaming.StreamState`` mid-pass (resumable ingestion).

    A StreamState is already a pytree, so this is ``save`` plus a manifest
    record of the coverage/config (rows_seen, k, d_total, srht or not) —
    enough for an operator to see how far a pass got without loading arrays.
    The carried key and SRHT plan are saved with the accumulators, so the
    restored state keeps absorbing rows under the identical randomness.

    ``wire`` names a ``streaming.WireSpec`` precision ("f32"/"bf16"/"int8")
    to write the checkpoint in the compressed wire format instead of raw
    accumulators; ``tol`` instead runs the probe-measured gate
    (``choose_wire_spec``) and writes the cheapest precision whose measured
    relative error meets it. The manifest's ``wire`` record (spec, measured
    error, wire bytes) tells ``restore_stream_state`` to decompress — and
    tells an operator what the checkpoint costs on disk.
    """
    wire_meta = None
    if wire is not None or tol is not None:
        from repro.core import streaming
        if tol is not None:
            spec, err = streaming.choose_wire_spec(
                state, tol, specs=(("int8", "bf16", "f32") if wire is None
                                   else (wire,)))
        else:
            spec = streaming._as_wire_spec(wire)
            err = streaming.wire_error(state, spec) \
                if state.probe_acc is not None else None
        state = streaming.compress_state(state, spec)
        wire_meta = {"spec": spec.sketch,
                     "error": None if err is None else float(err),
                     "bytes": int(streaming.wire_bytes(state))}
        meta = {
            "kind": "stream_state",
            "wire": wire_meta,
            "rows_seen": int(state.rows_seen),
            "row_high": int(state.row_high),
            "d_total": int(state.d_total),
            "k": int(state.A_blk.shape[0]),
            "srht": bool(state.srht),
        }
        meta.update(extra or {})
        return save(ckpt_dir, step, state, keep=keep, extra=meta)
    meta = {
        "kind": "stream_state",
        "rows_seen": int(state.rows_seen),
        "row_high": int(state.row_high),
        "d_total": int(state.d_total),
        "k": int(state.A_acc.shape[0]),
        "srht": state.signs is not None,
        "probes": (0 if state.probe_acc is None
                   else int(state.probe_acc.shape[-1])),
        "cosketch": (0 if state.cosketch_Y is None
                     else int(state.cosketch_Y.shape[-1])),
    }
    if state.decay_rate is not None:
        # the decay timestamps ride the manifest so an operator can see the
        # state's logical clock (and pending decay) without loading arrays
        meta.update(decay_rate=float(state.decay_rate),
                    t_state=int(state.t_state), t_data=int(state.t_data))
    meta.update(extra or {})
    return save(ckpt_dir, step, state, keep=keep, extra=meta)


def restore_stream_state(ckpt_dir: str, like, step: Optional[int] = None):
    """Restore a ``StreamState`` saved by ``save_stream_state``.

    ``like`` is a structurally matching state — in practice
    ``summarizer.init(key, shapes)`` with the same config the pass started
    from (key/plan values are overwritten by the checkpointed ones).
    Round-trips exactly: resuming then finalizing is bit-identical to the
    uninterrupted pass (tested in tests/core/test_streaming.py).

    Checkpoints written with ``save_stream_state(..., wire=)`` (or ``tol=``)
    are detected from the manifest's ``wire`` record: the restore template
    is compressed to the recorded spec, restored leaf-for-leaf, then
    decompressed back to a live ``StreamState`` — f32 wire checkpoints
    round-trip bit-exactly.
    """
    manifest = read_manifest(ckpt_dir, step=step)
    wire_meta = manifest.get("extra", {}).get("wire")
    if wire_meta is not None:
        from repro.core import streaming
        template = streaming.compress_state(like,
                                            streaming.WireSpec(
                                                wire_meta["spec"]))
        return streaming.decompress_state(
            restore(ckpt_dir, template, step=step))
    return restore(ckpt_dir, like, step=step)


def save_window_state(ckpt_dir: str, step: int, wstate, *, keep: int = 3,
                      extra: Optional[dict] = None) -> str:
    """Checkpoint a ``streaming.WindowState`` (the whole ring at once).

    A WindowState is a pytree (base key + bucket ring + head), so this is
    ``save`` plus a manifest record of the ring geometry: ``head`` (the
    newest live epoch — the ring index is ``head % n_buckets``),
    ``n_buckets``, and per-bucket coverage. Restoring resumes the window
    bit-exactly: same bucket contents, same head, same bucket keys.
    """
    from repro.core.streaming import WindowState
    if not isinstance(wstate, WindowState):
        raise ValueError(
            f"save_window_state needs a streaming.WindowState, got "
            f"{type(wstate).__name__} (use save_stream_state for a plain "
            f"StreamState)")
    meta = {
        "kind": "window_state",
        "head": int(wstate.head),
        "n_buckets": wstate.n_buckets,
        "ring_index": int(wstate.head) % wstate.n_buckets,
        "bucket_rows_seen": [int(b.rows_seen) for b in wstate.buckets],
        "k": int(wstate.buckets[0].A_acc.shape[0]),
        "d_total": int(wstate.buckets[0].d_total),
    }
    meta.update(extra or {})
    return save(ckpt_dir, step, wstate, keep=keep, extra=meta)


def restore_window_state(ckpt_dir: str, like, step: Optional[int] = None):
    """Restore a ``WindowState`` saved by ``save_window_state``.

    ``like`` is a structurally matching window — in practice
    ``WindowedSummarizer(...).init(key, shapes)`` with the same config
    (``n_buckets`` must match: the ring is restored slot-for-slot, and the
    saved ``head`` re-establishes which slot is current).
    """
    manifest = read_manifest(ckpt_dir, step=step)
    saved = manifest.get("extra", {}).get("n_buckets")
    have = len(like.buckets)
    if saved is not None and saved != have:
        raise ValueError(
            f"checkpoint was written with n_buckets={saved} but the restore "
            f"template has {have} buckets — window rings cannot be resized "
            f"on restore")
    return restore(ckpt_dir, like, step=step)


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(int(m.group(1)) for d in os.listdir(ckpt_dir)
                   if (m := re.fullmatch(r"step_(\d+)", d)))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
