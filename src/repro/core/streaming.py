"""StreamingSummarizer — mergeable one-pass summaries over row chunks.

The paper's whole point is that the Step-1 summary of (A, B) can be built in
a *single pass*; this module makes that operational when the matrices never
fit in memory at once. It factors ``build_summary`` into the four-phase
contract of a mergeable sketch (Tropp et al., "Practical sketching
algorithms for low-rank matrix approximation"):

    init(key, shapes)                       -> StreamState   (empty monoid id)
    update(state, A_chunk, B_chunk, off)    -> StreamState   (absorb rows)
    merge(s1, s2)                           -> StreamState   (associative +)
    finalize(state)                         -> SketchSummary (sqrt the norms)

Because every accumulator field (sketches, *squared* column norms, the
optional held-out probe block ``(A^T B) @ Omega``, and the optional
refinement co-sketch pair ``(A^T B) @ Omega_c`` / ``Psi_c @ (A^T B)``) is
linear in the data rows, ``StreamState`` is a commutative monoid under
``merge``: chunked
ingestion, any merge order, and the one-shot ``build_summary`` backends all
produce the same summary. The randomness
contract is the SummaryEngine's: the projection column for global row ``i``
is a pure function of ``(key, i)`` (gaussian ``fold_in``; SRHT via the
popcount Hadamard identity from one ``srht_plan``), so a chunk's
contribution depends only on its rows' global indices — never on when, where,
or in what order the chunk was seen.

Exactness grades (tested in tests/core/test_streaming.py):

* sequential ingestion at a fixed chunk size ``c`` (rows 0..d in order) is
  **bit-identical** to ``build_summary(backend='scan', block=c)`` — the
  update performs the identical float ops as the scan body;
* merge is **bit-commutative** (float add commutes);
* reassociating the merge tree (different chunk sizes, shuffled arrival,
  distributed psum) agrees to float-reassociation tolerance, the same
  contract the engine's cross-backend parity tests already enforce.

``StreamState`` is a NamedTuple pytree: it jits, vmaps, psums (the
distributed tree-reduction in ``core/distributed.py`` merges per-device
partial states with one all-reduce), and checkpoints
(``ckpt.checkpoint.save_stream_state`` / ``restore_stream_state`` give
resumable passes).

Drifting streams (docs/streaming.md "Drifting streams"): two summary
variants forget old rows so ``stream_factors`` answers "top components
*now*" instead of "top components ever":

* ``StreamingSummarizer(decay=gamma)`` — exponential decay. Every logical
  tick multiplies all previously absorbed mass by ``gamma``. The decay op
  itself (``decay_state`` / ``Summarizer.advance``) only advances an
  *integer timestamp* riding the state; the scalar multiply per block is
  settled lazily at the next update/merge/finalize. Because both sides of
  ``decay(merge(s1, s2)) == merge(decay(s1), decay(s2))`` then perform the
  identical float ops, the law holds *bitwise* — the decayed states stay a
  commutative monoid (property-tested in
  tests/core/test_streaming_drift.py).
* ``WindowedSummarizer(k, n_buckets=b)`` — sliding window over epochs: a
  ring of ``b`` partial ``StreamState`` buckets; the window summary is the
  merge of the live buckets and ``slide`` retires the oldest in O(1) by
  re-initializing one ring slot. Each epoch's bucket derives its
  projection key from the reserved fold ``window_bucket_key(key, epoch)``
  so bucket-local row ids can repeat across epochs without randomness
  collisions (golden-tested in tests/core/test_key_contract.py).

``decay=1.0`` (the default) leaves the decay fields ``None`` — the pytree
structure and every float op are bit-identical to the pre-decay
``StreamState``, so all historical parity/golden suites run unchanged.

>>> import jax, jax.numpy as jnp
>>> key = jax.random.PRNGKey(0)
>>> A = jax.random.normal(key, (64, 6))
>>> B = jax.random.normal(jax.random.fold_in(key, 1), (64, 4))
>>> summ = StreamingSummarizer(k=8)
>>> state = summ.init(key, (64, 6, 4))
>>> state = summ.update(state, A[:32], B[:32], 0)     # rows arrive in chunks
>>> state = summ.update(state, A[32:], B[32:], 32)
>>> s = summ.finalize(state)
>>> (s.A_sketch.shape, s.B_sketch.shape, int(state.rows_seen))
((8, 6), (8, 4), 64)
>>> from repro.core.summary_engine import build_summary
>>> ref = build_summary(key, A, B, 8, backend="reference")
>>> bool(jnp.allclose(s.A_sketch, ref.A_sketch, atol=1e-5))
True
"""
from __future__ import annotations

import collections
import functools
import json
import struct
from typing import Iterable, NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.summary_engine import (
    METHODS, _cast, _sketch_dot, projection_rows, srht_plan)
from repro.core.types import SketchSummary


class StreamState(NamedTuple):
    """Partial one-pass summary: the mergeable accumulator pytree.

    Norms are carried *squared* (``na2``/``nb2``) so ``merge`` is a plain sum
    on every field — the square root happens once, in ``finalize``.
    ``signs``/``srows`` hold the SRHT plan (None for gaussian); ``key`` is
    carried so a restored checkpoint can keep absorbing rows with the same
    randomness. ``rows_seen`` only tracks coverage for logging/manifests —
    the math never reads it.
    """

    key: Optional[jax.Array]       # base PRNG key (None for wrapped taps)
    A_acc: jax.Array               # (k, n1) running Pi @ A
    B_acc: jax.Array               # (k, n2) running Pi @ B
    na2: jax.Array                 # (n1,) running squared column norms of A
    nb2: jax.Array                 # (n2,) running squared column norms of B
    rows_seen: jax.Array           # () int32 total rows absorbed
    row_high: jax.Array            # () int32 high-water mark: 1 + max absorbed
                                   #    global row id (0 when empty) — what a
                                   #    resumed contiguous cursor starts from
    d_total: jax.Array             # () int32 global streamed dim (-1: unknown)
    signs: Optional[jax.Array]     # (d,) SRHT rademacher signs, else None
    srows: Optional[jax.Array]     # (k,) SRHT sampled Hadamard rows, else None
    omega: Optional[jax.Array] = None      # (n2, p) held-out probes, else None
    probe_acc: Optional[jax.Array] = None  # (n1, p) running (A^T B) @ omega
    decay_rate: Optional[jax.Array] = None  # () f32 per-tick retention gamma
                                            #    in (0, 1); None = no decay
                                            #    (bit-identical legacy path)
    t_state: Optional[jax.Array] = None    # () int32 logical now (advanced by
                                           #    decay_state; None w/o decay)
    t_data: Optional[jax.Array] = None     # () int32 time the accumulators
                                           #    are aged to (t_data <= t_state;
                                           #    the gap is pending decay)
    cosketch_omega: Optional[jax.Array] = None  # (n2, s) co-sketch range test
    cosketch_psi: Optional[jax.Array] = None    # (l, n1) co-range test
    cosketch_Y: Optional[jax.Array] = None      # (n1, s) running (A^T B) Omega_c
    cosketch_W: Optional[jax.Array] = None      # (l, n2) running Psi_c (A^T B)

    @property
    def k(self) -> int:
        """Sketch size."""
        return self.A_acc.shape[0]

    @property
    def n_probes(self) -> int:
        """Held-out probe count p (0 when no probe block is carried)."""
        return 0 if self.probe_acc is None else self.probe_acc.shape[-1]

    @property
    def n_cosketch(self) -> int:
        """Co-sketch width s (0 when no refinement block is carried)."""
        return 0 if self.cosketch_Y is None else self.cosketch_Y.shape[-1]

    @property
    def decayed(self) -> bool:
        """Whether this state carries the exponential-decay time algebra."""
        return self.decay_rate is not None


def _check_mergeable(s1: StreamState, s2: StreamState) -> None:
    """Shape-level compatibility guard (cheap; skips traced fields)."""
    if s1.A_acc.shape != s2.A_acc.shape or s1.B_acc.shape != s2.B_acc.shape:
        raise ValueError(
            f"cannot merge stream states of different shapes: "
            f"{s1.A_acc.shape}/{s1.B_acc.shape} vs "
            f"{s2.A_acc.shape}/{s2.B_acc.shape}")
    if (s1.signs is None) != (s2.signs is None):
        raise ValueError("cannot merge gaussian and srht stream states")
    if (s1.probe_acc is None) != (s2.probe_acc is None):
        raise ValueError("cannot merge a probe-carrying stream state with a "
                         "probe-free one (init both with the same probes=)")
    if (s1.cosketch_Y is None) != (s2.cosketch_Y is None):
        raise ValueError(
            "cannot merge a cosketch-carrying stream state with a "
            "cosketch-free one (init both with the same cosketch=)")
    if (s1.decay_rate is None) != (s2.decay_rate is None):
        raise ValueError(
            "cannot merge a decayed stream state with an undecayed one "
            "(init both with the same decay=)")
    if (s1.decay_rate is not None
            and not isinstance(s1.decay_rate, jax.core.Tracer)
            and not isinstance(s2.decay_rate, jax.core.Tracer)
            and float(s1.decay_rate) != float(s2.decay_rate)):
        raise ValueError(
            f"cannot merge stream states with different decay rates: "
            f"{float(s1.decay_rate)} vs {float(s2.decay_rate)}")


def _check_row_bounds(state: StreamState, lo: int, hi: int) -> None:
    """Eagerly reject global row ids outside [0, d_total).

    Out-of-range ids would otherwise corrupt the summary silently (SRHT
    clamps into the sign vector; gaussian folds in a wrong index). Skipped
    under tracing (concrete values unavailable) — streaming ingestion is
    an eager host loop in practice, so the guard fires where it matters.
    """
    if isinstance(state.d_total, jax.core.Tracer):
        return
    d = int(state.d_total)
    if lo < 0 or hi >= d:
        raise ValueError(
            f"global row ids [{lo}, {hi}] fall outside the declared "
            f"streamed dimension d_total={d} from init()")


def _scale_blocks(state: StreamState, factor) -> StreamState:
    """Multiply every linear accumulator block (sketches, squared norms, the
    probe block, and the co-sketch pair) by one scalar — decay settlement is
    exactly this."""
    return state._replace(
        A_acc=state.A_acc * factor,
        B_acc=state.B_acc * factor,
        na2=state.na2 * factor,
        nb2=state.nb2 * factor,
        probe_acc=(None if state.probe_acc is None
                   else state.probe_acc * factor),
        cosketch_Y=(None if state.cosketch_Y is None
                    else state.cosketch_Y * factor),
        cosketch_W=(None if state.cosketch_W is None
                    else state.cosketch_W * factor))


def _concrete_eq(a, b) -> bool:
    """True when both scalars are concrete and equal (False under tracing —
    the caller then takes the general traceable path)."""
    if isinstance(a, jax.core.Tracer) or isinstance(b, jax.core.Tracer):
        return False
    return int(a) == int(b)


def _settle_state(state: StreamState) -> StreamState:
    """Apply pending decay eagerly: age the accumulators from ``t_data`` up
    to ``t_state`` (one scalar multiply per block; a no-op without decay or
    when nothing is pending)."""
    if state.decay_rate is None or _concrete_eq(state.t_state, state.t_data):
        return state
    factor = state.decay_rate ** (state.t_state - state.t_data)
    return _scale_blocks(state, factor)._replace(t_data=state.t_state)


def decay_state(state: StreamState, dt: int = 1) -> StreamState:
    """Advance the state's logical clock by ``dt`` ticks (the decay op).

    Each tick multiplies all *previously absorbed* mass by the state's
    ``decay_rate`` — but lazily: only the integer timestamp moves here, and
    the scalar multiply per block settles at the next update / merge
    alignment / finalize. That laziness is what makes
    ``decay_state(merge_states(s1, s2), dt)`` bitwise equal to
    ``merge_states(decay_state(s1, dt), decay_state(s2, dt))``: both sides
    run the identical float ops in the identical order. On an undecayed
    state (``decay_rate is None``, i.e. ``decay=1.0``) this is the
    identity. ``dt`` must be a non-negative integer (time only advances).
    """
    if not isinstance(dt, jax.core.Tracer):
        dt = int(dt)
        if dt < 0:
            raise ValueError(
                f"decay_state needs a non-negative tick count, got dt={dt}")
        if dt == 0:
            return state
    if state.decay_rate is None:
        return state
    return state._replace(t_state=state.t_state + jnp.asarray(dt, jnp.int32))


def _align_states(s1: StreamState, s2: StreamState
                  ) -> Tuple[StreamState, StreamState]:
    """Age both decayed operands to the later ``t_data`` so ``merge`` can be
    a plain sum. Symmetric in (s1, s2) — the basis of bitwise merge
    commutativity — and the side already at the common timestamp is left
    untouched."""
    td = jnp.maximum(s1.t_data, s2.t_data)

    def _age(s: StreamState) -> StreamState:
        if _concrete_eq(s.t_data, td):
            return s._replace(t_data=td)
        return _scale_blocks(s, s.decay_rate ** (td - s.t_data)
                             )._replace(t_data=td)

    return _age(s1), _age(s2)


def merge_states(s1: StreamState, s2: StreamState) -> StreamState:
    """Combine summaries of disjoint row sets (the monoid operation).

    A plain sum on every accumulator field: commutative bit-for-bit,
    associative to float reassociation. The key/plan are taken from ``s1``
    (both operands must descend from the same ``init``). Decayed states are
    first aligned to a common data timestamp (the older side is aged by one
    scalar multiply per block); the merged clock is the later of the two —
    so merging never rewinds time, and pending decay stays pending.
    """
    _check_mergeable(s1, s2)
    extra = {}
    if s1.decay_rate is not None:
        s1, s2 = _align_states(s1, s2)
        extra = dict(t_state=jnp.maximum(s1.t_state, s2.t_state),
                     t_data=s1.t_data)
    return s1._replace(
        A_acc=s1.A_acc + s2.A_acc,
        B_acc=s1.B_acc + s2.B_acc,
        na2=s1.na2 + s2.na2,
        nb2=s1.nb2 + s2.nb2,
        rows_seen=s1.rows_seen + s2.rows_seen,
        row_high=jnp.maximum(s1.row_high, s2.row_high),
        probe_acc=(None if s1.probe_acc is None
                   else s1.probe_acc + s2.probe_acc),
        cosketch_Y=(None if s1.cosketch_Y is None
                    else s1.cosketch_Y + s2.cosketch_Y),
        cosketch_W=(None if s1.cosketch_W is None
                    else s1.cosketch_W + s2.cosketch_W),
        **extra)


def tree_merge(states: Sequence[StreamState]) -> StreamState:
    """Log-depth pairwise reduction of partial states (Spark treeAggregate
    shape; associativity makes any reduction tree equivalent)."""
    states = list(states)
    if not states:
        raise ValueError("tree_merge needs at least one state")
    while len(states) > 1:
        nxt = [merge_states(states[i], states[i + 1])
               for i in range(0, len(states) - 1, 2)]
        if len(states) % 2:
            nxt.append(states[-1])
        states = nxt
    return states[0]


def finalize_state(state: StreamState) -> SketchSummary:
    """StreamState -> the Step-1 ``SketchSummary`` (sqrt the squared norms;
    the probe block and its test matrix ride along when carried). Pending
    decay is settled first, so the summary — including the probe block the
    ErrorEngine reads — describes the *decayed* product as of ``t_state``:
    ``estimate_error`` stays unbiased for exactly what the factors
    estimate."""
    state = _settle_state(state)
    return SketchSummary(state.A_acc, state.B_acc,
                         jnp.sqrt(state.na2), jnp.sqrt(state.nb2),
                         probes=state.probe_acc, probe_omega=state.omega,
                         cosketch_Y=state.cosketch_Y,
                         cosketch_W=state.cosketch_W,
                         cosketch_omega=state.cosketch_omega,
                         cosketch_psi=state.cosketch_psi)


@functools.partial(jax.jit, static_argnames=("k", "method", "precision"))
def _chunk_contribution(key, signs, srows, A_chunk, B_chunk, gids, *,
                        k: int, method: str, precision: Optional[str]):
    """(dA, dB, dna2, dnb2) for one chunk of rows with global ids ``gids``.

    Performs the exact float ops of the scan backend's body — the basis of
    the bit-parity guarantee for aligned sequential ingestion.
    """
    plan = None if method == "gaussian" else (signs, srows)
    P = projection_rows(key, gids, k, method=method, plan=plan)
    Ac, Bc = _cast(A_chunk, precision), _cast(B_chunk, precision)
    return (_sketch_dot(P, Ac, precision),
            _sketch_dot(P, Bc, precision),
            jnp.sum(Ac.astype(jnp.float32) ** 2, axis=0),
            jnp.sum(Bc.astype(jnp.float32) ** 2, axis=0))


@functools.partial(jax.jit, static_argnames=("precision",))
def _probe_chunk(omega, A_chunk, B_chunk, *, precision: Optional[str]):
    """(n1, p) probe delta for one chunk — the exact float ops of the
    one-shot ``error_engine.probe_pass`` scan body (bit-parity contract)."""
    from repro.core.error_engine import probe_contribution
    return probe_contribution(omega, A_chunk, B_chunk, precision)


@functools.partial(jax.jit, static_argnames=("precision",))
def _cosketch_chunk(omega, psi, A_chunk, B_chunk, *,
                    precision: Optional[str]):
    """(dY, dW) co-sketch delta for one chunk — the exact float ops of the
    one-shot ``refinement.cosketch_pass`` scan body (bit-parity contract)."""
    from repro.core.refinement import cosketch_contribution
    return cosketch_contribution(omega, psi, A_chunk, B_chunk, precision)


class StreamingSummarizer:
    """Chunked/mergeable front-end to the SummaryEngine's single pass.

    Configure once (sketch size, method, precision); then drive any number
    of independent streams through ``init -> update* -> merge* -> finalize``.
    All randomness comes from the ``init`` key via the engine's
    (key, global row index) contract, so the result is independent of
    chunking and merge order, and matches the one-shot ``build_summary``.

    >>> import jax, jax.numpy as jnp
    >>> summ = StreamingSummarizer(k=4, method="srht")
    >>> key = jax.random.PRNGKey(7)
    >>> A = jax.random.normal(key, (32, 5))
    >>> B = jax.random.normal(jax.random.fold_in(key, 1), (32, 3))
    >>> left = summ.init(key, (32, 5, 3))        # two independent workers ...
    >>> right = summ.init(key, (32, 5, 3))
    >>> left = summ.update(left, A[:16], B[:16], 0)
    >>> right = summ.update(right, A[16:], B[16:], 16)
    >>> s = summ.finalize(summ.merge(left, right))   # ... merged associatively
    >>> s.B_sketch.shape
    (4, 3)
    """

    def __init__(self, k: int, *, method: str = "gaussian",
                 precision: Optional[str] = None, probes: int = 0,
                 cosketch: int = 0, decay: float = 1.0):
        if method not in METHODS:
            raise ValueError(
                f"unknown sketch method {method!r} (use {METHODS})")
        if isinstance(decay, bool) or not isinstance(decay, (int, float)) \
                or not 0.0 < float(decay) <= 1.0:
            raise ValueError(
                f"decay must be a retention factor in (0, 1], got {decay!r}")
        self.k = k
        self.method = method
        self.precision = precision
        self.probes = probes
        self.cosketch = cosketch
        self.decay = float(decay)

    # -- contract ----------------------------------------------------------

    def init(self, key: jax.Array, shapes: Tuple[int, int, int]) -> StreamState:
        """Empty state for a (d, n1, n2) stream under ``key``.

        ``d`` is the *global* streamed dimension: every update validates its
        row ids against it, and SRHT additionally derives its sign/sample
        plan from (key, d) here — the one O(d) step; every update is
        O(chunk).
        """
        d, n1, n2 = shapes
        if self.method == "srht":
            signs, srows, _ = srht_plan(key, d, self.k)
        else:
            signs = srows = None
        if self.probes:
            from repro.core.error_engine import probe_omega
            omega = probe_omega(key, n2, self.probes)
            probe_acc = jnp.zeros((n1, self.probes), jnp.float32)
        else:
            omega = probe_acc = None
        if self.cosketch:
            from repro.core.refinement import (
                cosketch_omega, cosketch_psi, cosketch_width)
            c_omega = cosketch_omega(key, n2, self.cosketch)
            c_psi = cosketch_psi(key, n1, self.cosketch)
            c_Y = jnp.zeros((n1, self.cosketch), jnp.float32)
            c_W = jnp.zeros((cosketch_width(self.cosketch), n2), jnp.float32)
        else:
            c_omega = c_psi = c_Y = c_W = None
        if self.decay < 1.0:
            decay_rate = jnp.asarray(self.decay, jnp.float32)
            t_state = t_data = jnp.zeros((), jnp.int32)
        else:
            # decay=1.0 keeps the legacy pytree structure: the None fields
            # flatten to nothing, so every historical bit-parity and
            # checkpoint contract is untouched
            decay_rate = t_state = t_data = None
        return StreamState(
            key=key,
            A_acc=jnp.zeros((self.k, n1), jnp.float32),
            B_acc=jnp.zeros((self.k, n2), jnp.float32),
            na2=jnp.zeros((n1,), jnp.float32),
            nb2=jnp.zeros((n2,), jnp.float32),
            rows_seen=jnp.zeros((), jnp.int32),
            row_high=jnp.zeros((), jnp.int32),
            d_total=jnp.asarray(d, jnp.int32),
            signs=signs, srows=srows, omega=omega, probe_acc=probe_acc,
            decay_rate=decay_rate, t_state=t_state, t_data=t_data,
            cosketch_omega=c_omega, cosketch_psi=c_psi,
            cosketch_Y=c_Y, cosketch_W=c_W)

    def update(self, state: StreamState, A_chunk: jax.Array,
               B_chunk: jax.Array, row_offset) -> StreamState:
        """Absorb a contiguous chunk of rows starting at global ``row_offset``.

        ``row_offset`` may be a traced scalar — recompilation keys only on
        the chunk shape. Chunks may arrive in any order and may even repeat
        across partial states as long as each global row is absorbed exactly
        once overall (the summary is a sum over rows). A zero-row chunk is
        the monoid identity: a no-op. With a concrete ``row_offset`` the
        bounds check costs no device work (the chunk is contiguous).
        """
        t = A_chunk.shape[0]
        if B_chunk.shape[0] != t:
            raise ValueError(f"chunk row counts differ: "
                             f"{A_chunk.shape} vs {B_chunk.shape}")
        if t == 0:
            return state
        if isinstance(row_offset, jax.core.Tracer):
            hi1 = jnp.asarray(row_offset, jnp.int32) + t
        else:
            off = int(row_offset)
            _check_row_bounds(state, off, off + t - 1)
            hi1 = off + t
        gids = (jnp.asarray(row_offset, jnp.int32)
                + jnp.arange(t, dtype=jnp.int32))
        return self._absorb(state, A_chunk, B_chunk, gids, t, hi1)

    def update_rows(self, state: StreamState, row_ids: jax.Array,
                    A_rows: jax.Array, B_rows: jax.Array) -> StreamState:
        """Absorb rows with explicit global ids (arbitrary-order arrival —
        the paper's shuffled co-occurrence stream). An empty id array is
        a no-op (the monoid identity)."""
        t = A_rows.shape[0]
        ids = jnp.asarray(row_ids, jnp.int32)
        if B_rows.shape[0] != t or ids.shape[0] != t:
            raise ValueError(
                f"row ids / chunk row counts differ: ids {ids.shape}, "
                f"A {A_rows.shape}, B {B_rows.shape}")
        if t == 0:
            return state
        if isinstance(ids, jax.core.Tracer):
            hi1 = jnp.max(ids) + 1
        else:
            # one fused device fetch for both bounds
            lo, hi = (int(v) for v in
                      jax.device_get(jnp.stack([jnp.min(ids),
                                                jnp.max(ids)])))
            _check_row_bounds(state, lo, hi)
            hi1 = hi + 1
        return self._absorb(state, A_rows, B_rows, ids, t, hi1)

    def merge(self, s1: StreamState, s2: StreamState) -> StreamState:
        """Alias of ``merge_states`` (module-level, needs no config)."""
        return merge_states(s1, s2)

    def advance(self, state: StreamState, dt: int = 1) -> StreamState:
        """Alias of ``decay_state``: advance the logical clock ``dt`` ticks
        (identity on an undecayed summarizer — ``decay=1.0``)."""
        return decay_state(state, dt)

    def finalize(self, state: StreamState) -> SketchSummary:
        """Alias of ``finalize_state`` (module-level, needs no config)."""
        return finalize_state(state)

    # -- conveniences ------------------------------------------------------

    def summarize_chunks(self, key: jax.Array,
                         shapes: Tuple[int, int, int],
                         chunks: Iterable[Tuple[jax.Array, jax.Array]]
                         ) -> SketchSummary:
        """One-call sequential ingestion: ``(A_chunk, B_chunk)`` pairs in row
        order -> finalized summary."""
        state = self.init(key, shapes)
        off = 0
        for A_chunk, B_chunk in chunks:
            state = self.update(state, A_chunk, B_chunk, off)
            off += A_chunk.shape[0]
        return self.finalize(state)

    def ingest(self, state: StreamState,
               chunks: Iterable[Tuple[jax.Array, jax.Array]], *,
               row_offset: Optional[int] = None,
               prefetch: int = 2) -> StreamState:
        """Double-buffered sequential ingestion of ``(A_chunk, B_chunk)``
        pairs in row order.

        Up to ``prefetch`` upcoming chunks are staged host->device with
        ``jax.device_put`` while the fused update for the current chunk is
        still executing — jax dispatch is asynchronous, so the copy for
        chunk ``c+1`` overlaps chunk ``c``'s compute and the pass approaches
        memory-bandwidth speed instead of alternating copy/compute.
        ``prefetch=0`` degrades to the serial copy-then-update loop (the
        overlap-off baseline the ingest benchmark measures against).

        The math is untouched: staging only moves bytes, so ``ingest`` is
        **bit-identical** to the equivalent ``update`` loop at the same
        chunk boundaries (tested in tests/core/test_streaming_ingest.py).
        Chunks start at ``row_offset`` (default: the state's ``row_high``
        cursor — the resume-contiguously convention of ``serve.engine``).
        """
        if isinstance(prefetch, bool) or not isinstance(prefetch, int) \
                or prefetch < 0:
            raise ValueError(
                f"prefetch must be a non-negative chunk count, "
                f"got {prefetch!r}")
        off = int(state.row_high) if row_offset is None else int(row_offset)
        it = iter(chunks)
        staged: collections.deque = collections.deque()

        def _stage_next() -> None:
            try:
                A_chunk, B_chunk = next(it)
            except StopIteration:
                return
            staged.append((jax.device_put(A_chunk), jax.device_put(B_chunk)))

        for _ in range(prefetch + 1):       # prime the pipeline
            _stage_next()
        while staged:
            A_chunk, B_chunk = staged.popleft()
            # enqueue the next host->device copy BEFORE dispatching the
            # update when running serial (prefetch=0) would instead wait
            if prefetch:
                _stage_next()
            state = self.update(state, A_chunk, B_chunk, off)
            off += A_chunk.shape[0]
            if not prefetch:
                jax.block_until_ready(state.A_acc)
                _stage_next()
        return state

    def _absorb(self, state, A_chunk, B_chunk, gids, t, hi1) -> StreamState:
        if A_chunk.shape[0] != B_chunk.shape[0]:
            raise ValueError(f"chunk row counts differ: "
                             f"{A_chunk.shape} vs {B_chunk.shape}")
        # Settle pending decay *before* absorbing: new rows enter at weight
        # 1 (they arrive "now"), old mass is physically scaled down so
        # accumulator magnitudes stay bounded on long decayed streams.
        state = _settle_state(state)
        dA, dB, dna2, dnb2 = _chunk_contribution(
            state.key, state.signs, state.srows, A_chunk, B_chunk, gids,
            k=self.k, method=self.method, precision=self.precision)
        probe_acc = state.probe_acc
        if state.omega is not None:
            probe_acc = probe_acc + _probe_chunk(
                state.omega, A_chunk, B_chunk, precision=self.precision)
        c_Y, c_W = state.cosketch_Y, state.cosketch_W
        if state.cosketch_omega is not None:
            dY, dW = _cosketch_chunk(
                state.cosketch_omega, state.cosketch_psi, A_chunk, B_chunk,
                precision=self.precision)
            c_Y, c_W = c_Y + dY, c_W + dW
        return state._replace(
            A_acc=state.A_acc + dA, B_acc=state.B_acc + dB,
            na2=state.na2 + dna2, nb2=state.nb2 + dnb2,
            rows_seen=state.rows_seen + jnp.int32(t),
            row_high=jnp.maximum(state.row_high,
                                 jnp.asarray(hi1, jnp.int32)),
            probe_acc=probe_acc, cosketch_Y=c_Y, cosketch_W=c_W)


# -- wire format: compressed StreamState for checkpoints and transfer --------

#: sketch-block precisions a WireSpec may name, cheapest-last
WIRE_DTYPES = ("f32", "bf16", "int8")


class WireSpec(NamedTuple):
    """On-the-wire precision policy for a compressed ``StreamState``.

    One knob: the storage dtype of the *sketch-shaped* blocks (the two
    sketches and, when carried, the co-sketch pair) — they dominate the
    state's bytes and are noise-floored by sketching error anyway. The
    squared-norm vectors and the held-out probe block always stay f32: the
    norms are the rescaled estimator's whole advantage, and the probe block
    is the exact side information that *measures* what quantization cost
    (``wire_error``), so it must not itself be quantized. A NamedTuple of
    one string: hashable, so it can ride ``PipelinePlan`` as a cache key.

    >>> WireSpec("bf16").bits
    16
    >>> WireSpec() == WireSpec("f32")   # default: lossless
    True
    """

    sketch: str = "f32"

    @property
    def bits(self) -> int:
        """Storage bits per sketch-block value."""
        return {"f32": 32, "bf16": 16, "int8": 8}[self.sketch]


class CompressedState(NamedTuple):
    """Arrays-only wire image of a *settled* ``StreamState``.

    Everything derivable from ``key`` is dropped: the probe test matrix,
    the co-sketch test pair, and the SRHT sign/sample plan are pure
    functions of ``(key, shape)`` under the engine's randomness contract,
    so ``decompress_state`` regenerates them bit-identically instead of
    shipping them. ``srht`` is a 0/1 scalar recording which method's plan
    to rebuild. Pending decay is settled by ``compress_state``, so only
    ``t_state`` travels (``t_data == t_state`` on arrival). ``*_scale``
    fields are the per-slice symmetric dequantization scales (int8 only).
    """

    key: jax.Array
    A_blk: jax.Array                     # (k, n1) sketch, spec dtype
    B_blk: jax.Array                     # (k, n2) sketch, spec dtype
    na2: jax.Array                       # (n1,) f32 — never quantized
    nb2: jax.Array                       # (n2,) f32 — never quantized
    rows_seen: jax.Array
    row_high: jax.Array
    d_total: jax.Array
    srht: jax.Array                      # () int32: 1 = rebuild an SRHT plan
    A_scale: Optional[jax.Array] = None  # (k, 1) int8 dequant scales
    B_scale: Optional[jax.Array] = None  # (k, 1)
    probe_acc: Optional[jax.Array] = None   # (n1, p) f32 — never quantized
    decay_rate: Optional[jax.Array] = None
    t_state: Optional[jax.Array] = None
    cosketch_Y: Optional[jax.Array] = None  # (n1, s) spec dtype
    cosketch_W: Optional[jax.Array] = None  # (l, n2) spec dtype
    Y_scale: Optional[jax.Array] = None     # (1, s) int8 dequant scales
    W_scale: Optional[jax.Array] = None     # (l, 1)


def _as_wire_spec(spec: Union[WireSpec, str]) -> WireSpec:
    spec = WireSpec(spec) if isinstance(spec, str) else spec
    if not isinstance(spec, WireSpec) or spec.sketch not in WIRE_DTYPES:
        raise ValueError(
            f"wire spec must name a sketch dtype in {WIRE_DTYPES}, "
            f"got {spec!r}")
    return spec


def _quant_block(x: jax.Array, spec: WireSpec, axis: int):
    """(stored block, dequant scale or None) for one sketch-shaped block.

    int8 is symmetric per-slice along ``axis`` (scale = max|x| / 127 with
    keepdims, clamped away from zero so all-zero slices stay exact zeros).
    """
    if spec.sketch == "f32":
        return x, None
    if spec.sketch == "bf16":
        return x.astype(jnp.bfloat16), None
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=axis, keepdims=True),
                        jnp.float32(1e-30)) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant_block(blk: jax.Array, scale: Optional[jax.Array]) -> jax.Array:
    if blk.dtype == jnp.int8:
        return blk.astype(jnp.float32) * scale
    return blk.astype(jnp.float32)


def compress_state(state: StreamState,
                   spec: Union[WireSpec, str] = WireSpec()
                   ) -> CompressedState:
    """StreamState -> its wire image under ``spec``.

    Settles pending decay first (the wire carries one timestamp), then
    stores the sketch-shaped blocks at the spec's precision and everything
    else f32. With the default f32 spec, ``decompress_state`` returns a
    state **bit-identical** to the settled input — structure included
    (property-tested in tests/core/test_streaming_ingest.py).
    """
    spec = _as_wire_spec(spec)
    if state.key is None:
        raise ValueError(
            "compress_state needs the state's base key: the wire format "
            "regenerates the probe/co-sketch test matrices and the SRHT "
            "plan from it instead of shipping them")
    state = _settle_state(state)
    A_blk, A_scale = _quant_block(state.A_acc, spec, 1)
    B_blk, B_scale = _quant_block(state.B_acc, spec, 1)
    c_Y = c_W = Y_s = W_s = None
    if state.cosketch_Y is not None:
        c_Y, Y_s = _quant_block(state.cosketch_Y, spec, 0)
        c_W, W_s = _quant_block(state.cosketch_W, spec, 1)
    return CompressedState(
        key=state.key, A_blk=A_blk, B_blk=B_blk,
        na2=state.na2, nb2=state.nb2,
        rows_seen=state.rows_seen, row_high=state.row_high,
        d_total=state.d_total,
        srht=jnp.asarray(0 if state.signs is None else 1, jnp.int32),
        A_scale=A_scale, B_scale=B_scale,
        probe_acc=state.probe_acc,
        decay_rate=state.decay_rate, t_state=state.t_state,
        cosketch_Y=c_Y, cosketch_W=c_W, Y_scale=Y_s, W_scale=W_s)


def decompress_state(comp: CompressedState) -> StreamState:
    """Wire image -> a full ``StreamState`` ready to keep absorbing rows.

    Rebuilds every key-derived field (probe omega, co-sketch test pair,
    SRHT plan) from ``comp.key`` — bit-identical to the originals by the
    (key, index) randomness contract — and dequantizes the sketch blocks.
    """
    k, n1 = comp.A_blk.shape
    n2 = comp.B_blk.shape[1]
    if int(comp.srht):
        signs, srows, _ = srht_plan(comp.key, int(comp.d_total), k)
    else:
        signs = srows = None
    omega = None
    if comp.probe_acc is not None:
        from repro.core.error_engine import probe_omega
        omega = probe_omega(comp.key, n2, comp.probe_acc.shape[1])
    c_omega = c_psi = c_Y = c_W = None
    if comp.cosketch_Y is not None:
        from repro.core.refinement import cosketch_omega, cosketch_psi
        s = comp.cosketch_Y.shape[1]
        c_omega = cosketch_omega(comp.key, n2, s)
        c_psi = cosketch_psi(comp.key, n1, s)
        c_Y = _dequant_block(comp.cosketch_Y, comp.Y_scale)
        c_W = _dequant_block(comp.cosketch_W, comp.W_scale)
    return StreamState(
        key=comp.key,
        A_acc=_dequant_block(comp.A_blk, comp.A_scale),
        B_acc=_dequant_block(comp.B_blk, comp.B_scale),
        na2=comp.na2, nb2=comp.nb2,
        rows_seen=comp.rows_seen, row_high=comp.row_high,
        d_total=comp.d_total, signs=signs, srows=srows,
        omega=omega, probe_acc=comp.probe_acc,
        decay_rate=comp.decay_rate,
        t_state=comp.t_state, t_data=comp.t_state,
        cosketch_omega=c_omega, cosketch_psi=c_psi,
        cosketch_Y=c_Y, cosketch_W=c_W)


def wire_bytes(comp: CompressedState) -> int:
    """Payload bytes of a wire image (array bytes; the pack header — a few
    dozen bytes of field names — is excluded)."""
    return sum(int(leaf.nbytes) for leaf in comp if leaf is not None)


def wire_pack(comp: CompressedState) -> bytes:
    """Serialize a wire image to self-describing bytes (a JSON field header
    + raw little-endian array payloads) — what actually crosses hosts in
    ``dist.multihost.cross_host_merge`` and lands in compressed
    checkpoints' transport tests."""
    import numpy as np
    header, payload = [], []
    for name, leaf in zip(comp._fields, comp):
        if leaf is None:
            continue
        # NOTE: not ascontiguousarray — it promotes 0-d scalars to 1-d,
        # and tobytes() already serialises any layout in C order
        arr = np.asarray(leaf)
        header.append({"field": name, "dtype": str(arr.dtype),
                       "shape": list(arr.shape)})
        payload.append(arr.tobytes())
    head = json.dumps(header).encode("utf-8")
    return struct.pack("<I", len(head)) + head + b"".join(payload)


def wire_unpack(data: bytes) -> CompressedState:
    """Inverse of ``wire_pack``."""
    import numpy as np
    (hlen,) = struct.unpack_from("<I", data, 0)
    header = json.loads(data[4:4 + hlen].decode("utf-8"))
    off = 4 + hlen
    kw = {}
    for field in header:
        dt = np.dtype(field["dtype"])
        count = 1
        for dim in field["shape"]:
            count *= int(dim)
        arr = np.frombuffer(data, dtype=dt, count=count, offset=off)
        kw[field["field"]] = jnp.asarray(arr.reshape(field["shape"]))
        off += dt.itemsize * count
    return CompressedState(**kw)


def wire_error(state: StreamState, spec: Union[WireSpec, str]) -> float:
    """Probe-measured relative error a round-trip through ``spec`` adds.

    The held-out probe block ``b_j = (A^T B) w_j`` is *exact* side
    information riding the state, so quantization cost is measurable
    without ever forming the n1 x n2 product: sketch-estimate each probe
    from the original and the decompressed state (``A_acc^T (B_acc w_j)``,
    O(k·n·p)), and return

        sqrt(mean_j ||dev_j||^2 / ||w_j||^2) / ||M||_F_est,

    where ``dev_j`` is the per-probe deviation and ``||M||_F_est`` is the
    ErrorEngine's unbiased Frobenius estimate from the exact probe block —
    the same estimator ``estimate_error`` applies to the decompressed
    summary. f32 round-trips are bit-identical, so their error is exactly
    0.0; the result feeds the ``choose_wire_spec`` gate.
    """
    if state.omega is None:
        raise ValueError(
            "wire_error needs the held-out probe block (init the stream "
            "with probes>0) — it is the exact reference quantization "
            "error is measured against")
    spec = _as_wire_spec(spec)
    settled = _settle_state(state)
    rt = decompress_state(compress_state(settled, spec))
    w = settled.omega

    def sketch_probe(s: StreamState) -> jax.Array:
        return s.A_acc.T @ (s.B_acc @ w)        # ~ M @ w, never n1 x n2

    dev = sketch_probe(rt) - sketch_probe(settled)
    wn2 = jnp.sum(w.astype(jnp.float32) ** 2, axis=0)
    frob_dev = jnp.sqrt(jnp.mean(jnp.sum(dev ** 2, axis=0) / wn2))
    frob_m = jnp.sqrt(jnp.mean(
        jnp.sum(settled.probe_acc ** 2, axis=0) / wn2))
    return float(frob_dev / jnp.maximum(frob_m, jnp.float32(1e-30)))


def choose_wire_spec(state: StreamState, tol: float,
                     specs: Sequence[Union[WireSpec, str]] =
                     ("int8", "bf16", "f32")
                     ) -> Tuple[WireSpec, float]:
    """The probe-measured compression gate: cheapest spec meeting ``tol``.

    Tries ``specs`` in order (fewest wire bytes first) and returns the
    first whose ``wire_error`` is within ``tol``, with the measured error.
    f32 is lossless (error exactly 0.0), so the gate is total: when no
    candidate meets ``tol`` it falls back to f32. Used before checkpoint
    writes
    (``ckpt.checkpoint.save_stream_state(wire="auto")``) and inter-host
    transfer (``dist.multihost.cross_host_merge``).
    """
    if isinstance(tol, bool) or not isinstance(tol, (int, float)) \
            or not float(tol) > 0.0:
        raise ValueError(
            f"gate tolerance must be a positive relative error, got {tol!r}")
    for spec in specs:
        spec = _as_wire_spec(spec)
        err = 0.0 if spec.sketch == "f32" else wire_error(state, spec)
        if err <= float(tol):
            return spec, err
    return WireSpec("f32"), 0.0   # lossless meets any tolerance


# -- sliding window over epochs ----------------------------------------------

_WINDOW_TAG = 0x77647721  # ascii "wdw!" — reserved fold tag for bucket keys


def window_bucket_key(key: jax.Array, epoch) -> jax.Array:
    """Projection key for the window bucket holding ``epoch``.

    Two-level reserved fold (the tenant/probe scheme): fold the window tag
    first, then the epoch — so bucket keys can never collide with row folds,
    tenant folds, or probe folds of the same base key, and bucket-local row
    ids may repeat across epochs without reusing projection columns.
    Golden-pinned in tests/core/test_key_contract.py.
    """
    if not isinstance(epoch, jax.core.Tracer):
        epoch = int(epoch)
        if epoch < 0:
            raise ValueError(
                f"window epoch must be non-negative, got {epoch}")
    return jax.random.fold_in(jax.random.fold_in(key, _WINDOW_TAG), epoch)


class WindowState(NamedTuple):
    """Sliding-window summary: a ring of per-epoch partial ``StreamState``s.

    ``buckets[e % n_buckets]`` holds epoch ``e``'s rows; ``head`` is the
    newest live epoch, so the window always covers epochs
    ``head - n_buckets + 1 .. head`` (every slot is live — a fresh window
    starts at ``head = n_buckets - 1`` over all-empty past epochs). The
    whole thing is a pytree: it checkpoints via
    ``ckpt.checkpoint.save_window_state`` with ``head`` in the manifest.
    """

    key: jax.Array                    # base PRNG key (bucket keys fold from it)
    buckets: Tuple[StreamState, ...]  # ring; slot e % n_buckets holds epoch e
    head: jax.Array                   # () int32 newest live epoch

    @property
    def n_buckets(self) -> int:
        """Ring size (the window length in epochs)."""
        return len(self.buckets)


class WindowedSummarizer:
    """Sliding-window front-end: the summary of the last ``n_buckets`` epochs.

    Keeps a ring of ``n_buckets`` partial ``StreamState``s (one per epoch,
    each under its own ``window_bucket_key``); the window summary is the
    merge of the live buckets, and ``slide`` retires the oldest epoch in
    O(1) by re-initializing a single ring slot — no rescan, no subtraction.
    Updates land in the head epoch with *bucket-local* row ids (each epoch
    is its own 0..d-1 row space). The ring bookkeeping is host-side eager
    (slot selection needs a concrete ``head``); the per-bucket math is the
    jitted StreamingSummarizer path unchanged.

    With probes, every bucket shares the *base* key's probe test matrix
    (``probe_omega(key, n2, p)``) — probe blocks are linear in the data, so
    they merge across buckets only against a common omega, and the window's
    ``estimate_error`` stays unbiased for the windowed product.

    >>> import jax, jax.numpy as jnp
    >>> win = WindowedSummarizer(k=4, n_buckets=2)
    >>> key = jax.random.PRNGKey(0)
    >>> A = jax.random.normal(key, (8, 3))
    >>> B = jax.random.normal(jax.random.fold_in(key, 1), (8, 2))
    >>> w = win.init(key, (8, 3, 2))
    >>> w = win.update(w, A, B, 0)   # rows land in the head epoch
    >>> w = win.slide(w)             # next epoch opens, oldest expires
    >>> int(jnp.sum(win.merged(w).rows_seen))   # still inside the window
    8
    >>> w = win.slide(w)             # the epoch holding those rows expires
    >>> bool(jnp.all(win.finalize(w).A_sketch == 0))
    True
    """

    def __init__(self, k: int, n_buckets: int, *,
                 method: str = "gaussian",
                 precision: Optional[str] = None, probes: int = 0,
                 cosketch: int = 0):
        if isinstance(n_buckets, bool) or not isinstance(n_buckets, int) \
                or n_buckets < 1:
            raise ValueError(
                f"n_buckets must be a positive int (the window length in "
                f"epochs), got {n_buckets!r}")
        self.n_buckets = n_buckets
        self._inner = StreamingSummarizer(
            k, method=method, precision=precision, probes=probes,
            cosketch=cosketch)

    @property
    def k(self) -> int:
        """Sketch size of every bucket."""
        return self._inner.k

    @property
    def method(self) -> str:
        """Sketch method of every bucket."""
        return self._inner.method

    @property
    def probes(self) -> int:
        """Held-out probe count carried by every bucket."""
        return self._inner.probes

    @property
    def cosketch(self) -> int:
        """Co-sketch width carried by every bucket."""
        return self._inner.cosketch

    def _fresh_bucket(self, key, shapes, epoch, omega,
                      cpair=None) -> StreamState:
        bucket = self._inner.init(window_bucket_key(key, epoch), shapes)
        if omega is not None:
            # all buckets share the BASE key's probe matrix: probe blocks
            # only sum across buckets against a common omega
            bucket = bucket._replace(omega=omega)
        if cpair is not None:
            # same sharing for the co-sketch test pair: (Y, W) blocks only
            # sum across buckets against a common (Omega_c, Psi_c)
            bucket = bucket._replace(cosketch_omega=cpair[0],
                                     cosketch_psi=cpair[1])
        return bucket

    def init(self, key: jax.Array,
             shapes: Tuple[int, int, int]) -> WindowState:
        """Empty window for a (d, n1, n2) stream: ``head = n_buckets - 1``
        over all-empty epochs ``0 .. n_buckets - 1`` (``d`` is the per-epoch
        row space — bucket-local ids restart each epoch)."""
        if self._inner.probes:
            from repro.core.error_engine import probe_omega
            omega = probe_omega(key, shapes[2], self._inner.probes)
        else:
            omega = None
        if self._inner.cosketch:
            from repro.core.refinement import cosketch_omega, cosketch_psi
            cpair = (cosketch_omega(key, shapes[2], self._inner.cosketch),
                     cosketch_psi(key, shapes[1], self._inner.cosketch))
        else:
            cpair = None
        buckets = tuple(self._fresh_bucket(key, shapes, e, omega, cpair)
                        for e in range(self.n_buckets))
        return WindowState(key=key, buckets=buckets,
                           head=jnp.asarray(self.n_buckets - 1, jnp.int32))

    def _check_ring(self, wstate: WindowState) -> None:
        if len(wstate.buckets) != self.n_buckets:
            raise ValueError(
                f"window state carries {len(wstate.buckets)} buckets but "
                f"this summarizer expects n_buckets={self.n_buckets}")

    def _with_head_bucket(self, wstate, bucket) -> WindowState:
        slot = int(wstate.head) % self.n_buckets
        buckets = list(wstate.buckets)
        buckets[slot] = bucket
        return wstate._replace(buckets=tuple(buckets))

    def update(self, wstate: WindowState, A_chunk, B_chunk,
               row_offset) -> WindowState:
        """Absorb a contiguous chunk into the head epoch (bucket-local
        ``row_offset``)."""
        self._check_ring(wstate)
        slot = int(wstate.head) % self.n_buckets
        return self._with_head_bucket(wstate, self._inner.update(
            wstate.buckets[slot], A_chunk, B_chunk, row_offset))

    def update_rows(self, wstate: WindowState, row_ids, A_rows,
                    B_rows) -> WindowState:
        """Absorb rows with explicit bucket-local ids into the head epoch."""
        self._check_ring(wstate)
        slot = int(wstate.head) % self.n_buckets
        return self._with_head_bucket(wstate, self._inner.update_rows(
            wstate.buckets[slot], row_ids, A_rows, B_rows))

    def ingest(self, wstate: WindowState,
               chunks: Iterable[Tuple[jax.Array, jax.Array]], *,
               row_offset: Optional[int] = None,
               prefetch: int = 2) -> WindowState:
        """Double-buffered ingestion into the head epoch: delegates to the
        inner ``StreamingSummarizer.ingest`` on the head bucket (same
        overlap, same bit-parity contract, bucket-local row ids)."""
        self._check_ring(wstate)
        slot = int(wstate.head) % self.n_buckets
        return self._with_head_bucket(wstate, self._inner.ingest(
            wstate.buckets[slot], chunks, row_offset=row_offset,
            prefetch=prefetch))

    def slide(self, wstate: WindowState, n: int = 1) -> WindowState:
        """Advance the window by ``n`` epochs — O(1) per epoch: the expiring
        slot is re-initialized (under the *new* epoch's bucket key), nothing
        else is touched."""
        self._check_ring(wstate)
        if isinstance(n, bool) or not isinstance(n, int) or n < 1:
            raise ValueError(
                f"slide needs a positive epoch count, got {n!r}")
        ref = wstate.buckets[0]
        shapes = (int(ref.d_total), ref.A_acc.shape[1], ref.B_acc.shape[1])
        cpair = (None if ref.cosketch_omega is None
                 else (ref.cosketch_omega, ref.cosketch_psi))
        head = int(wstate.head)
        buckets = list(wstate.buckets)
        for _ in range(n):
            head += 1
            buckets[head % self.n_buckets] = self._fresh_bucket(
                wstate.key, shapes, head, ref.omega, cpair)
        return wstate._replace(buckets=tuple(buckets),
                               head=jnp.asarray(head, jnp.int32))

    def merged(self, wstate: WindowState) -> StreamState:
        """The window as one ``StreamState``: live buckets merged in
        ascending epoch order (a fixed merge tree, so a window rebuilt from
        the same buckets merges bit-identically)."""
        self._check_ring(wstate)
        head = int(wstate.head)
        return tree_merge([wstate.buckets[e % self.n_buckets]
                           for e in range(head - self.n_buckets + 1,
                                          head + 1)])

    def finalize(self, wstate: WindowState) -> SketchSummary:
        """Finalize the merged window into a Step-1 ``SketchSummary``."""
        return finalize_state(self.merged(wstate))
