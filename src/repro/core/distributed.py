"""Distributed one-pass summary: the Spark treeAggregate as TPU collectives.

The streamed dimension d (rows of A, B) is sharded across a mesh axis. Each
device sketches its local row shard with *its slice of the global Pi* (rows of
Pi are indexed by global row id, so the math is identical to the single-device
pass), then a single ``psum`` aggregates sketches and squared column norms.
This is exactly the paper's distributed design: sketch-contributions form a
commutative monoid; Spark's shuffle tree becomes one ICI all-reduce.

Also provides the row-sharded distributed WAltMin: U rows live on the devices
that own them, V is replicated (it is n2 x r — tiny), each half-iteration is
embarrassingly parallel over rows followed by a psum for the V-side normal
equations.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map
except ImportError:  # newer jax promoted it to the top level
    from jax import shard_map

from repro.core.types import LowRankFactors, SketchSummary

# ``axis`` arguments accept a single mesh axis name (flat all-reduce) or an
# ``(outer, inner)`` pair — e.g. ``("host", "device")`` — for the
# hierarchical tree-reduce: intra-host psum over local devices first, then
# one inter-host all-reduce per accumulator block.


def _reduce_axes(mesh: Mesh, axis) -> tuple[str, ...]:
    """Normalize ``axis`` to the reduction hierarchy (outer..inner)."""
    axes = (axis,) if isinstance(axis, str) else tuple(axis)
    if not axes or not all(isinstance(a, str) and a in mesh.shape
                           for a in axes):
        raise ValueError(
            f"axis must name mesh axes out of {tuple(mesh.shape)}, "
            f"got {axis!r}")
    return axes


def _axes_size(mesh: Mesh, axes: tuple[str, ...]) -> int:
    size = 1
    for ax in axes:
        size *= mesh.shape[ax]
    return size


def _shard_index(mesh: Mesh, axes: tuple[str, ...]) -> jax.Array:
    """Global shard position: row-major over the hierarchy (the same order
    ``PartitionSpec((outer, inner))`` lays rows out in)."""
    idx = jax.lax.axis_index(axes[0])
    for ax in axes[1:]:
        idx = idx * mesh.shape[ax] + jax.lax.axis_index(ax)
    return idx


def _block_psum(x: jax.Array, axes: tuple[str, ...]) -> jax.Array:
    """Hierarchical tree-reduce for the large accumulator blocks: psum the
    innermost (device) level first, then one all-reduce per outer (host)
    level — merge is a plain sum, so this is the flat psum reassociated
    (bit-commutative; equal up to float reassociation tolerance)."""
    for ax in reversed(axes):
        x = jax.lax.psum(x, ax)
    return x


def _scalar_psum(x: jax.Array, axes: tuple[str, ...]) -> jax.Array:
    """Single fused all-reduce over the whole hierarchy for the tiny
    squared-norm vectors — one collective over the same devices in the same
    order as the flat path, so norms stay **bit-exact** between the
    hierarchical and flat reductions (pinned by tests/dist)."""
    return jax.lax.psum(x, axes if len(axes) > 1 else axes[0])


def _pad_rows(X: jax.Array, rows: int) -> jax.Array:
    """Zero-pad the leading (row) dim up to ``rows``. Zero rows are exact
    identities for every accumulator: they contribute 0.0 to sketches,
    squared norms, probes, and co-sketches alike."""
    pad = rows - X.shape[0]
    return X if pad == 0 else jnp.pad(X, ((0, pad),) + ((0, 0),) *
                                      (X.ndim - 1))


def distributed_sketch_summary(mesh: Mesh, axis, key: jax.Array,
                               A: jax.Array, B: jax.Array, k: int,
                               method: str = "gaussian",
                               precision: str | None = None
                               ) -> SketchSummary:
    """One-pass summary with A, B sharded over rows (the d axis) on ``axis``.

    The projection operator is never materialized globally: each shard
    generates the operator columns for its own global row range from
    (key, global_row_index) via the SummaryEngine's shared randomness
    contract — identical values regardless of the number of shards (the
    srht sign/sample plan is derived from ``key`` alone, so it is the same
    on every shard). Registered as the engine's 'distributed' backend.

    ``axis`` may be one mesh axis (flat all-reduce) or an
    ``(outer, inner)`` hierarchy such as ``("host", "device")`` — the
    sketch blocks then tree-reduce intra-host first, one inter-host
    all-reduce per block. A ragged ``d`` (not a multiple of the shard
    count) is handled by zero-padding the trailing shard: zero rows are
    exact identities, and the SRHT plan is still derived from the *real*
    ``d``, so the summary is bit-identical to passing pre-padded inputs.
    """
    from repro.core.summary_engine import (
        _cast, pi_rows, srht_plan, srht_rows_from_plan)
    axes = _reduce_axes(mesh, axis)
    n_shards = _axes_size(mesh, axes)
    d = A.shape[0]
    d_pad = -(-d // n_shards) * n_shards
    shard_rows = d_pad // n_shards
    if method == "srht":
        # the plan is shard-independent (derived from key alone, for the
        # REAL d); jax's no-replacement sampler does not trace inside
        # shard_map, so derive it once here and close over it (replicated)
        signs, srows, _ = srht_plan(key, d, k)
    elif method != "gaussian":
        raise ValueError(f"unknown sketch method {method!r}")

    def _local_pass(A_loc, B_loc):
        row0 = _shard_index(mesh, axes) * shard_rows
        gids = row0 + jnp.arange(shard_rows)
        if method == "gaussian":
            P_loc = pi_rows(key, gids, k)
        else:
            # clamp padded ids into the sign vector: their operator values
            # are arbitrary, but they only ever multiply zero-padded rows
            P_loc = srht_rows_from_plan(signs[jnp.minimum(gids, d - 1)],
                                        srows, gids, k)
        Ac = _cast(A_loc, precision)
        Bc = _cast(B_loc, precision)
        dot = lambda X: jax.lax.dot_general(
            _cast(P_loc, precision).astype(X.dtype), X,
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        As = _block_psum(dot(Ac), axes)
        Bs = _block_psum(dot(Bc), axes)
        na2 = _scalar_psum(jnp.sum(Ac.astype(jnp.float32) ** 2, axis=0), axes)
        nb2 = _scalar_psum(jnp.sum(Bc.astype(jnp.float32) ** 2, axis=0), axes)
        return SketchSummary(As, Bs, jnp.sqrt(na2), jnp.sqrt(nb2))

    spec = P(axes if len(axes) > 1 else axes[0], None)
    fn = shard_map(
        _local_pass, mesh=mesh,
        in_specs=(spec, spec),
        out_specs=SketchSummary(P(None, None), P(None, None), P(None), P(None)),
    )
    return fn(_pad_rows(A, d_pad), _pad_rows(B, d_pad))


def distributed_streaming_update(mesh: Mesh, axis, summarizer,
                                 state, A_slab: jax.Array, B_slab: jax.Array,
                                 row_offset: int = 0):
    """Absorb a row-sharded slab into a replicated ``StreamState``.

    The slab's rows (global ids ``row_offset .. row_offset + slab_d``) are
    sharded over ``axis``; each device computes its shard's contribution with
    its slice of the global projection (the engine's (key, global row id)
    contract), then ONE psum per block merges the per-device partial states —
    the all-reduce IS the ``streaming.merge`` tree-reduction, executed on the
    ICI (Spark's treeAggregate combiner collapsed into a collective). The
    merged state is returned replicated, ready for the next slab or
    ``finalize``.

    ``axis`` may be an ``(outer, inner)`` hierarchy — ``("host",
    "device")`` — in which case the sketch/probe/co-sketch blocks
    tree-reduce intra-host first and cross hosts once per block, while the
    tiny squared-norm vectors take a single fused all-reduce (bit-exact
    with the flat path). A ragged slab is zero-padded onto the trailing
    shard (zero rows are exact identities); ``rows_seen``/``row_high``
    track the *real* row count.
    """
    from repro.core.streaming import StreamState, merge_states
    axes = _reduce_axes(mesh, axis)
    n_shards = _axes_size(mesh, axes)
    slab_d = A_slab.shape[0]
    slab_pad = -(-slab_d // n_shards) * n_shards
    shard_rows = slab_pad // n_shards
    key, signs, srows = state.key, state.signs, state.srows
    k = summarizer.k

    omega = state.omega
    c_omega, c_psi = state.cosketch_omega, state.cosketch_psi

    def _local_delta(A_loc, B_loc):
        idx = _shard_index(mesh, axes)
        gids = row_offset + idx * shard_rows + jnp.arange(shard_rows)
        from repro.core.streaming import _chunk_contribution
        dA, dB, dna2, dnb2 = _chunk_contribution(
            key, signs, srows, A_loc, B_loc, gids, k=k,
            method=summarizer.method, precision=summarizer.precision)
        # the psum over shards IS the merge of the per-device partial states
        out = (_block_psum(dA, axes), _block_psum(dB, axes),
               _scalar_psum(dna2, axes), _scalar_psum(dnb2, axes))
        if omega is not None:
            # the probe block is linear in the rows too: same one psum
            from repro.core.error_engine import probe_contribution
            dprobe = probe_contribution(omega, A_loc, B_loc,
                                        summarizer.precision)
            out = out + (_block_psum(dprobe, axes),)
        if c_omega is not None:
            # ... and so is the refinement co-sketch pair
            from repro.core.refinement import cosketch_contribution
            dY, dW = cosketch_contribution(c_omega, c_psi, A_loc, B_loc,
                                           summarizer.precision)
            out = out + (_block_psum(dY, axes), _block_psum(dW, axes))
        return out

    out_specs = (P(None, None), P(None, None), P(None), P(None))
    if omega is not None:
        out_specs = out_specs + (P(None, None),)
    if c_omega is not None:
        out_specs = out_specs + (P(None, None), P(None, None))
    in_spec = P(axes if len(axes) > 1 else axes[0], None)
    fn = shard_map(_local_delta, mesh=mesh,
                   in_specs=(in_spec, in_spec),
                   out_specs=out_specs)
    parts = fn(_pad_rows(A_slab, slab_pad), _pad_rows(B_slab, slab_pad))
    dA, dB, dna2, dnb2 = parts[:4]
    nxt = 4
    dprobe = None
    if omega is not None:
        dprobe, nxt = parts[nxt], nxt + 1
    dY = dW = None
    if c_omega is not None:
        dY, dW = parts[nxt], parts[nxt + 1]
    # A decayed delta arrives "now": its data timestamp is the state's
    # logical clock, so the merge alignment settles the state's pending
    # decay (gamma^(t_state - t_data), the same scalar multiply the
    # single-device update performs) and adds the fresh rows at weight 1 —
    # decay commutes with the psum because both are linear.
    delta = StreamState(key=None, A_acc=dA, B_acc=dB, na2=dna2, nb2=dnb2,
                        rows_seen=jnp.asarray(slab_d, jnp.int32),
                        row_high=jnp.asarray(row_offset + slab_d, jnp.int32),
                        d_total=state.d_total, signs=signs, srows=srows,
                        omega=omega, probe_acc=dprobe,
                        decay_rate=state.decay_rate,
                        t_state=state.t_state, t_data=state.t_state,
                        cosketch_omega=c_omega, cosketch_psi=c_psi,
                        cosketch_Y=dY, cosketch_W=dW)
    return merge_states(state, delta)


def distributed_streaming_summary(mesh: Mesh, axis, key: jax.Array,
                                  A: jax.Array, B: jax.Array, k: int,
                                  method: str = "gaussian",
                                  precision: str | None = None,
                                  slab: int | None = None,
                                  probes: int = 0, cosketch: int = 0):
    """Full streaming pass over row-sharded (A, B): slab-chunked ingestion +
    per-slab tree-merge. With ``slab=None`` the whole pair is one slab —
    semantically ``distributed_sketch_summary`` re-expressed through the
    streaming monoid (parity-tested in tests/core/test_streaming.py).
    ``probes`` retains the held-out probe block, ``cosketch`` the refinement
    co-sketch pair (their per-shard contributions merge through the same
    psum as the sketches). ``axis`` accepts the ``("host", "device")``
    hierarchy, and a ragged ``d`` zero-pads the trailing shard of the last
    slab (exact — zero rows contribute nothing)."""
    from repro.core.streaming import StreamingSummarizer
    d = A.shape[0]
    n_shards = _axes_size(mesh, _reduce_axes(mesh, axis))
    summ = StreamingSummarizer(k, method=method, precision=precision,
                               probes=probes, cosketch=cosketch)
    state = summ.init(key, (d, A.shape[1], B.shape[1]))
    slab = d if slab is None else slab
    # round full slabs to a shard multiple; the trailing partial slab is
    # zero-padded by distributed_streaming_update
    slab = max(n_shards, slab - slab % n_shards)
    for off in range(0, d, slab):
        state = distributed_streaming_update(
            mesh, axis, summ, state, A[off:off + slab], B[off:off + slab],
            row_offset=off)
    return summ.finalize(state)


def distributed_smppca(mesh: Mesh, axis: str, key: jax.Array, A: jax.Array,
                       B: jax.Array, *, r: int, k: int, m: int, T: int = 10,
                       method: str = "gaussian") -> LowRankFactors:
    """Full distributed pipeline. Steps 2-3 run replicated (they are o(n k + m
    r^2 T) — negligible next to the pass) after the single all-reduced pass;
    every device computes identical factors (same seed), mirroring the
    every-worker-completes design of the gradient compressor."""
    from repro.core.summary_engine import build_summary
    k1, k2 = jax.random.split(key)
    summary = build_summary(k1, A, B, k, method=method, backend="distributed",
                            mesh=mesh, axis=axis)
    from repro.core.smppca import smppca_from_summary
    return smppca_from_summary(k2, summary, r=r, m=m, T=T).factors
