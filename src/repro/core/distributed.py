"""Distributed one-pass summary: the Spark treeAggregate as TPU collectives.

The streamed dimension d (rows of A, B) is sharded across a mesh axis. Each
device sketches its local row shard with *its slice of the global Pi* (rows of
Pi are indexed by global row id, so the math is identical to the single-device
pass), then a single ``psum`` aggregates sketches and squared column norms.
This is exactly the paper's distributed design: sketch-contributions form a
commutative monoid; Spark's shuffle tree becomes one ICI all-reduce.

Also provides the row-sharded distributed WAltMin: U rows live on the devices
that own them, V is replicated (it is n2 x r — tiny), each half-iteration is
embarrassingly parallel over rows followed by a psum for the V-side normal
equations.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map
except ImportError:  # newer jax promoted it to the top level
    from jax import shard_map

from repro.core.types import LowRankFactors, SketchSummary


def distributed_sketch_summary(mesh: Mesh, axis: str, key: jax.Array,
                               A: jax.Array, B: jax.Array, k: int,
                               method: str = "gaussian",
                               precision: str | None = None
                               ) -> SketchSummary:
    """One-pass summary with A, B sharded over rows (the d axis) on ``axis``.

    The projection operator is never materialized globally: each shard
    generates the operator columns for its own global row range from
    (key, global_row_index) via the SummaryEngine's shared randomness
    contract — identical values regardless of the number of shards (the
    srht sign/sample plan is derived from ``key`` alone, so it is the same
    on every shard). Registered as the engine's 'distributed' backend.
    """
    from repro.core.summary_engine import (
        _cast, pi_rows, srht_plan, srht_rows_from_plan)
    n_shards = mesh.shape[axis]
    d = A.shape[0]
    if d % n_shards != 0:
        raise ValueError(f"row dim ({d}) must be a multiple of the mesh "
                         f"axis size ({n_shards})")
    shard_rows = d // n_shards
    if method == "srht":
        # the plan is shard-independent (derived from key alone); jax's
        # no-replacement sampler does not trace inside shard_map, so derive
        # it once here and close over it (replicated on every shard)
        signs, srows, _ = srht_plan(key, d, k)
    elif method != "gaussian":
        raise ValueError(f"unknown sketch method {method!r}")

    def _local_pass(A_loc, B_loc):
        idx = jax.lax.axis_index(axis)
        row0 = idx * shard_rows
        gids = row0 + jnp.arange(shard_rows)
        if method == "gaussian":
            P_loc = pi_rows(key, gids, k)
        else:
            P_loc = srht_rows_from_plan(signs[gids], srows, gids, k)
        Ac = _cast(A_loc, precision)
        Bc = _cast(B_loc, precision)
        dot = lambda X: jax.lax.dot_general(
            _cast(P_loc, precision).astype(X.dtype), X,
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        As = jax.lax.psum(dot(Ac), axis)
        Bs = jax.lax.psum(dot(Bc), axis)
        na2 = jax.lax.psum(jnp.sum(Ac.astype(jnp.float32) ** 2, axis=0), axis)
        nb2 = jax.lax.psum(jnp.sum(Bc.astype(jnp.float32) ** 2, axis=0), axis)
        return SketchSummary(As, Bs, jnp.sqrt(na2), jnp.sqrt(nb2))

    fn = shard_map(
        _local_pass, mesh=mesh,
        in_specs=(P(axis, None), P(axis, None)),
        out_specs=SketchSummary(P(None, None), P(None, None), P(None), P(None)),
    )
    return fn(A, B)


def distributed_streaming_update(mesh: Mesh, axis: str, summarizer,
                                 state, A_slab: jax.Array, B_slab: jax.Array,
                                 row_offset: int = 0):
    """Absorb a row-sharded slab into a replicated ``StreamState``.

    The slab's rows (global ids ``row_offset .. row_offset + slab_d``) are
    sharded over ``axis``; each device computes its shard's contribution with
    its slice of the global projection (the engine's (key, global row id)
    contract), then ONE psum merges the per-device partial states — the
    all-reduce IS the ``streaming.merge`` tree-reduction, executed on the ICI
    (Spark's treeAggregate combiner collapsed into a collective). The merged
    state is returned replicated, ready for the next slab or ``finalize``.
    """
    from repro.core.streaming import StreamState, merge_states
    n_shards = mesh.shape[axis]
    slab_d = A_slab.shape[0]
    if slab_d % n_shards != 0:
        raise ValueError(f"slab rows ({slab_d}) must be a multiple of the "
                         f"mesh axis size ({n_shards})")
    shard_rows = slab_d // n_shards
    key, signs, srows = state.key, state.signs, state.srows
    k = summarizer.k

    omega = state.omega
    c_omega, c_psi = state.cosketch_omega, state.cosketch_psi

    def _local_delta(A_loc, B_loc):
        idx = jax.lax.axis_index(axis)
        gids = row_offset + idx * shard_rows + jnp.arange(shard_rows)
        from repro.core.streaming import _chunk_contribution
        dA, dB, dna2, dnb2 = _chunk_contribution(
            key, signs, srows, A_loc, B_loc, gids, k=k,
            method=summarizer.method, precision=summarizer.precision)
        # the psum over shards IS the merge of the per-device partial states
        out = (jax.lax.psum(dA, axis), jax.lax.psum(dB, axis),
               jax.lax.psum(dna2, axis), jax.lax.psum(dnb2, axis))
        if omega is not None:
            # the probe block is linear in the rows too: same one psum
            from repro.core.error_engine import probe_contribution
            dprobe = probe_contribution(omega, A_loc, B_loc,
                                        summarizer.precision)
            out = out + (jax.lax.psum(dprobe, axis),)
        if c_omega is not None:
            # ... and so is the refinement co-sketch pair
            from repro.core.refinement import cosketch_contribution
            dY, dW = cosketch_contribution(c_omega, c_psi, A_loc, B_loc,
                                           summarizer.precision)
            out = out + (jax.lax.psum(dY, axis), jax.lax.psum(dW, axis))
        return out

    out_specs = (P(None, None), P(None, None), P(None), P(None))
    if omega is not None:
        out_specs = out_specs + (P(None, None),)
    if c_omega is not None:
        out_specs = out_specs + (P(None, None), P(None, None))
    fn = shard_map(_local_delta, mesh=mesh,
                   in_specs=(P(axis, None), P(axis, None)),
                   out_specs=out_specs)
    parts = fn(A_slab, B_slab)
    dA, dB, dna2, dnb2 = parts[:4]
    nxt = 4
    dprobe = None
    if omega is not None:
        dprobe, nxt = parts[nxt], nxt + 1
    dY = dW = None
    if c_omega is not None:
        dY, dW = parts[nxt], parts[nxt + 1]
    # A decayed delta arrives "now": its data timestamp is the state's
    # logical clock, so the merge alignment settles the state's pending
    # decay (gamma^(t_state - t_data), the same scalar multiply the
    # single-device update performs) and adds the fresh rows at weight 1 —
    # decay commutes with the psum because both are linear.
    delta = StreamState(key=None, A_acc=dA, B_acc=dB, na2=dna2, nb2=dnb2,
                        rows_seen=jnp.asarray(slab_d, jnp.int32),
                        row_high=jnp.asarray(row_offset + slab_d, jnp.int32),
                        d_total=state.d_total, signs=signs, srows=srows,
                        omega=omega, probe_acc=dprobe,
                        decay_rate=state.decay_rate,
                        t_state=state.t_state, t_data=state.t_state,
                        cosketch_omega=c_omega, cosketch_psi=c_psi,
                        cosketch_Y=dY, cosketch_W=dW)
    return merge_states(state, delta)


def distributed_streaming_summary(mesh: Mesh, axis: str, key: jax.Array,
                                  A: jax.Array, B: jax.Array, k: int,
                                  method: str = "gaussian",
                                  precision: str | None = None,
                                  slab: int | None = None,
                                  probes: int = 0, cosketch: int = 0):
    """Full streaming pass over row-sharded (A, B): slab-chunked ingestion +
    per-slab tree-merge. With ``slab=None`` the whole pair is one slab —
    semantically ``distributed_sketch_summary`` re-expressed through the
    streaming monoid (parity-tested in tests/core/test_streaming.py).
    ``probes`` retains the held-out probe block, ``cosketch`` the refinement
    co-sketch pair (their per-shard contributions merge through the same
    psum as the sketches)."""
    from repro.core.streaming import StreamingSummarizer
    d = A.shape[0]
    n_shards = mesh.shape[axis]
    if d % n_shards != 0:
        raise ValueError(f"row dim ({d}) must be a multiple of the mesh "
                         f"axis size ({n_shards})")
    summ = StreamingSummarizer(k, method=method, precision=precision,
                               probes=probes, cosketch=cosketch)
    state = summ.init(key, (d, A.shape[1], B.shape[1]))
    slab = d if slab is None else slab
    # round the slab to a shard multiple so every slab — including the
    # trailing partial one — splits evenly over the mesh axis
    slab = max(n_shards, slab - slab % n_shards)
    for off in range(0, d, slab):
        state = distributed_streaming_update(
            mesh, axis, summ, state, A[off:off + slab], B[off:off + slab],
            row_offset=off)
    return summ.finalize(state)


def distributed_smppca(mesh: Mesh, axis: str, key: jax.Array, A: jax.Array,
                       B: jax.Array, *, r: int, k: int, m: int, T: int = 10,
                       method: str = "gaussian") -> LowRankFactors:
    """Full distributed pipeline. Steps 2-3 run replicated (they are o(n k + m
    r^2 T) — negligible next to the pass) after the single all-reduced pass;
    every device computes identical factors (same seed), mirroring the
    every-worker-completes design of the gradient compressor."""
    from repro.core.summary_engine import build_summary
    k1, k2 = jax.random.split(key)
    summary = build_summary(k1, A, B, k, method=method, backend="distributed",
                            mesh=mesh, axis=axis)
    from repro.core.smppca import smppca_from_summary
    return smppca_from_summary(k2, summary, r=r, m=m, T=T).factors
