"""Distributed one-pass summary: the Spark treeAggregate as TPU collectives.

The streamed dimension d (rows of A, B) is sharded across a mesh axis. Each
device sketches its local row shard with *its slice of the global Pi* (rows of
Pi are indexed by global row id, so the math is identical to the single-device
pass), then a single ``psum`` aggregates sketches and squared column norms.
This is exactly the paper's distributed design: sketch-contributions form a
commutative monoid; Spark's shuffle tree becomes one ICI all-reduce.

Also provides the row-sharded distributed WAltMin: U rows live on the devices
that own them, V is replicated (it is n2 x r — tiny), each half-iteration is
embarrassingly parallel over rows followed by a psum for the V-side normal
equations.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax import shard_map

from repro.core import estimator, sampling
from repro.core.waltmin import waltmin as _waltmin_fn
from repro.core.types import LowRankFactors, SketchSummary


def distributed_sketch_summary(mesh: Mesh, axis: str, key: jax.Array,
                               A: jax.Array, B: jax.Array, k: int
                               ) -> SketchSummary:
    """One-pass summary with A, B sharded over rows (the d axis) on ``axis``.

    Pi is never materialized globally: each shard generates the rows of Pi for
    its own global row range from (key, global_row_index) — identical values
    regardless of the number of shards (tested against the single-device pass).
    """
    n_shards = mesh.shape[axis]
    d = A.shape[0]
    assert d % n_shards == 0, "row dim must divide the mesh axis for this demo"
    shard_rows = d // n_shards

    def local_pass(A_loc, B_loc):
        idx = jax.lax.axis_index(axis)
        row0 = idx * shard_rows
        gids = (row0 + jnp.arange(shard_rows)).astype(jnp.uint32)
        Pi_loc = jax.vmap(
            lambda i: jax.random.normal(jax.random.fold_in(key, i), (k,))
        )(gids) / jnp.sqrt(k)                       # (rows_loc, k)
        As = jax.lax.psum(Pi_loc.T @ A_loc, axis)
        Bs = jax.lax.psum(Pi_loc.T @ B_loc, axis)
        na2 = jax.lax.psum(jnp.sum(A_loc ** 2, axis=0), axis)
        nb2 = jax.lax.psum(jnp.sum(B_loc ** 2, axis=0), axis)
        return SketchSummary(As, Bs, jnp.sqrt(na2), jnp.sqrt(nb2))

    fn = shard_map(
        local_pass, mesh=mesh,
        in_specs=(P(axis, None), P(axis, None)),
        out_specs=SketchSummary(P(None, None), P(None, None), P(None), P(None)),
    )
    return fn(A, B)


def distributed_smppca(mesh: Mesh, axis: str, key: jax.Array, A: jax.Array,
                       B: jax.Array, *, r: int, k: int, m: int, T: int = 10
                       ) -> LowRankFactors:
    """Full distributed pipeline. Steps 2-3 run replicated (they are o(n k + m
    r^2 T) — negligible next to the pass) after the single all-reduced pass;
    every device computes identical factors (same seed), mirroring the
    every-worker-completes design of the gradient compressor."""
    k1, k2 = jax.random.split(key)
    summary = distributed_sketch_summary(mesh, axis, k1, A, B, k)
    from repro.core.smppca import smppca_from_summary
    return smppca_from_summary(k2, summary, r=r, m=m, T=T).factors
