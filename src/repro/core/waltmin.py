"""Step 3 of SMP-PCA: WAltMin — weighted alternating minimization (Alg 2).

Solves  min_{U,V} sum_{(i,j) in Omega} w_ij (e_i^T U V^T e_j - M~(i,j))^2,
w_ij = 1/q_hat_ij, on a static-shape COO sample. Spark's hash-partitioned ALS
becomes: per-row r x r normal equations built with ``segment_sum`` and solved
with a batched Cholesky-ish ``jnp.linalg.solve`` — the XLA-native equivalent.

Sample splitting (Alg 2 line 3): Omega is split into 2T+1 subsets; the t-th
half-iteration only *sees* subset 2t+1 / 2t+2 via masking (static shapes).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.types import LowRankFactors, SampleSet
from repro.core import sampling

_RIDGE = 1e-8


# ---------------------------------------------------------------------------
# COO helpers
# ---------------------------------------------------------------------------

def coo_matmat(rows: jax.Array, cols: jax.Array, vals: jax.Array,
               X: jax.Array, n_out: int) -> jax.Array:
    """(sparse (n_out, n_in)) @ X  where sparse[r, c] = vals, X: (n_in, p)."""
    contrib = vals[:, None] * X[cols]          # (nnz, p)
    return jax.ops.segment_sum(contrib, rows, num_segments=n_out)


def coo_rmatmat(rows: jax.Array, cols: jax.Array, vals: jax.Array,
                X: jax.Array, n_out: int) -> jax.Array:
    """(sparse)^T @ X."""
    contrib = vals[:, None] * X[rows]
    return jax.ops.segment_sum(contrib, cols, num_segments=n_out)


def coo_topr_svd(key: jax.Array, rows: jax.Array, cols: jax.Array,
                 vals: jax.Array, n1: int, n2: int, r: int,
                 n_iter: int = 8) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Randomized top-r SVD of a sparse (n1, n2) matrix via subspace iteration.

    Never materializes the dense matrix: only COO matvecs. Returns (U, s, V).
    """
    p = min(n2, r + 8)                         # oversampling
    G = jax.random.normal(key, (n2, p))
    Y = coo_matmat(rows, cols, vals, G, n1)    # (n1, p)

    def _body(_, Y):
        Q, _ = jnp.linalg.qr(Y)
        Z = coo_rmatmat(rows, cols, vals, Q, n2)   # (n2, p)
        Z, _ = jnp.linalg.qr(Z)
        return coo_matmat(rows, cols, vals, Z, n1)

    Y = jax.lax.fori_loop(0, n_iter, _body, Y)
    Q, _ = jnp.linalg.qr(Y)                    # (n1, p)
    Bt = coo_rmatmat(rows, cols, vals, Q, n2)  # (n2, p) = (Q^T S)^T
    Ub, s, Vt = jnp.linalg.svd(Bt.T, full_matrices=False)
    U = Q @ Ub[:, :r]
    return U, s[:r], Vt[:r].T


# ---------------------------------------------------------------------------
# WAltMin
# ---------------------------------------------------------------------------

def _trim_rows(U: jax.Array, norm_col: jax.Array, r: int) -> jax.Array:
    """Alg 2 step 6: zero rows whose norm exceeds 8 sqrt(r) ||A_i||/||A||_F,
    then re-orthonormalize. Guards the incoherence needed by Lemma C.2."""
    frob = jnp.sqrt(jnp.sum(norm_col ** 2))
    thresh = 8.0 * jnp.sqrt(r) * norm_col / jnp.maximum(frob, 1e-12)
    row_norm = jnp.linalg.norm(U, axis=1)
    keep = (row_norm <= jnp.maximum(thresh, 1e-12))[:, None]
    Ut = jnp.where(keep, U, 0.0)
    Q, _ = jnp.linalg.qr(Ut)
    return Q


def _ls_step(rows_from: jax.Array, cols_to: jax.Array, vals: jax.Array,
             w: jax.Array, F: jax.Array, n_to: int) -> jax.Array:
    """One half-iteration: solve for the ``cols_to`` side factor given F.

    For each target index t: G_t = sum w * F_i F_i^T ; b_t = sum w * val * F_i,
    over entries whose source index is i=rows_from and target t=cols_to.
    """
    r = F.shape[1]
    Fi = F[rows_from]                                   # (m, r)
    wv = (w * vals)[:, None] * Fi                       # (m, r)
    outer = (w[:, None, None] * Fi[:, :, None] * Fi[:, None, :])  # (m, r, r)
    G = jax.ops.segment_sum(outer, cols_to, num_segments=n_to)
    b = jax.ops.segment_sum(wv, cols_to, num_segments=n_to)
    # Two-scale Tikhonov: a 1e-6-relative per-row term for conditioning plus a
    # 1e-4-relative *global* floor. Rows that draw fewer than r samples under
    # Alg-2 splitting are underdetermined; the global floor damps their
    # null-space energy to O(1) instead of 1/eps, while biasing well-sampled
    # rows (whose Gram trace ~ the global mean) by only ~0.01%.
    tr = jnp.trace(G, axis1=1, axis2=2)[:, None, None]
    lam = 1e-6 * tr / r + 1e-4 * jnp.mean(tr) / r + _RIDGE
    G = G + lam * jnp.eye(r)
    return jnp.linalg.solve(G, b[..., None])[..., 0]    # (n_to, r)


def _waltmin_impl(key: jax.Array, samples: SampleSet, values: jax.Array,
                  n1: int, n2: int, r: int, T: int,
                  norm_A: jax.Array | None, use_splits: bool,
                  scan: bool) -> LowRankFactors:
    """One body for both execution modes: ``scan=True`` runs the T iteration
    pairs as one ``lax.scan`` (the jitted path), ``scan=False`` as a Python
    loop of eager dispatches (the EstimationEngine's reference oracle). The
    iteration driver is the ONLY thing that differs — weights, masks, keys,
    init, and the final solve are shared, which is what keeps the
    cross-backend parity contract a property of the code rather than of
    hand-synchronized copies."""
    w_all = jnp.where(samples.mask, 1.0 / jnp.maximum(samples.q_hat, 1e-12), 0.0)
    vals = jnp.where(samples.mask, values, 0.0)
    if norm_A is None:
        norm_A = jnp.ones((n1,))

    k_split, k_svd = jax.random.split(key)
    if use_splits:
        subset = sampling.split_omega(k_split, samples, 2 * T + 1)
    else:
        subset = jnp.zeros((samples.m,), jnp.int32)

    def _wmask(s):
        if not use_splits:
            return w_all
        # splits partition Omega; rescale q_hat by subset fraction
        return jnp.where(subset == s, w_all * (2 * T + 1), 0.0)

    # --- init: SVD of R_Omega0(M~), trim, orthonormalize -------------------
    w0 = _wmask(0)
    U0, _, _ = coo_topr_svd(k_svd, samples.rows, samples.cols, w0 * vals,
                            n1, n2, r)
    U = _trim_rows(U0, norm_A, r)

    # --- alternating half-iterations ---------------------------------------
    # Each half-step solves the weighted LS for one side given the *column
    # space* of the other; orthonormalizing the carried factor between steps
    # removes the scale drift that makes raw ALS diverge in f32 (only the
    # span matters — the final V solve restores a consistent scaled pair).
    def _half_pair(U, t):
        V = _ls_step(samples.rows, samples.cols, vals, _wmask(2 * t + 1), U, n2)
        Vq, _ = jnp.linalg.qr(V)
        Unew = _ls_step(samples.cols, samples.rows, vals, _wmask(2 * t + 2),
                        Vq, n1)
        Uq, _ = jnp.linalg.qr(Unew)
        return Uq

    if scan:
        U_final, _ = jax.lax.scan(lambda U, t: (_half_pair(U, t), None),
                                  U, jnp.arange(T))
    else:
        U_final = U
        for t in range(T):
            U_final = _half_pair(U_final, t)
    # final V solve against the last (orthonormal) U: consistent scaled pair
    V_final = _ls_step(samples.rows, samples.cols, vals, _wmask(2 * T - 1),
                       U_final, n2)
    return LowRankFactors(U_final, V_final)


@functools.partial(
    jax.jit, static_argnames=("n1", "n2", "r", "T", "use_splits"))
def waltmin(key: jax.Array, samples: SampleSet, values: jax.Array,
            n1: int, n2: int, r: int, T: int,
            norm_A: jax.Array | None = None,
            use_splits: bool = True) -> LowRankFactors:
    """Algorithm 2. ``values`` are M~ on Omega (or exact entries for LELA).

    norm_A: column norms used by the trim step (falls back to uniform).
    use_splits=False reuses all samples every iteration (practical mode, what
    the paper's Spark code does; splits are for the analysis).
    """
    return _waltmin_impl(key, samples, values, n1, n2, r, T, norm_A,
                         use_splits, scan=True)


def waltmin_reference(key: jax.Array, samples: SampleSet, values: jax.Array,
                      n1: int, n2: int, r: int, T: int,
                      norm_A: jax.Array | None = None,
                      use_splits: bool = True) -> LowRankFactors:
    """Algorithm 2 as written on the page: T Python-level iteration pairs,
    every half-step dispatched eagerly (no jit, no scan) — the
    EstimationEngine's ``backend='reference'`` oracle, and the baseline the
    jitted scan loop's speedup is measured against (benchmarks/run.py
    ``--suite estimation``)."""
    return _waltmin_impl(key, samples, values, n1, n2, r, T, norm_A,
                         use_splits, scan=False)
