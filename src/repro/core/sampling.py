"""Step 2a of SMP-PCA: biased entrywise sampling of the product matrix.

Eq. (1):  q_ij = m * ( ||A_i||^2/(2 n2 ||A||_F^2) + ||B_j||^2/(2 n1 ||B||_F^2) )

Two implementations:

* ``sample_entries`` — the production path. Exploits the *mixture* structure of
  Eq. (1): with prob 1/2 draw (i ~ ||A_i||^2, j ~ uniform) else
  (i ~ uniform, j ~ ||B_j||^2). Vectorized inverse-CDF (searchsorted over the
  two factor cumsums) replaces the paper's per-row binary search (App C.5) —
  O((n + m) log n), fully data-parallel, exactly the same multinomial model
  whose error the paper bounds within 2x of the binomial model [7][21].
* ``sample_entries_binomial`` — the paper's analyzed Bernoulli-per-entry model;
  O(n1*n2), used for small-scale tests and the phase-transition benchmark.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.types import SampleSet


def q_probabilities(norm_A: jax.Array, norm_B: jax.Array, m: int) -> jax.Array:
    """Dense (n1, n2) matrix of q_hat = min(1, q_ij). Test/benchmark helper."""
    n1, n2 = norm_A.shape[0], norm_B.shape[0]
    fa2 = jnp.sum(norm_A ** 2)
    fb2 = jnp.sum(norm_B ** 2)
    q = m * (norm_A[:, None] ** 2 / (2 * n2 * fa2)
             + norm_B[None, :] ** 2 / (2 * n1 * fb2))
    return jnp.minimum(q, 1.0)


def q_at(norm_A: jax.Array, norm_B: jax.Array, m: int,
         rows: jax.Array, cols: jax.Array) -> jax.Array:
    """q_hat evaluated at given (i, j) pairs without materializing (n1, n2)."""
    n1, n2 = norm_A.shape[0], norm_B.shape[0]
    fa2 = jnp.sum(norm_A ** 2)
    fb2 = jnp.sum(norm_B ** 2)
    q = m * (norm_A[rows] ** 2 / (2 * n2 * fa2)
             + norm_B[cols] ** 2 / (2 * n1 * fb2))
    return jnp.minimum(q, 1.0)


def _categorical_from_weights(key: jax.Array, w: jax.Array, shape) -> jax.Array:
    """Inverse-CDF categorical sampling: O(n) setup + O(m log n) draws."""
    cdf = jnp.cumsum(w)
    total = cdf[-1]
    u = jax.random.uniform(key, shape) * total
    return jnp.clip(jnp.searchsorted(cdf, u, side="right"), 0, w.shape[0] - 1)


def require_nonzero_norms(norm_A: jax.Array, norm_B: jax.Array) -> None:
    """Reject an all-zero factor before sampling corrupts silently.

    A zero-norm *matrix* makes Eq. (1) divide by ``||A||_F^2 = 0`` (NaN
    ``q_hat`` propagating into the rescaled extraction) and degenerates the
    inverse-CDF to ``total = 0`` (every draw clips to index 0), so it is a
    caller error named here. Zero-norm *rows* are fine: the mixture's
    uniform branch still reaches them and their ``q_hat`` stays positive
    through the other factor's term. Host-side only — traced norms (inside
    a jitted estimator cell) are skipped; the eager entry points
    (``sample_entries`` / ``sample_entries_binomial`` /
    ``estimate_product``) fire the guard where concrete values exist.
    """
    if isinstance(norm_A, jax.core.Tracer) or \
            isinstance(norm_B, jax.core.Tracer):
        return
    # one fused device fetch for both totals (batched norms reduce too)
    fa2, fb2 = (float(v) for v in jax.device_get(
        jnp.stack([jnp.min(jnp.sum(jnp.asarray(norm_A, jnp.float32) ** 2,
                                   axis=-1)),
                   jnp.min(jnp.sum(jnp.asarray(norm_B, jnp.float32) ** 2,
                                   axis=-1))])))
    for name, f2 in (("A", fa2), ("B", fb2)):
        if not f2 > 0.0:
            raise ValueError(
                f"all columns of {name} have zero norm (||{name}||_F = 0, "
                f"or a NaN norm) — the Eq. (1) sampling distribution is "
                f"undefined for a zero factor; nothing to estimate")


@functools.partial(jax.jit, static_argnames=("m",))
def _sample_entries(key: jax.Array, norm_A: jax.Array, norm_B: jax.Array,
                    m: int) -> SampleSet:
    n1, n2 = norm_A.shape[0], norm_B.shape[0]
    k_branch, k_ra, k_ua, k_rb, k_ub = jax.random.split(key, 5)

    # branch 0: i ~ ||A_i||^2 / ||A||_F^2, j ~ U[n2]
    rows_a = _categorical_from_weights(k_ra, norm_A.astype(jnp.float32) ** 2, (m,))
    cols_a = jax.random.randint(k_ua, (m,), 0, n2)
    # branch 1: i ~ U[n1], j ~ ||B_j||^2 / ||B||_F^2
    rows_b = jax.random.randint(k_ub, (m,), 0, n1)
    cols_b = _categorical_from_weights(k_rb, norm_B.astype(jnp.float32) ** 2, (m,))

    pick_b = jax.random.bernoulli(k_branch, 0.5, (m,))
    rows = jnp.where(pick_b, rows_b, rows_a).astype(jnp.int32)
    cols = jnp.where(pick_b, cols_b, cols_a).astype(jnp.int32)
    q_hat = q_at(norm_A, norm_B, m, rows, cols)
    return SampleSet(rows, cols, q_hat, jnp.ones((m,), bool))


def sample_entries(key: jax.Array, norm_A: jax.Array, norm_B: jax.Array,
                   m: int) -> SampleSet:
    """Draw m entries from the Eq. (1) mixture (duplicates allowed, multinomial
    model). Returns a static-shape SampleSet with all entries valid.
    Raises ``ValueError`` naming the factor when called eagerly on an
    all-zero A or B (the distribution is undefined); zero-norm rows are fine
    (the uniform mixture branch covers them)."""
    require_nonzero_norms(norm_A, norm_B)
    return _sample_entries(key, norm_A, norm_B, m)


def sample_entries_binomial(key: jax.Array, norm_A: jax.Array,
                            norm_B: jax.Array, m: int,
                            max_samples: int | None = None) -> SampleSet:
    """Paper's Bernoulli-per-entry model (Alg 1 line 3). Dense O(n1*n2);
    returns a SampleSet padded to ``max_samples`` (default 2m). Raises
    ``ValueError`` naming the factor on an all-zero A or B."""
    require_nonzero_norms(norm_A, norm_B)
    n1, n2 = norm_A.shape[0], norm_B.shape[0]
    cap = int(max_samples or 2 * m)
    q = q_probabilities(norm_A, norm_B, m)
    hit = jax.random.bernoulli(key, q)
    flat = hit.reshape(-1)
    # stable selection of up to cap sampled positions
    order = jnp.argsort(~flat)          # sampled first
    sel = order[:cap]
    mask = flat[sel]
    rows = (sel // n2).astype(jnp.int32)
    cols = (sel % n2).astype(jnp.int32)
    q_hat = q.reshape(-1)[sel]
    return SampleSet(rows, cols, q_hat, mask)


def split_omega(key: jax.Array, samples: SampleSet, n_splits: int) -> jax.Array:
    """Assign each sampled entry to one of ``n_splits`` subsets (Alg 2 line 3).

    Returns (m,) int32 subset ids; WAltMin masks by id per half-iteration.
    """
    return jax.random.randint(key, (samples.m,), 0, n_splits).astype(jnp.int32)
