"""repro.core — Single-Pass PCA of Matrix Products (SMP-PCA, NIPS 2016).

Public API:
    build_summary / rows_summary                          (step 1: the engine)
    estimate_product                                      (steps 2-3: the engine)
    estimate_error / adaptive_rank / probe_omega          (quality: ErrorEngine)
    PipelinePlan / PipelineEngine / get_engine            (compile-once plans)
    sketch_summary / sketch_pass / streamed_rows_summary  (step 1, legacy wrappers)
    sample_entries / q_probabilities                      (step 2a, Eq 1)
    rescaled_entries / rescaled_matrix                    (step 2b, Eq 2)
    waltmin / waltmin_reference                           (step 3, Alg 2)
    smppca / smppca_from_summary                          (Alg 1)
    lela / sketch_svd / optimal_rank_r / product_of_pcas  (baselines)
    distributed_sketch_summary / distributed_smppca       (multi-device pass)
    StreamingSummarizer / merge_states / finalize_state   (chunked ingestion)
    decay_state / WindowedSummarizer / window_bucket_key  (drifting streams)
    WireSpec / compress_state / choose_wire_spec          (state on the wire)
    RefineSpec / refine_factors / refined_svd             (sketch-power refinement)
    cosketch_omega / cosketch_psi / attach_cosketch       (Tropp co-sketch block)
"""
from repro.core.types import (
    ErrorEstimate, EstimateResult, LowRankFactors, SampleSet, SketchSummary,
    SMPPCAResult)
from repro.core.error_engine import (
    AdaptiveRankResult, adaptive_rank, estimate_error, merge_probes,
    probe_contribution, probe_omega, probe_pass, rank_curve)
from repro.core.sketch import (
    column_norms, fwht, gaussian_pi, merge_summaries, pi_rows, sketch_pass,
    sketch_summary, srht_sketch, streamed_rows_summary)
from repro.core.summary_engine import (
    backends, build_summary, identity_product_summary, norms_only_summary,
    projection_rows, register_backend, rows_summary, srht_plan,
    summary_stage, tap_pair_summary)
from repro.core.sampling import (
    q_at, q_probabilities, sample_entries, sample_entries_binomial, split_omega)
from repro.core.estimator import (
    plain_jl_entries, rescaled_entries, rescaled_matrix)
from repro.core.waltmin import (
    coo_matmat, coo_rmatmat, coo_topr_svd, waltmin, waltmin_reference)
from repro.core.estimation_engine import (
    default_m, estimate_product, estimation_stage, estimators, exact_entries,
    implicit_topr, register_estimator)
from repro.core.pipeline import (
    EstimationSpec, PipelineEngine, PipelinePlan, PipelineResult, RankPolicy,
    SketchSpec, get_engine, lela_plan, sketch_svd_plan, smppca_plan)
from repro.core.smppca import (
    smppca, smppca_from_summary, spectral_error, spectral_error_vs_optimal)
from repro.core.lela import lela
from repro.core.baselines import optimal_rank_r, product_of_pcas, sketch_svd
from repro.core.distributed import (
    distributed_sketch_summary, distributed_smppca,
    distributed_streaming_summary, distributed_streaming_update)
from repro.core.streaming import (
    CompressedState, StreamingSummarizer, StreamState, WindowedSummarizer,
    WindowState, WireSpec, choose_wire_spec, compress_state, decay_state,
    decompress_state, finalize_state, merge_states, tree_merge,
    window_bucket_key, wire_bytes, wire_error, wire_pack, wire_unpack)
from repro.core.refinement import (
    RefineSpec, attach_cosketch, cosketch_contribution, cosketch_key,
    cosketch_omega, cosketch_pass, cosketch_psi, cosketch_width,
    merge_cosketch, refine_factors, refined_svd, validate_refine)
