"""Step 2b of SMP-PCA: the rescaled JL estimator (Eq. 2).

    M~(i,j) = ||A_i|| * ||B_j|| * <A~_i, B~_j> / (||A~_i|| * ||B~_j||)

i.e. keep the *sketched angle* but substitute the *exact* column norms carried
as side information from the single pass. Compact form: D_A (A~^T B~) D_B with
D_A = diag(||A_i||/||A~_i||), D_B = diag(||B_j||/||B~_j||) (Appendix B).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import SketchSummary

_EPS = 1e-12


def rescaled_entries(summary: SketchSummary, rows: jax.Array,
                     cols: jax.Array) -> jax.Array:
    """M~ evaluated at (rows, cols) — O(m k), never materializes (n1, n2).

    This is the pure-XLA path; repro.kernels.sampled_dot is the TPU kernel.
    """
    Ai = summary.A_sketch[:, rows]              # (k, m)
    Bj = summary.B_sketch[:, cols]              # (k, m)
    dots = jnp.sum(Ai * Bj, axis=0)             # (m,)
    sa = jnp.sqrt(jnp.sum(Ai ** 2, axis=0))
    sb = jnp.sqrt(jnp.sum(Bj ** 2, axis=0))
    scale = (summary.norm_A[rows] * summary.norm_B[cols]) / \
        jnp.maximum(sa * sb, _EPS)
    return dots * scale


def plain_jl_entries(summary: SketchSummary, rows: jax.Array,
                     cols: jax.Array) -> jax.Array:
    """The naive estimator <A~_i, B~_j> the paper improves upon (Fig 2a)."""
    Ai = summary.A_sketch[:, rows]
    Bj = summary.B_sketch[:, cols]
    return jnp.sum(Ai * Bj, axis=0)


def rescaled_matrix(summary: SketchSummary) -> jax.Array:
    """Dense M~ = D_A (A~^T B~) D_B. Small-n tests/benchmarks only."""
    sa = jnp.sqrt(jnp.sum(summary.A_sketch ** 2, axis=0))
    sb = jnp.sqrt(jnp.sum(summary.B_sketch ** 2, axis=0))
    da = summary.norm_A / jnp.maximum(sa, _EPS)
    db = summary.norm_B / jnp.maximum(sb, _EPS)
    return (summary.A_sketch.T @ summary.B_sketch) * da[:, None] * db[None, :]
