"""Algorithm 1: SMP-PCA — Streaming Matrix Product PCA, end to end.

A thin preset over the PipelineEngine: ``smppca`` builds the declarative
``pipeline.smppca_plan`` (step-1 sketch spec + step-2/3 estimation spec under
the historical ``split(key, 3)`` layout) and executes it through the shared
compile-once engine — the whole sketch -> estimate pipeline is ONE fused
jitted dispatch, cached per (plan, shape signature). Key derivations and
results are bit-for-bit the historical stage-by-stage composition

    summary = summary_engine.build_summary(...)      (step 1: one pass)
    result  = estimation_engine.estimate_product(    (steps 2-3)
                  ..., method='rescaled_jl', ...)

(golden-tested in tests/core/test_key_contract.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import estimation_engine, pipeline
from repro.core.types import LowRankFactors, SketchSummary, SMPPCAResult


def smppca(key: jax.Array, A: jax.Array, B: jax.Array, *, r: int, k: int,
           m: int, T: int = 10, method: str = "gaussian",
           backend: str = "reference", block: int = 1024,
           precision: str | None = None, est_backend: str = "jit",
           use_splits: bool = False) -> SMPPCAResult:
    """Single-pass rank-r PCA of A^T B. A: (d, n1), B: (d, n2).

    The step-1 pass goes through the SummaryEngine (``method``/``backend``/
    ``block``/``precision`` select the sketch and its execution strategy);
    steps 2-3 go through the EstimationEngine (``est_backend`` selects the
    completion execution strategy; the method is the paper's rescaled_jl).
    Both stages run as one plan-compiled fused dispatch (PipelineEngine)."""
    plan = pipeline.smppca_plan(
        r=r, k=k, m=m, T=T, method=method, backend=backend, block=block,
        precision=precision, est_backend=est_backend, use_splits=use_splits)
    res = pipeline.get_engine().run(plan, key, A, B)
    return SMPPCAResult(res.estimate.factors, res.summary,
                        res.estimate.samples, res.estimate.values)


@functools.partial(jax.jit, static_argnames=("r", "m", "T", "est_backend",
                                             "use_splits"))
def smppca_from_summary(key: jax.Array, summary: SketchSummary, *, r: int,
                        m: int, T: int = 10, est_backend: str = "jit",
                        use_splits: bool = False) -> SMPPCAResult:
    """Steps 2-3 given a one-pass summary (entry point for streaming and for
    the distributed pass, whose psum produces exactly this summary)."""
    est = estimation_engine.estimate_product(
        key, summary, r, method="rescaled_jl", backend=est_backend, m=m, T=T,
        use_splits=use_splits)
    return SMPPCAResult(est.factors, summary, est.samples, est.values)


# ---------------------------------------------------------------------------
# Evaluation helpers (small-n; used by tests and benchmarks)
# ---------------------------------------------------------------------------

def spectral_error(A: jax.Array, B: jax.Array,
                   factors: LowRankFactors) -> jax.Array:
    """|| A^T B - U V^T ||_2 / || A^T B ||_2 (dense; evaluation only)."""
    M = A.T @ B
    err = jnp.linalg.norm(M - factors.U @ factors.V.T, ord=2)
    return err / jnp.linalg.norm(M, ord=2)


def spectral_error_vs_optimal(A: jax.Array, B: jax.Array, r: int,
                              factors: LowRankFactors) -> tuple[jax.Array, jax.Array]:
    """(algorithm error, optimal rank-r error), both relative spectral norm."""
    M = A.T @ B
    nM = jnp.linalg.norm(M, ord=2)
    U, s, Vt = jnp.linalg.svd(M, full_matrices=False)
    Mr = (U[:, :r] * s[:r]) @ Vt[:r]
    return (jnp.linalg.norm(M - factors.U @ factors.V.T, ord=2) / nM,
            jnp.linalg.norm(M - Mr, ord=2) / nM)
