"""Algorithm 1: SMP-PCA — Streaming Matrix Product PCA, end to end.

    summary  = one pass over (A, B)            -> sketches + column norms
    Omega    = biased sample (Eq 1)            -> m entries
    values   = rescaled-JL estimates (Eq 2) on Omega
    factors  = WAltMin completion (Alg 2)      -> U (n1, r), V (n2, r)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import estimator, sampling, summary_engine
from repro.core.waltmin import waltmin as _waltmin_fn
from repro.core.types import LowRankFactors, SampleSet, SketchSummary, SMPPCAResult


@functools.partial(jax.jit, static_argnames=("r", "k", "m", "T", "method",
                                              "backend", "block", "precision",
                                              "use_splits"))
def smppca(key: jax.Array, A: jax.Array, B: jax.Array, *, r: int, k: int,
           m: int, T: int = 10, method: str = "gaussian",
           backend: str = "reference", block: int = 1024,
           precision: str | None = None,
           use_splits: bool = False) -> SMPPCAResult:
    """Single-pass rank-r PCA of A^T B. A: (d, n1), B: (d, n2).

    The step-1 pass goes through the SummaryEngine: ``method``/``backend``/
    ``block``/``precision`` select the sketch and its execution strategy
    (see ``core.summary_engine.build_summary``)."""
    k_sketch, k_sample, k_als = jax.random.split(key, 3)
    summary = summary_engine.build_summary(
        k_sketch, A, B, k, method=method, backend=backend, block=block,
        precision=precision)
    return smppca_from_summary(
        jax.random.fold_in(k_sample, 0), summary, r=r, m=m, T=T,
        use_splits=use_splits)


@functools.partial(jax.jit, static_argnames=("r", "m", "T", "use_splits"))
def smppca_from_summary(key: jax.Array, summary: SketchSummary, *, r: int,
                        m: int, T: int = 10,
                        use_splits: bool = False) -> SMPPCAResult:
    """Steps 2-3 given a one-pass summary (entry point for streaming and for
    the distributed pass, whose psum produces exactly this summary)."""
    k_sample, k_als = jax.random.split(key)
    samples = sampling.sample_entries(k_sample, summary.norm_A, summary.norm_B, m)
    values = estimator.rescaled_entries(summary, samples.rows, samples.cols)
    factors = _waltmin_fn(k_als, samples, values,
                              summary.n1, summary.n2, r, T,
                              norm_A=summary.norm_A, use_splits=use_splits)
    return SMPPCAResult(factors, summary, samples, values)


# ---------------------------------------------------------------------------
# Evaluation helpers (small-n; used by tests and benchmarks)
# ---------------------------------------------------------------------------

def spectral_error(A: jax.Array, B: jax.Array,
                   factors: LowRankFactors) -> jax.Array:
    """|| A^T B - U V^T ||_2 / || A^T B ||_2 (dense; evaluation only)."""
    M = A.T @ B
    err = jnp.linalg.norm(M - factors.U @ factors.V.T, ord=2)
    return err / jnp.linalg.norm(M, ord=2)


def spectral_error_vs_optimal(A: jax.Array, B: jax.Array, r: int,
                              factors: LowRankFactors) -> tuple[jax.Array, jax.Array]:
    """(algorithm error, optimal rank-r error), both relative spectral norm."""
    M = A.T @ B
    nM = jnp.linalg.norm(M, ord=2)
    U, s, Vt = jnp.linalg.svd(M, full_matrices=False)
    Mr = (U[:, :r] * s[:r]) @ Vt[:r]
    return (jnp.linalg.norm(M - factors.U @ factors.V.T, ord=2) / nM,
            jnp.linalg.norm(M - Mr, ord=2) / nM)
