"""Core pytree types for SMP-PCA.

Everything is a NamedTuple so it is a natural JAX pytree, jit/pjit friendly,
and serializable by the checkpoint layer.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class SketchSummary(NamedTuple):
    """One-pass summary of (A, B) per Algorithm 1 step 1.

    A: (d, n1), B: (d, n2); sketches are (k, n1)/(k, n2). Column norms are the
    paper's *side information* that powers the rescaled JL estimator.
    """

    A_sketch: jax.Array        # (k, n1) = Pi @ A
    B_sketch: jax.Array        # (k, n2) = Pi @ B
    norm_A: jax.Array          # (n1,)  exact column L2 norms of A
    norm_B: jax.Array          # (n2,)  exact column L2 norms of B

    @property
    def k(self) -> int:
        """Sketch size (rows of the sketches)."""
        return self.A_sketch.shape[0]

    @property
    def n1(self) -> int:
        """Columns of A."""
        return self.A_sketch.shape[1]

    @property
    def n2(self) -> int:
        """Columns of B."""
        return self.B_sketch.shape[1]

    @property
    def frob_A(self) -> jax.Array:
        """Frobenius norm of A (from the retained column norms)."""
        return jnp.sqrt(jnp.sum(self.norm_A ** 2))

    @property
    def frob_B(self) -> jax.Array:
        """Frobenius norm of B (from the retained column norms)."""
        return jnp.sqrt(jnp.sum(self.norm_B ** 2))


class SampleSet(NamedTuple):
    """A static-shape COO sample of entries of the (n1 x n2) product matrix.

    ``rows/cols`` index into A's / B's columns. ``q_hat`` is min(1, q_ij) used
    for the 1/q_hat completion weights. ``mask`` marks valid entries (padding
    allows static shapes under jit).
    """

    rows: jax.Array            # (m,) int32
    cols: jax.Array            # (m,) int32
    q_hat: jax.Array           # (m,) float32
    mask: jax.Array            # (m,) bool

    @property
    def m(self) -> int:
        """Static sample budget (padded length)."""
        return self.rows.shape[0]


class LowRankFactors(NamedTuple):
    """Rank-r approximation in factored form: M_hat = U @ V^T."""

    U: jax.Array               # (n1, r)
    V: jax.Array               # (n2, r)

    @property
    def r(self) -> int:
        """Factor rank."""
        return self.U.shape[1]

    def dense(self) -> jax.Array:
        """Materialize the (n1, n2) approximation U @ V^T."""
        return self.U @ self.V.T


class EstimateResult(NamedTuple):
    """Step-2/3 output of the EstimationEngine (``estimate_product``).

    ``samples``/``values`` carry the Omega sample and the estimated entries
    for the completion methods; both are None for ``method='direct_svd'``
    (which never samples). None fields are empty pytree nodes, so the result
    stays jit/vmap friendly across methods.
    """

    factors: LowRankFactors
    samples: Optional[SampleSet]
    values: Optional[jax.Array]   # (m,) estimated entries on Omega


class SMPPCAResult(NamedTuple):
    """Full Algorithm-1 output: factors plus the intermediates."""

    factors: LowRankFactors
    summary: SketchSummary
    samples: SampleSet
    sampled_values: jax.Array  # (m,) rescaled-JL estimates on Omega
