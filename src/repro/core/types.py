"""Core pytree types for SMP-PCA.

Everything is a NamedTuple so it is a natural JAX pytree, jit/pjit friendly,
and serializable by the checkpoint layer.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class SketchSummary(NamedTuple):
    """One-pass summary of (A, B) per Algorithm 1 step 1.

    A: (d, n1), B: (d, n2); sketches are (k, n1)/(k, n2). Column norms are the
    paper's *side information* that powers the rescaled JL estimator.

    ``probes``/``probe_omega`` are the optional held-out probe block (the
    ErrorEngine's a-posteriori quality side information, Tropp et al.
    1609.00048): ``probes = (A^T B) @ probe_omega`` accumulated in the same
    single pass, ``probe_omega`` the (n2, p) Gaussian test matrix derived
    from the sketch key. Both are None when the summary was built without
    probes (``build_summary(..., probes=0)``, the default).

    ``cosketch_*`` is the optional Tropp range/co-range pair retained for
    sketch-power/Tropp refinement (RefinementEngine): ``cosketch_Y =
    (A^T B) @ cosketch_omega`` (n1, s) and ``cosketch_W = cosketch_psi @
    (A^T B)`` (l, n2) with ``l = 2s + 1`` (Tropp's co-range oversampling),
    accumulated in the same single pass, with the
    (n2, s)/(l, n1) Gaussian test matrices derived from the sketch key
    under the reserved "csk!" fold. All four stay None by default
    (``build_summary(..., cosketch=0)``) so legacy treedefs, checkpoints,
    and the streaming monoid are unchanged when refinement is off.
    """

    A_sketch: jax.Array        # (k, n1) = Pi @ A
    B_sketch: jax.Array        # (k, n2) = Pi @ B
    norm_A: jax.Array          # (n1,)  exact column L2 norms of A
    norm_B: jax.Array          # (n2,)  exact column L2 norms of B
    probes: Optional[jax.Array] = None       # (n1, p) = A^T (B @ probe_omega)
    probe_omega: Optional[jax.Array] = None  # (n2, p) held-out Gaussian probes
    cosketch_Y: Optional[jax.Array] = None      # (n1, s) range co-sketch
    cosketch_W: Optional[jax.Array] = None      # (l, n2) co-range co-sketch
    cosketch_omega: Optional[jax.Array] = None  # (n2, s) range test matrix
    cosketch_psi: Optional[jax.Array] = None    # (l, n1) co-range test matrix

    @property
    def k(self) -> int:
        """Sketch size (rows of the sketches)."""
        return self.A_sketch.shape[0]

    @property
    def n1(self) -> int:
        """Columns of A."""
        return self.A_sketch.shape[1]

    @property
    def n2(self) -> int:
        """Columns of B."""
        return self.B_sketch.shape[1]

    @property
    def frob_A(self) -> jax.Array:
        """Frobenius norm of A (from the retained column norms)."""
        return jnp.sqrt(jnp.sum(self.norm_A ** 2))

    @property
    def frob_B(self) -> jax.Array:
        """Frobenius norm of B (from the retained column norms)."""
        return jnp.sqrt(jnp.sum(self.norm_B ** 2))

    @property
    def n_probes(self) -> int:
        """Held-out probe count p (0 when no probe block was retained)."""
        return 0 if self.probes is None else self.probes.shape[-1]

    @property
    def n_cosketch(self) -> int:
        """Co-sketch width s (0 when no refinement block was retained)."""
        return 0 if self.cosketch_Y is None else self.cosketch_Y.shape[-1]


class SampleSet(NamedTuple):
    """A static-shape COO sample of entries of the (n1 x n2) product matrix.

    ``rows/cols`` index into A's / B's columns. ``q_hat`` is min(1, q_ij) used
    for the 1/q_hat completion weights. ``mask`` marks valid entries (padding
    allows static shapes under jit).
    """

    rows: jax.Array            # (m,) int32
    cols: jax.Array            # (m,) int32
    q_hat: jax.Array           # (m,) float32
    mask: jax.Array            # (m,) bool

    @property
    def m(self) -> int:
        """Static sample budget (padded length)."""
        return self.rows.shape[0]


class LowRankFactors(NamedTuple):
    """Rank-r approximation in factored form: M_hat = U @ V^T."""

    U: jax.Array               # (n1, r)
    V: jax.Array               # (n2, r)

    @property
    def r(self) -> int:
        """Factor rank."""
        return self.U.shape[1]

    def dense(self) -> jax.Array:
        """Materialize the (n1, n2) approximation U @ V^T."""
        return self.U @ self.V.T


class ErrorEstimate(NamedTuple):
    """A-posteriori quality estimate of rank-r factors (ErrorEngine output).

    All statistics come from the p held-out probe columns retained in the
    summary: each probe gives one unbiased sample of the squared Frobenius
    residual ``||A^T B - U V^T||_F^2``, and the fields below are the sample
    mean, a normal-approximation confidence interval over the p samples, a
    spectral-norm proxy, and the residual relative to the estimated
    ``||A^T B||_F``. Every field is a scalar array, so the estimate vmaps
    across batched (L, ...) results.
    """

    frob_est: jax.Array       # sqrt of the unbiased mean squared residual
    frob_sq_est: jax.Array    # unbiased estimate of ||A^T B - U V^T||_F^2
    frob_lo: jax.Array        # lower confidence bound on the Frobenius residual
    frob_hi: jax.Array        # upper confidence bound on the Frobenius residual
    spectral_est: jax.Array   # max_j ||R w_j|| / ||w_j|| — spectral-norm proxy
    rel_est: jax.Array        # frob_est / estimated ||A^T B||_F


class EstimateResult(NamedTuple):
    """Step-2/3 output of the EstimationEngine (``estimate_product``).

    ``samples``/``values`` carry the Omega sample and the estimated entries
    for the completion methods; both are None for ``method='direct_svd'``
    (which never samples). ``error`` is the ErrorEngine's a-posteriori
    quality estimate, filled only by ``estimate_product(..., with_error=
    True)`` on probe-carrying summaries. None fields are empty pytree nodes,
    so the result stays jit/vmap friendly across methods.
    """

    factors: LowRankFactors
    samples: Optional[SampleSet]
    values: Optional[jax.Array]   # (m,) estimated entries on Omega
    error: Optional[ErrorEstimate] = None


class SMPPCAResult(NamedTuple):
    """Full Algorithm-1 output: factors plus the intermediates."""

    factors: LowRankFactors
    summary: SketchSummary
    samples: SampleSet
    sampled_values: jax.Array  # (m,) rescaled-JL estimates on Omega
