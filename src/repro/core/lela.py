"""LELA [Bhojanapalli-Jain-Sanghavi, SODA'15] — the two-pass baseline.

Pass 1: column norms of A and B. Pass 2: *exact* entries A_i^T B_j on the
sampled Omega. Then the same WAltMin completion. SMP-PCA replaces pass 2 with
the rescaled-JL estimate; comparing the two isolates the cost of sketching
(the eta*sigma_r^* term in Thm 3.1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import sampling
from repro.core.waltmin import waltmin as _waltmin_fn
from repro.core.types import LowRankFactors, SampleSet


@functools.partial(jax.jit, static_argnames=("r", "m", "T", "use_splits"))
def lela(key: jax.Array, A: jax.Array, B: jax.Array, *, r: int, m: int,
         T: int = 10, use_splits: bool = False) -> LowRankFactors:
    k_sample, k_als = jax.random.split(key)
    # ---- pass 1: norms ------------------------------------------------------
    norm_A = jnp.sqrt(jnp.sum(A.astype(jnp.float32) ** 2, axis=0))
    norm_B = jnp.sqrt(jnp.sum(B.astype(jnp.float32) ** 2, axis=0))
    samples = sampling.sample_entries(k_sample, norm_A, norm_B, m)
    # ---- pass 2: exact sampled entries (the pass SMP-PCA eliminates) --------
    # chunked so the (d, chunk) gathers stay cache-resident (a fair baseline:
    # the Spark LELA streams these too)
    chunk = 2048
    pad = (-m) % chunk
    rows = jnp.pad(samples.rows, (0, pad))
    cols = jnp.pad(samples.cols, (0, pad))
    def body(_, rc):
        r_, c_ = rc
        return None, jnp.sum(A[:, r_] * B[:, c_], axis=0)
    _, vals = jax.lax.scan(
        body, None, (rows.reshape(-1, chunk), cols.reshape(-1, chunk)))
    values = vals.reshape(-1)[:m]
    return _waltmin_fn(k_als, samples, values, A.shape[1], B.shape[1],
                           r, T, norm_A=norm_A, use_splits=use_splits)
