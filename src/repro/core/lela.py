"""LELA [Bhojanapalli-Jain-Sanghavi, SODA'15] — the two-pass baseline.

Pass 1: column norms of A and B. Pass 2: *exact* entries A_i^T B_j on the
sampled Omega. Then the same WAltMin completion. SMP-PCA replaces pass 2 with
the rescaled-JL estimate; comparing the two isolates the cost of sketching
(the eta*sigma_r^* term in Thm 3.1).

A thin preset over the PipelineEngine: ``lela`` executes
``pipeline.lela_plan`` (a sketch-free ``norms_only`` first stage +
``method='lela_waltmin'`` estimation fed the original pair as its exact
second pass) as one plan-compiled fused dispatch. The caller key goes
straight to estimation (``key_layout='direct'``), bit-for-bit the historical
derivation.
"""
from __future__ import annotations

import jax

from repro.core import pipeline
from repro.core.summary_engine import norms_only_summary
from repro.core.types import LowRankFactors

__all__ = ["lela", "norms_only_summary"]


def lela(key: jax.Array, A: jax.Array, B: jax.Array, *, r: int, m: int,
         T: int = 10, use_splits: bool = False) -> LowRankFactors:
    """LELA two-pass baseline: biased sample + exact entries + WAltMin."""
    plan = pipeline.lela_plan(r=r, m=m, T=T, use_splits=use_splits)
    return pipeline.get_engine().run(plan, key, A, B).estimate.factors
