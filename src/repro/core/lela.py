"""LELA [Bhojanapalli-Jain-Sanghavi, SODA'15] — the two-pass baseline.

Pass 1: column norms of A and B. Pass 2: *exact* entries A_i^T B_j on the
sampled Omega. Then the same WAltMin completion. SMP-PCA replaces pass 2 with
the rescaled-JL estimate; comparing the two isolates the cost of sketching
(the eta*sigma_r^* term in Thm 3.1).

A thin composition over the EstimationEngine: pass 1 builds a sketch-free
summary (norms only), and ``estimate_product(method='lela_waltmin',
exact_pair=(A, B))`` runs the sampled second pass + completion.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import estimation_engine
from repro.core.types import LowRankFactors, SketchSummary


def norms_only_summary(A: jax.Array, B: jax.Array) -> SketchSummary:
    """Pass 1: a ``SketchSummary`` with exact column norms and empty (0, n)
    sketches — all a norm-driven estimator (lela_waltmin) consumes."""
    norm_A = jnp.sqrt(jnp.sum(A.astype(jnp.float32) ** 2, axis=0))
    norm_B = jnp.sqrt(jnp.sum(B.astype(jnp.float32) ** 2, axis=0))
    return SketchSummary(jnp.zeros((0, A.shape[1]), jnp.float32),
                         jnp.zeros((0, B.shape[1]), jnp.float32),
                         norm_A, norm_B)


@functools.partial(jax.jit, static_argnames=("r", "m", "T", "use_splits"))
def lela(key: jax.Array, A: jax.Array, B: jax.Array, *, r: int, m: int,
         T: int = 10, use_splits: bool = False) -> LowRankFactors:
    """LELA two-pass baseline: biased sample + exact entries + WAltMin."""
    summary = norms_only_summary(A, B)
    est = estimation_engine.estimate_product(
        key, summary, r, method="lela_waltmin", backend="jit", m=m, T=T,
        use_splits=use_splits, exact_pair=(A, B))
    return est.factors
