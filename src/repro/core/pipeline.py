"""PipelineEngine — compile-once execution of the paper's fixed pipeline.

The paper's method is a fixed recipe: one-pass summary of (A, B), then
completion of the top-r factors from the sketch plus side information,
then (optionally) an a-posteriori quality estimate. Tropp et al.'s
practical-sketching framework treats exactly this as a fixed-storage,
fixed-recipe pipeline that is *compiled once and fed data* — this module
makes that operational:

* ``PipelinePlan`` — a declarative, hashable description of the whole
  pipeline: the sketch stage (``SketchSpec``: method/backend/k/block/
  precision/probes), the estimation stage (``EstimationSpec``: method/
  backend/m/T/use_splits), the rank policy (``RankPolicy``: fixed ``r``,
  or auto with ``tol``/``r_max``), the key layout (how the caller's one
  base key fans out into the per-stage keys), and error attachment.
* ``PipelineEngine`` — compiles a plan into ONE jitted executable spanning
  all three engines (summary -> estimation -> error estimate fused in a
  single device dispatch; batched/vmapped mode included), behind an LRU
  executable cache keyed on ``(plan, shape/dtype signature)``. Repeat
  traffic on a warm plan never re-traces: it is one cache lookup and one
  fused dispatch.

``smppca`` / ``lela`` / ``sketch_svd`` are thin presets over this engine
(``smppca_plan`` / ``lela_plan`` / ``sketch_svd_plan``), and
``serve.SketchService`` runs every ``flush_factors`` / ``stream_factors``
bucket through the same cache. Key derivations are bit-for-bit the
historical ones (golden-tested in tests/core/test_key_contract.py), and the
fused executables produce bit-identical results to the stage-by-stage
composition — compiling the pipeline changes *when* work is traced, never
*what* is computed.

Quality-gated rank (``RankPolicy(r=None, tol=...)``) runs as: one fused
summary+rank-curve dispatch (the ``adaptive_rank`` sweep — a single SVD of
the rescaled sketch product scores EVERY candidate rank), one host read of
the curve to fast-forward the doubling schedule past ranks that provably
fail, then an estimation dispatch at the chosen rank whose *served*
a-posteriori estimate is the authoritative gate (further doubling happens
only if the curve was optimistic about the completion method). The common
case is ONE estimation dispatch total; the stage-by-stage escalation it
replaces re-ran a full estimation dispatch plus a blocking host sync per
doubling round unconditionally.

>>> import jax, jax.numpy as jnp
>>> from repro.core.pipeline import PipelineEngine, smppca_plan
>>> key = jax.random.PRNGKey(0)
>>> A = jax.random.normal(key, (128, 12))
>>> B = jax.random.normal(jax.random.fold_in(key, 1), (128, 10))
>>> engine = PipelineEngine()
>>> plan = smppca_plan(r=3, k=32, m=400, T=2)    # hashable, declarative
>>> res = engine.run(plan, key, A, B)            # cold: trace once
>>> (res.estimate.factors.U.shape, res.estimate.factors.V.shape)
((12, 3), (10, 3))
>>> _ = engine.run(plan, key, A, B)              # warm: one fused dispatch
>>> (engine.stats.traces, engine.stats.hits, engine.stats.misses)
(1, 1, 1)
"""
from __future__ import annotations

import collections
import dataclasses
import zlib
from typing import Callable, NamedTuple, Optional, Tuple, Union

import jax
import numpy as np

from repro.core import error_engine, estimation_engine, streaming, summary_engine
from repro.core.refinement import RefineSpec, validate_refine
from repro.core.types import EstimateResult, SketchSummary
from repro.kernels.tuning import TuningSpec

#: Supported key layouts — how one caller key fans out into per-stage keys.
LAYOUTS = ("service", "smppca", "sketch_svd", "direct")

# historical start rank of the quality-gated doubling schedule
_R0 = 4

# reserved tenant-namespace fold tag ("tnt!") — like the ErrorEngine's probe
# tag, the two-level fold cannot collide with any per-row single fold_in
_TENANT_TAG = 0x746E7421


class SketchSpec(NamedTuple):
    """Declarative step-1 stage: what ``summary_stage`` builds.

    ``method='norms_only'`` is the sketch-free LELA first pass (``k``,
    ``backend``, ``block``, ``precision`` and the sketch key are unused).
    """

    method: str = "gaussian"       # 'gaussian' | 'srht' | 'norms_only'
    backend: str = "reference"     # summary_engine.backends() minus 'distributed'
    k: int = 128
    block: int = 1024
    precision: Optional[str] = None
    probes: int = 0
    cosketch: int = 0              # refinement co-sketch width s (0 = off)


class EstimationSpec(NamedTuple):
    """Declarative steps-2/3 stage: what ``estimation_stage`` runs.

    ``m=None`` means the paper's default sample budget (``default_m``),
    resolved at trace time from the summary's static shapes.
    """

    method: str = "rescaled_jl"    # estimation_engine.METHODS
    backend: str = "jit"           # estimation_engine.BACKENDS
    m: Optional[int] = None
    T: int = 10
    use_splits: bool = False


class RankPolicy(NamedTuple):
    """Rank selection: fixed (``r=<int>``) or quality-gated auto.

    ``r=None`` with ``tol=<relative Frobenius error>`` gates the rank: the
    engine reads the per-rank error curve once (one fused SVD sweep) and
    picks the first rank on the doubling schedule (4, 8, 16, ... capped at
    ``r_max`` and min(n1, n2, k)) whose estimated error meets ``tol``.
    """

    r: Optional[int] = None
    tol: Optional[float] = None
    r_max: Optional[int] = None

    @property
    def auto(self) -> bool:
        """True when the rank is quality-gated rather than fixed."""
        return self.r is None


class PipelinePlan(NamedTuple):
    """The whole pipeline as one hashable value — the executable-cache key.

    ``key_layout`` fixes how the caller's base key fans out into the
    (sketch key, estimation key) pair; the layouts are the frozen historical
    derivations (see docs/architecture.md "Where the randomness lives"):

    * ``'service'``    sketch = key, estimation = ``fold_in(key, 1)``
      (vmapped over the key stack in batched mode) — ``SketchService``;
    * ``'smppca'``     ``split(key, 3)`` -> sketch = part 0, estimation =
      ``fold_in(part 1, 0)`` — Algorithm 1's layout;
    * ``'sketch_svd'`` ``split(key)`` -> (sketch, estimation);
    * ``'direct'``     both stages get the caller key unchanged — LELA.

    ``with_error`` attaches the ErrorEngine estimate inside the same fused
    dispatch (needs ``sketch.probes > 0``); the quality-gated path always
    attaches it, mirroring the escalation loop it replaces.

    ``tuning`` optionally pins Pallas kernel configs (a hashable
    ``repro.kernels.tuning.TuningSpec``). ``None`` — the default, and the
    hash every pre-tuning plan has — resolves each kernel through the
    committed tuning table / frozen defaults at trace time. Because the
    spec is part of this NamedTuple it is part of every executable cache
    key: two plans differing only in tuning compile separately, and warm
    repeat-shape traffic under either never re-traces.

    ``refine`` pins the reconstruction refinement for ``method='power'``
    estimation (a hashable ``RefineSpec``) and requires a co-sketch-carrying
    sketch stage (``SketchSpec(cosketch=s)``). Like ``tuning`` it rides the
    NamedTuple, so it joins every executable cache key: warm serving under a
    pinned refinement never re-traces, and plans differing only in iters or
    method compile separately. ``None`` — the default, and the hash every
    pre-refinement plan has — leaves the pipeline bit-identical to before.

    ``wire`` pins the transport precision for states this plan's streams
    put on the wire (a hashable ``streaming.WireSpec`` — checkpoint writes
    and cross-host merges; see docs/streaming.md "Scale-out ingest"). The
    compute path never reads it, but it rides the NamedTuple so plans
    differing only in transport hash apart. ``None`` — the default, and
    the hash every pre-wire plan has — means lossless f32 transport.
    """

    sketch: SketchSpec = SketchSpec()
    estimation: EstimationSpec = EstimationSpec()
    rank: RankPolicy = RankPolicy()
    key_layout: str = "service"
    with_error: bool = False
    tuning: Optional[TuningSpec] = None
    refine: Optional[RefineSpec] = None
    wire: Optional["streaming.WireSpec"] = None


class PipelineResult(NamedTuple):
    """One pipeline execution: the step-1 summary + the step-2/3 estimate
    (with the ErrorEngine estimate attached when the plan asked for it)."""

    summary: SketchSummary
    estimate: EstimateResult


@dataclasses.dataclass
class EngineStats:
    """Observable engine counters (the compile-counter hook the cache tests
    read). ``traces`` increments inside the traced Python body, so it counts
    actual XLA traces — a warm cache shows dispatches without traces."""

    traces: int = 0            # XLA traces (executable compilations)
    hits: int = 0              # executable-cache hits
    misses: int = 0            # executable-cache misses (fresh builds)
    evictions: int = 0         # LRU evictions past max_entries
    est_dispatches: int = 0    # dispatches of an estimation-carrying executable
    curve_dispatches: int = 0  # dispatches of a rank-curve executable


def tenant_id(tenant: Union[int, str]) -> int:
    """Canonical uint31 id for a tenant handle (int passed through, str
    hashed) — the value ``tenant_key`` folds into the key derivation.

    Ints must already sit in the fold_in range [0, 2^31); strings map
    through crc32 (stable across processes and Python versions, unlike
    ``hash``) masked into the same range.
    """
    if isinstance(tenant, bool) or not isinstance(tenant, (int, str)):
        raise TypeError(f"tenant must be an int or str, got {tenant!r}")
    if isinstance(tenant, str):
        return zlib.crc32(tenant.encode()) & 0x7FFFFFFF
    if not 0 <= tenant < 2 ** 31:
        raise ValueError(f"int tenant ids must be in [0, 2**31), got {tenant}")
    return tenant


def tenant_key(key: jax.Array, tenant: Union[int, str]) -> jax.Array:
    """Namespace a caller key under a tenant: the reserved two-level fold
    ``fold_in(fold_in(key, 0x746E7421), tenant_id(tenant))``.

    This is how many tenants share one warm ``PipelineEngine`` executable
    cache without randomness collisions: the fold happens BEFORE the
    layout fan-out (so every downstream sketch/estimation/probe key is
    namespaced), it changes only key *values* — never shapes, plans, or
    executables — and the reserved tag keeps two tenants submitting the
    same user key bit-independent of each other and of every non-tenant
    derivation. Golden-tested in tests/core/test_key_contract.py.
    """
    return jax.random.fold_in(
        jax.random.fold_in(key, _TENANT_TAG), tenant_id(tenant))


def derive_keys(layout: str, key: jax.Array, *, batched: bool = False,
                tenant: Optional[Union[int, str]] = None):
    """(sketch key, estimation key) under a fixed layout — pure/traceable.

    The ONE place the plan-path key fan-out lives; every derivation is the
    frozen historical one, golden-tested in tests/core/test_key_contract.py.
    Batched mode (a stacked key per pair) is a 'service' notion: the other
    layouts take exactly one caller key. ``tenant`` (if given) namespaces
    the caller key through ``tenant_key`` before the fan-out — ``None``
    (the default) leaves every historical derivation bit-identical. The
    serving scheduler folds per-request tenants host-side before stacking,
    which lands on exactly this derivation.
    """
    if tenant is not None:
        if batched:
            key = jax.vmap(lambda kk: tenant_key(kk, tenant))(key)
        else:
            key = tenant_key(key, tenant)
    if layout == "service":
        if batched:
            return key, jax.vmap(lambda kk: jax.random.fold_in(kk, 1))(key)
        return key, jax.random.fold_in(key, 1)
    if batched:
        raise NotImplementedError(
            f"batched pipelines are only defined for key_layout='service' "
            f"(got {layout!r})")
    if layout == "smppca":
        k_sketch, k_sample, _ = jax.random.split(key, 3)
        return k_sketch, jax.random.fold_in(k_sample, 0)
    if layout == "sketch_svd":
        k_sketch, k_pow = jax.random.split(key)
        return k_sketch, k_pow
    if layout == "direct":
        return key, key
    raise ValueError(f"unknown key layout {layout!r} (use one of {LAYOUTS})")


def validate_plan(plan: PipelinePlan) -> None:
    """Reject malformed plans eagerly, before any executable is built."""
    if not isinstance(plan, PipelinePlan):
        raise TypeError(f"expected a PipelinePlan, got {type(plan).__name__}")
    sk, est, rank = plan.sketch, plan.estimation, plan.rank
    if plan.key_layout not in LAYOUTS:
        raise ValueError(f"unknown key layout {plan.key_layout!r} "
                         f"(use one of {LAYOUTS})")
    if sk.method not in summary_engine.METHODS + ("norms_only",):
        raise ValueError(f"unknown sketch method {sk.method!r} (use "
                         f"{summary_engine.METHODS + ('norms_only',)})")
    if sk.method != "norms_only":
        if sk.backend not in summary_engine.backends():
            raise ValueError(f"unknown summary backend {sk.backend!r} "
                             f"(use one of {summary_engine.backends()})")
        if sk.backend == "distributed":
            raise ValueError(
                "backend='distributed' needs a mesh and is not "
                "plan-compilable — use build_summary(..., mesh=, axis=) "
                "or distributed_streaming_summary directly")
    if est.method not in estimation_engine.METHODS:
        raise ValueError(f"unknown estimation method {est.method!r} "
                         f"(use one of {estimation_engine.METHODS})")
    if est.backend not in estimation_engine.BACKENDS:
        raise ValueError(f"unknown estimation backend {est.backend!r} "
                         f"(use one of {estimation_engine.BACKENDS})")
    if rank.auto:
        if rank.tol is None:
            raise ValueError(
                "RankPolicy(r=None) is quality-gated and needs tol= "
                "(the relative-error gate)")
        if plan.sketch.probes <= 0:
            raise ValueError(
                "quality-gated rank needs a probe-carrying sketch stage — "
                "set SketchSpec(probes=p)")
    elif not isinstance(rank.r, int):
        raise ValueError(f"RankPolicy.r must be an int or None, "
                         f"got {rank.r!r}")
    if plan.with_error and plan.sketch.probes <= 0:
        raise ValueError("with_error=True needs SketchSpec(probes=p)")
    if est.method == "power" and sk.cosketch <= 0:
        raise ValueError(
            "estimation method 'power' reconstructs from the refinement "
            "co-sketch block — set SketchSpec(cosketch=s)")
    if plan.refine is not None:
        validate_refine(plan.refine)
        if est.method != "power":
            raise ValueError(
                f"PipelinePlan.refine only applies to estimation "
                f"method='power', got method={est.method!r}")
    if plan.tuning is not None:
        if not isinstance(plan.tuning, TuningSpec):
            raise ValueError(f"PipelinePlan.tuning must be a TuningSpec or "
                             f"None, got {type(plan.tuning).__name__}")
        plan.tuning.validate()
    if plan.wire is not None:
        if not isinstance(plan.wire, streaming.WireSpec):
            raise ValueError(f"PipelinePlan.wire must be a WireSpec or "
                             f"None, got {type(plan.wire).__name__}")
        streaming._as_wire_spec(plan.wire)


def _signature(tree) -> tuple:
    """Shape/dtype signature of an argument pytree (the cache-key half that
    tracks what the executable was traced for)."""
    return tuple((tuple(leaf.shape), str(leaf.dtype))
                 for leaf in jax.tree_util.tree_leaves(tree))


class PipelineEngine:
    """LRU cache of plan-compiled executables + the host-side rank gate.

    One engine instance is one executable cache: facades share the process
    default (``get_engine()``), services can hold their own. ``max_entries``
    bounds the cache; the least-recently-used executable is dropped past it
    (``stats.evictions``) and re-traced on next use.
    """

    def __init__(self, max_entries: int = 64):
        if max_entries < 1:
            raise ValueError(f"max_entries must be >= 1, got {max_entries}")
        self.max_entries = max_entries
        self._cache: "collections.OrderedDict[tuple, Callable]" = \
            collections.OrderedDict()
        self.stats = EngineStats()

    # -- cache plumbing ----------------------------------------------------

    def __len__(self) -> int:
        return len(self._cache)

    def clear(self) -> None:
        """Drop every cached executable (counters are kept)."""
        self._cache.clear()

    def _executable(self, cache_key: tuple, build: Callable) -> Callable:
        try:
            fn = self._cache[cache_key]
        except KeyError:
            self.stats.misses += 1
            fn = build()
            self._cache[cache_key] = fn
            if len(self._cache) > self.max_entries:
                self._cache.popitem(last=False)
                self.stats.evictions += 1
            return fn
        self._cache.move_to_end(cache_key)
        self.stats.hits += 1
        return fn

    # -- executable builders (each body traces exactly once per cache entry)

    def _build_full(self, plan: PipelinePlan, batched: bool) -> Callable:
        def pipeline_fn(key, A, B):
            self.stats.traces += 1
            k_sketch, k_est = derive_keys(plan.key_layout, key,
                                          batched=batched)
            summary = summary_engine.summary_stage(plan.sketch, k_sketch,
                                                   A, B, plan.tuning)
            exact = (A, B) if plan.estimation.method == "lela_waltmin" \
                else None
            est = estimation_engine.estimation_stage(
                plan.estimation, k_est, summary, plan.rank.r,
                exact_pair=exact, refine=plan.refine,
                with_error=plan.with_error)
            return PipelineResult(summary, est)
        return jax.jit(pipeline_fn)

    def _build_curve_full(self, plan: PipelinePlan, batched: bool) -> Callable:
        def curve_fn(key, A, B):
            self.stats.traces += 1
            k_sketch, _ = derive_keys(plan.key_layout, key, batched=batched)
            summary = summary_engine.summary_stage(plan.sketch, k_sketch,
                                                   A, B, plan.tuning)
            return summary, self._curve(plan, summary, batched)
        return jax.jit(curve_fn)

    def _build_curve_from_summary(self, plan: PipelinePlan,
                                  batched: bool) -> Callable:
        def curve_fn(summary):
            self.stats.traces += 1
            return self._curve(plan, summary, batched)
        return jax.jit(curve_fn)

    def _build_from_summary(self, plan: PipelinePlan,
                            batched: bool) -> Callable:
        def estimate_fn(key, summary, exact_pair):
            self.stats.traces += 1
            _, k_est = derive_keys(plan.key_layout, key, batched=batched)
            return estimation_engine.estimation_stage(
                plan.estimation, k_est, summary, plan.rank.r,
                exact_pair=exact_pair, refine=plan.refine,
                with_error=plan.with_error)
        return jax.jit(estimate_fn)

    def _build_summary_only(self, spec: SketchSpec,
                            tuning: Optional[TuningSpec]) -> Callable:
        def summary_fn(key, A, B):
            self.stats.traces += 1
            return summary_engine.summary_stage(spec, key, A, B, tuning)
        return jax.jit(summary_fn)

    def _curve(self, plan: PipelinePlan, summary, batched: bool):
        """Per-rank estimated-error curve up to the plan's rank cap.

        Shapes are static under trace, so the cap is resolved here and baked
        into the executable. Batched summaries get one vmapped sweep. A
        refined plan scores *refined* truncations (the gate then passes at
        the rank the served factors actually achieve), capped additionally
        by the co-sketch width — the refined basis has only s columns."""
        n1 = int(summary.A_sketch.shape[-1])
        n2 = int(summary.B_sketch.shape[-1])
        cap = min(n1, n2, plan.sketch.k)
        if plan.refine is not None:
            cap = min(cap, int(summary.cosketch_Y.shape[-1]))
        r_cap = cap if plan.rank.r_max is None else min(plan.rank.r_max, cap)
        if batched:
            return jax.vmap(lambda s: error_engine.rank_curve(
                s, r_cap, refine=plan.refine))(summary)
        return error_engine.rank_curve(summary, r_cap, refine=plan.refine)

    # -- the rank gate (host side; ONE curve read per bucket) --------------

    @staticmethod
    def _pick_rank(curve, tol: float) -> int:
        """First rank on the doubling schedule whose estimated error meets
        ``tol`` for EVERY request in the bucket (else the cap) — the exact
        decision rule of the per-round escalation loop this replaces, read
        off the precomputed curve in one host sync."""
        worst = np.asarray(jax.device_get(curve))
        if worst.ndim == 2:
            worst = worst.max(axis=0)
        r_cap = int(worst.shape[0])
        r = min(_R0, r_cap)
        while worst[r - 1] > tol and r < r_cap:
            r = min(2 * r, r_cap)
        return r

    @staticmethod
    def _curve_cache_plan(plan: PipelinePlan) -> PipelinePlan:
        """The curve executable never reads ``tol`` (it is consumed host-side
        by the rank pick), so strip it from the cache key — gated requests
        differing only in tolerance share one compiled sweep."""
        return plan._replace(rank=plan.rank._replace(tol=None))

    def _gated_estimate(self, plan: PipelinePlan, key, summary, curve,
                        exact_pair) -> EstimateResult:
        """The quality gate: the precomputed curve fast-forwards the doubling
        schedule to its first plausible rank, then the *served* factors'
        a-posteriori estimate is the authoritative check — if it still misses
        ``tol`` (the curve scores SVD truncations of the rescaled sketch
        product; a completion method's factors can be worse), the schedule
        keeps doubling exactly like the escalation loop this replaces. The
        common case is ONE estimation dispatch; extra rounds happen only when
        the curve was optimistic."""
        r_cap = int(curve.shape[-1])
        r = self._pick_rank(curve, plan.rank.tol)
        while True:
            fixed = plan._replace(rank=RankPolicy(r=r), with_error=True)
            est = self._estimate_from_summary(fixed, key, summary, exact_pair)
            worst = float(np.max(np.asarray(jax.device_get(
                est.error.rel_est))))
            if worst <= plan.rank.tol or r >= r_cap:
                return est
            r = min(2 * r, r_cap)

    # -- entry points ------------------------------------------------------

    def run(self, plan: PipelinePlan, key: jax.Array, A: jax.Array,
            B: jax.Array) -> PipelineResult:
        """Execute the whole plan on (A, B) — (d, n) pairs, or stacked
        (L, d, n) with a key stack for the batched/vmapped mode.

        Fixed rank: one fused summary->estimation->error dispatch. Auto rank:
        one fused summary+curve dispatch, one host curve read, then the
        curve-fast-forwarded estimation rounds of ``_gated_estimate`` (ONE
        dispatch in the common case; ``with_error`` forced on, and the served
        estimate — not the curve — has the final word on ``tol``).
        """
        validate_plan(plan)
        batched = A.ndim == 3
        if not plan.rank.auto:
            fn = self._executable(("full", plan, _signature((key, A, B))),
                                  lambda: self._build_full(plan, batched))
            self.stats.est_dispatches += 1
            return fn(key, A, B)
        curve_plan = self._curve_cache_plan(plan)
        fn = self._executable(
            ("curve_full", curve_plan, _signature((key, A, B))),
            lambda: self._build_curve_full(curve_plan, batched))
        self.stats.curve_dispatches += 1
        summary, curve = fn(key, A, B)
        exact = (A, B) if plan.estimation.method == "lela_waltmin" else None
        est = self._gated_estimate(plan, key, summary, curve, exact)
        return PipelineResult(summary, est)

    def run_from_summary(self, plan: PipelinePlan, key: jax.Array,
                         summary: SketchSummary, *,
                         exact_pair: Optional[Tuple[jax.Array, jax.Array]]
                         = None) -> EstimateResult:
        """Steps 2-3 (+ error) of the plan against an existing summary — the
        compiled path streaming sessions share with ``run`` (the summary was
        accumulated chunk-by-chunk, so the sketch stage already happened).
        The estimation key is derived from ``key`` by the plan's layout,
        exactly as ``run`` would."""
        validate_plan(plan)
        if not plan.rank.auto:
            return self._estimate_from_summary(plan, key, summary, exact_pair)
        batched = summary.A_sketch.ndim == 3
        curve_plan = self._curve_cache_plan(plan)
        fn = self._executable(
            ("curve_summary", curve_plan, _signature(summary)),
            lambda: self._build_curve_from_summary(curve_plan, batched))
        self.stats.curve_dispatches += 1
        return self._gated_estimate(plan, key, summary, fn(summary),
                                    exact_pair)

    def summarize(self, spec: SketchSpec, key: jax.Array, A: jax.Array,
                  B: jax.Array, tuning: Optional[TuningSpec] = None
                  ) -> SketchSummary:
        """The step-1 stage alone as a cached executable (``SketchService.
        flush``) — ``key`` is the sketch key (no layout fan-out). ``tuning``
        joins the cache key exactly as ``PipelinePlan.tuning`` does for full
        plans, so a pinned-config summary path also never re-traces warm."""
        if tuning is not None:
            if not isinstance(tuning, TuningSpec):
                raise ValueError(f"tuning must be a TuningSpec or None, "
                                 f"got {type(tuning).__name__}")
            tuning.validate()
        fn = self._executable(
            ("summary", spec, tuning, _signature((key, A, B))),
            lambda: self._build_summary_only(spec, tuning))
        return fn(key, A, B)

    def _estimate_from_summary(self, plan, key, summary,
                               exact_pair) -> EstimateResult:
        batched = summary.A_sketch.ndim == 3
        fn = self._executable(
            ("est_summary", plan, _signature((key, summary, exact_pair))),
            lambda: self._build_from_summary(plan, batched))
        self.stats.est_dispatches += 1
        return fn(key, summary, exact_pair)


# ---------------------------------------------------------------------------
# Plan presets — the algorithm facades as declarative plans
# ---------------------------------------------------------------------------

def smppca_plan(*, r: int, k: int, m: int, T: int = 10,
                method: str = "gaussian", backend: str = "reference",
                block: int = 1024, precision: Optional[str] = None,
                est_backend: str = "jit",
                use_splits: bool = False) -> PipelinePlan:
    """Algorithm 1 (SMP-PCA) as a plan: gaussian/srht sketch -> rescaled-JL
    entries -> WAltMin, under the historical split(key, 3) layout."""
    return PipelinePlan(
        sketch=SketchSpec(method=method, backend=backend, k=k, block=block,
                          precision=precision),
        estimation=EstimationSpec(method="rescaled_jl", backend=est_backend,
                                  m=m, T=T, use_splits=use_splits),
        rank=RankPolicy(r=r), key_layout="smppca")


def lela_plan(*, r: int, m: int, T: int = 10,
              use_splits: bool = False) -> PipelinePlan:
    """The LELA two-pass baseline as a plan: norms-only first pass -> exact
    sampled entries -> WAltMin (the caller key goes straight to estimation)."""
    return PipelinePlan(
        sketch=SketchSpec(method="norms_only", k=0),
        estimation=EstimationSpec(method="lela_waltmin", backend="jit", m=m,
                                  T=T, use_splits=use_splits),
        rank=RankPolicy(r=r), key_layout="direct")


def sketch_svd_plan(*, r: int, k: int, method: str = "gaussian",
                    backend: str = "reference",
                    est_backend: str = "jit") -> PipelinePlan:
    """SVD(A~^T B~) as a plan: sketch -> direct top-r SVD of the sketch
    product, under the historical split(key) layout."""
    return PipelinePlan(
        sketch=SketchSpec(method=method, backend=backend, k=k),
        estimation=EstimationSpec(method="direct_svd", backend=est_backend),
        rank=RankPolicy(r=r), key_layout="sketch_svd")


_DEFAULT_ENGINE = PipelineEngine()


def get_engine() -> PipelineEngine:
    """The process-default engine the algorithm facades share — warm plans
    stay warm across ``smppca``/``lela``/``sketch_svd``/service calls."""
    return _DEFAULT_ENGINE
