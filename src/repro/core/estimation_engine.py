"""EstimationEngine — ONE entry point for the paper's Step 2+3.

``estimate_product(key, summary, r, method=..., backend=...)`` turns any
``build_summary`` output (the Step-1 ``SketchSummary``) into rank-r factors
of A^T B. It is the step-2 mirror of the SummaryEngine: the three historical
estimation paths are registered here as *methods*, each runnable on several
execution *backends*, behind one (method, backend) registry:

methods (what is estimated):

    rescaled_jl   the paper's SMP-PCA step 2: biased Omega sample (Eq 1),
                  rescaled-JL entry estimates (Eq 2) from the sketches +
                  retained column norms, WAltMin completion (Alg 2)
    lela_waltmin  the LELA two-pass baseline [Bhojanapalli et al.]: the same
                  biased sample, but *exact* entries A_i^T B_j gathered from
                  the original pair (pass ``exact_pair=(A, B)``), then the
                  same WAltMin. Comparing it with rescaled_jl isolates the
                  eta*sigma_r^* sketching cost of Thm 3.1
    direct_svd    SVD(A~^T B~): top-r SVD of the product of the sketches, no
                  sampling/completion — the one-pass strawman SMP-PCA beats
    power         sketch-power/Tropp refinement (core/refinement.py): the
                  stabilized (Y, W) co-sketch reconstruction, optionally
                  preceded by sketch-power subspace iterations against the
                  rescaled sketch product. Needs a co-sketch-carrying
                  summary (``build_summary(..., cosketch=s)``); configured
                  by ``refine=RefineSpec(iters, method={'power','tropp'})``

backends (how it runs):

    reference     eager Python loops (WAltMin iterations dispatch one op at a
                  time; direct_svd materializes A~^T B~ and takes a dense
                  SVD) — the semantic oracle the other backends are tested
                  against, and the baseline their speedup is measured against
    jit           everything jitted: WAltMin's T iterations run as one
                  ``lax.scan`` (core/waltmin.py), direct_svd as implicit
                  power iteration — one dispatch per estimate
    pallas        like jit, but rescaled-JL entry extraction runs the
                  scalar-prefetch gather kernel ``kernels/sampled_dot.py``
                  (indices in SMEM; each grid step DMAs exactly the (1, k)
                  sketch rows it needs). Methods without a kernel-specific
                  stage (lela_waltmin, direct_svd) alias their jit path.

Batched mode: pass a summary whose fields carry a leading stack axis
(L, ...) — e.g. ``build_summary`` on stacked (L, d, n) inputs — and the
engine estimates all L products in one vmapped dispatch (one key per pair,
either ``split(key, L)`` or an explicit key stack), matching the
SummaryEngine's batched sketch mode. The reference backend loops instead
(eager python is the point of that backend); results are stacked identically.

Randomness contract: ``key`` is split once into (sample key, ALS key) —
identical across backends, so for a fixed key every backend sees the same
Omega and the same ALS initialization, and outputs agree to float
reassociation. ``smppca`` and ``lela`` are thin compositions of the two
engines and preserve their historical key derivations exactly.
"""
from __future__ import annotations

import functools
import math
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import estimator, refinement, sampling
from repro.core.refinement import RefineSpec
from repro.core.types import (
    EstimateResult, LowRankFactors, SampleSet, SketchSummary)
from repro.core.waltmin import waltmin, waltmin_reference

METHODS = ("rescaled_jl", "lela_waltmin", "direct_svd", "power")
BACKENDS = ("reference", "jit", "pallas")

_REGISTRY: Dict[Tuple[str, str], Callable] = {}


def register_estimator(method: str, backend: str):
    """Register ``fn(key, summary, r, *, m, T, use_splits, exact_pair,
    refine)`` for one (method, backend) cell. Registering an existing cell
    overrides it — the hook for experiment-specific estimators."""
    def _deco(fn):
        _REGISTRY[(method, backend)] = fn
        return fn
    return _deco


def estimators() -> tuple:
    """All registered (method, backend) cells."""
    return tuple(sorted(_REGISTRY))


def default_m(n1: int, n2: int, r: int) -> int:
    """The paper's m = Theta(n r log n) sample budget with the constant the
    experiments use (Sec 4: ~10 n r log n)."""
    n = max(n1, n2)
    return int(10 * n * r * math.log(max(n, 2)))


# ---------------------------------------------------------------------------
# Shared stages
# ---------------------------------------------------------------------------

def _sample_omega(key: jax.Array, summary: SketchSummary, m: int) -> SampleSet:
    return sampling.sample_entries(key, summary.norm_A, summary.norm_B, m)


def exact_entries(A: jax.Array, B: jax.Array, rows: jax.Array,
                  cols: jax.Array, chunk: int = 2048) -> jax.Array:
    """Exact A_i^T B_j on (rows, cols) — LELA's second pass, chunked so the
    (d, chunk) gathers stay cache-resident."""
    m = rows.shape[0]
    pad = (-m) % chunk
    rp = jnp.pad(rows, (0, pad))
    cp = jnp.pad(cols, (0, pad))

    def _body(_, rc):
        r_, c_ = rc
        return None, jnp.sum(A[:, r_] * B[:, c_], axis=0)

    _, vals = jax.lax.scan(
        _body, None, (rp.reshape(-1, chunk), cp.reshape(-1, chunk)))
    return vals.reshape(-1)[:m]


def implicit_topr(matvec, rmatvec, n1: int, n2: int, r: int, key: jax.Array,
                  n_iter: int = 12) -> LowRankFactors:
    """Top-r factors of an (n1, n2) operator given only mat-vec closures
    (randomized subspace iteration; footnote 6's 'never materialize')."""
    p = min(n2, r + 8)
    G = jax.random.normal(key, (n2, p))
    Y = matvec(G)

    def _body(_, Y):
        Q, _ = jnp.linalg.qr(Y)
        Z, _ = jnp.linalg.qr(rmatvec(Q))
        return matvec(Z)

    Y = jax.lax.fori_loop(0, n_iter, _body, Y)
    Q, _ = jnp.linalg.qr(Y)
    Bt = rmatvec(Q)                          # (n2, p)
    Ub, s, Vt = jnp.linalg.svd(Bt.T, full_matrices=False)
    return LowRankFactors(Q @ (Ub[:, :r] * s[:r]), Vt[:r].T)


# ---------------------------------------------------------------------------
# rescaled_jl — sample, estimate from the summary, complete
# ---------------------------------------------------------------------------

def _rescaled_jl(key, summary, r, *, m, T, use_splits, exact_pair,
                 refine=None, values_fn, waltmin_fn) -> EstimateResult:
    del exact_pair, refine
    k_sample, k_als = jax.random.split(key)
    samples = _sample_omega(k_sample, summary, m)
    values = values_fn(summary, samples.rows, samples.cols)
    factors = waltmin_fn(k_als, samples, values, summary.n1, summary.n2, r, T,
                         norm_A=summary.norm_A, use_splits=use_splits)
    return EstimateResult(factors, samples, values)


@register_estimator("rescaled_jl", "reference")
def _rescaled_jl_reference(key, summary, r, **kw) -> EstimateResult:
    return _rescaled_jl(key, summary, r,
                        values_fn=estimator.rescaled_entries,
                        waltmin_fn=waltmin_reference, **kw)


@register_estimator("rescaled_jl", "jit")
@functools.partial(jax.jit,
                   static_argnames=("r", "m", "T", "use_splits", "refine"))
def _rescaled_jl_jit(key, summary, r, **kw) -> EstimateResult:
    return _rescaled_jl(key, summary, r,
                        values_fn=estimator.rescaled_entries,
                        waltmin_fn=waltmin, **kw)


def _pallas_values(summary: SketchSummary, rows: jax.Array,
                   cols: jax.Array) -> jax.Array:
    """Rescaled-JL entries via the scalar-prefetch gather kernel. The kernel
    wants row-major (n, k) sketches — k is small, so the one-time transpose
    is cheap next to the O(m k) gather it unlocks."""
    from repro.kernels import ops as kops
    return kops.sampled_rescaled_dot(
        summary.A_sketch.T, summary.B_sketch.T,
        summary.norm_A, summary.norm_B, rows, cols)


@register_estimator("rescaled_jl", "pallas")
def _rescaled_jl_pallas(key, summary, r, **kw) -> EstimateResult:
    return _rescaled_jl(key, summary, r, values_fn=_pallas_values,
                        waltmin_fn=waltmin, **kw)


# ---------------------------------------------------------------------------
# lela_waltmin — sample, gather exact entries, complete (two-pass baseline)
# ---------------------------------------------------------------------------

def _lela_waltmin(key, summary, r, *, m, T, use_splits, exact_pair,
                  refine=None, waltmin_fn) -> EstimateResult:
    del refine
    if exact_pair is None:
        raise ValueError(
            "method='lela_waltmin' is the two-pass baseline: it needs the "
            "original matrices for its exact second pass — pass "
            "exact_pair=(A, B)")
    A, B = exact_pair
    k_sample, k_als = jax.random.split(key)
    samples = _sample_omega(k_sample, summary, m)
    values = exact_entries(A, B, samples.rows, samples.cols)
    factors = waltmin_fn(k_als, samples, values, summary.n1, summary.n2, r, T,
                         norm_A=summary.norm_A, use_splits=use_splits)
    return EstimateResult(factors, samples, values)


@register_estimator("lela_waltmin", "reference")
def _lela_reference(key, summary, r, **kw) -> EstimateResult:
    return _lela_waltmin(key, summary, r, waltmin_fn=waltmin_reference, **kw)


@register_estimator("lela_waltmin", "jit")
@register_estimator("lela_waltmin", "pallas")   # no kernel stage: alias jit
@functools.partial(jax.jit,
                   static_argnames=("r", "m", "T", "use_splits", "refine"))
def _lela_jit(key, summary, r, **kw) -> EstimateResult:
    return _lela_waltmin(key, summary, r, waltmin_fn=waltmin, **kw)


# ---------------------------------------------------------------------------
# direct_svd — top-r SVD of the product of the sketches, no completion
# ---------------------------------------------------------------------------

@register_estimator("direct_svd", "reference")
def _direct_svd_reference(key, summary, r, *, m, T, use_splits,
                          exact_pair, refine=None) -> EstimateResult:
    del key, m, T, use_splits, exact_pair, refine
    M = summary.A_sketch.T @ summary.B_sketch
    U, s, Vt = jnp.linalg.svd(M, full_matrices=False)
    return EstimateResult(
        LowRankFactors(U[:, :r] * s[:r], Vt[:r].T), None, None)


@register_estimator("direct_svd", "jit")
@register_estimator("direct_svd", "pallas")     # no kernel stage: alias jit
@functools.partial(jax.jit,
                   static_argnames=("r", "m", "T", "use_splits", "refine"))
def _direct_svd_jit(key, summary, r, *, m, T, use_splits,
                    exact_pair, refine=None) -> EstimateResult:
    del m, T, use_splits, exact_pair, refine
    As, Bs = summary.A_sketch, summary.B_sketch
    factors = implicit_topr(
        lambda X: As.T @ (Bs @ X),
        lambda X: Bs.T @ (As @ X),
        summary.n1, summary.n2, r, key)
    return EstimateResult(factors, None, None)


# ---------------------------------------------------------------------------
# power — sketch-power/Tropp refinement from the retained co-sketch block
# ---------------------------------------------------------------------------

@register_estimator("power", "reference")
def _power_reference(key, summary, r, *, m, T, use_splits, exact_pair,
                     refine=None) -> EstimateResult:
    """Deterministic given the summary (like direct_svd/reference, the key
    is unused — the randomness already lives in the retained co-sketch)."""
    del key, m, T, use_splits, exact_pair
    factors = refinement.refine_factors(summary, r, refine or RefineSpec())
    return EstimateResult(factors, None, None)


@register_estimator("power", "jit")
@register_estimator("power", "pallas")          # no kernel stage: alias jit
@functools.partial(jax.jit,
                   static_argnames=("r", "m", "T", "use_splits", "refine"))
def _power_jit(key, summary, r, *, m, T, use_splits, exact_pair,
               refine=None) -> EstimateResult:
    del key, m, T, use_splits, exact_pair
    factors = refinement.refine_factors(summary, r, refine or RefineSpec())
    return EstimateResult(factors, None, None)


# ---------------------------------------------------------------------------
# The entry point
# ---------------------------------------------------------------------------

def _is_key_stack(key, L: int) -> bool:
    ndim = jnp.ndim(key)
    if ndim == 2:
        return key.shape[0] == L
    if ndim == 1 and jax.dtypes.issubdtype(key.dtype, jax.dtypes.prng_key):
        return key.shape[0] == L
    return False


def estimate_product(key: jax.Array, summary: SketchSummary, r: int, *,
                     method: str = "rescaled_jl", backend: str = "jit",
                     m: Optional[int] = None, T: int = 10,
                     use_splits: bool = False,
                     exact_pair: Optional[Tuple[jax.Array, jax.Array]] = None,
                     refine: Optional[RefineSpec] = None,
                     with_error: bool = False) -> EstimateResult:
    """Rank-r factors of A^T B from a one-pass summary (Alg 1 steps 2-3).

    summary: any ``build_summary`` output — (k, n) sketches + exact column
             norms, or a stacked (L, k, n)/(L, n) summary for the batched
             mode, which vmaps the chosen (method, backend) over the L
             summaries in one dispatch (``key`` is split per pair, or pass a
             stack of L keys).
    method:  'rescaled_jl' (the paper) | 'lela_waltmin' (two-pass baseline;
             needs ``exact_pair=(A, B)``) | 'direct_svd' (SVD of the sketch
             product, no completion) | 'power' (sketch-power/Tropp
             refinement from the retained co-sketch block; needs
             ``build_summary(..., cosketch=s)`` and takes ``refine=``).
    backend: 'reference' (eager oracle) | 'jit' (lax.scan WAltMin / implicit
             power iteration) | 'pallas' (jit + the sampled-dot gather
             kernel for rescaled-JL extraction).
    m:       Omega sample budget; defaults to the paper's ~10 n r log n.
             Ignored by direct_svd.
    T:       WAltMin iteration pairs. use_splits: Alg-2 sample splitting.
    refine:  ``RefineSpec(iters, method={'power','tropp'})`` for
             method='power' — 'tropp' is the stabilized (Y, W)
             reconstruction alone, 'power' prepends ``iters`` sketch-power
             subspace iterations. Hashable and static: a fixed refine never
             re-traces the jitted cells.
    with_error: attach the ErrorEngine's a-posteriori quality estimate
             (``EstimateResult.error``) — works on every method x backend
             cell, but needs a probe-carrying summary
             (``build_summary(..., probes=p)``).

    >>> import jax, jax.numpy as jnp
    >>> from repro.core.summary_engine import build_summary
    >>> key = jax.random.PRNGKey(0)
    >>> A = jax.random.normal(key, (128, 12))
    >>> B = jax.random.normal(jax.random.fold_in(key, 1), (128, 10))
    >>> summary = build_summary(key, A, B, 32)          # step 1: one pass
    >>> res = estimate_product(jax.random.fold_in(key, 2), summary, r=3,
    ...                        m=400, T=2)              # steps 2-3
    >>> (res.factors.U.shape, res.factors.V.shape)      # A^T B ~= U @ V.T
    ((12, 3), (10, 3))
    >>> res.samples.rows.shape                          # the Omega sample
    (400,)
    """
    if method not in METHODS:
        raise ValueError(
            f"unknown estimation method {method!r} (use one of {METHODS})")
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown estimation backend {backend!r} (use one of {BACKENDS})")
    if refine is not None and method != "power":
        raise ValueError(
            f"refine= only applies to method='power', got method={method!r}")
    if method == "power":
        refine = RefineSpec() if refine is None else refine
        refinement.validate_refine(refine)
        refinement.require_cosketch(summary)
    if method in ("rescaled_jl", "lela_waltmin"):
        # the Eq. (1) sampler is undefined on a zero factor — fail eagerly
        # here (the jitted cells trace through the norms and cannot)
        sampling.require_nonzero_norms(summary.norm_A, summary.norm_B)
    fn = _REGISTRY[(method, backend)]
    batched = summary.A_sketch.ndim == 3
    if with_error and summary.probes is None:
        raise ValueError(
            "with_error=True needs a probe-carrying summary — build it with "
            "build_summary(..., probes=p) / StreamingSummarizer(probes=p)")
    if m is None:
        m = default_m(int(summary.A_sketch.shape[-1]),
                      int(summary.B_sketch.shape[-1]), r)
    kw = dict(m=m, T=T, use_splits=use_splits, exact_pair=exact_pair,
              refine=refine)

    if not batched:
        return _maybe_error(fn(key, summary, r, **kw), summary, with_error)

    L = summary.A_sketch.shape[0]
    keys = key if _is_key_stack(key, L) else jax.random.split(key, L)
    if backend == "reference":
        # eager python is the point of this backend — loop, then stack
        outs = []
        for i in range(L):
            kw_i = dict(kw, exact_pair=None if exact_pair is None else
                        (exact_pair[0][i], exact_pair[1][i]))
            outs.append(fn(keys[i], jax.tree.map(lambda x: x[i], summary),
                           r, **kw_i))
        out = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    elif exact_pair is not None:
        A, B = exact_pair
        out = jax.vmap(
            lambda kk, s, a, b: fn(kk, s, r, m=m, T=T, use_splits=use_splits,
                                   exact_pair=(a, b), refine=refine)
        )(keys, summary, A, B)
    else:
        out = jax.vmap(lambda kk, s: fn(kk, s, r, **kw))(keys, summary)
    return _maybe_error(out, summary, with_error, batched=True)


def estimation_stage(spec, key: jax.Array, summary: SketchSummary, r: int, *,
                     exact_pair: Optional[Tuple[jax.Array, jax.Array]] = None,
                     refine: Optional[RefineSpec] = None,
                     with_error: bool = False) -> EstimateResult:
    """Steps 2-3 as a fusable stage driven by a declarative spec.

    ``spec`` is any object with the ``EstimationSpec`` fields (method,
    backend, m, T, use_splits) — ``core.pipeline`` owns the concrete type.
    ``refine`` rides the plan (``PipelinePlan.refine``), not the spec, so
    one spec hash serves every refinement. Pure and traceable: the
    PipelineEngine composes it with the summary and error stages inside ONE
    jitted executable.
    """
    return estimate_product(key, summary, r, method=spec.method,
                            backend=spec.backend, m=spec.m, T=spec.T,
                            use_splits=spec.use_splits, exact_pair=exact_pair,
                            refine=refine, with_error=with_error)


def _maybe_error(result: EstimateResult, summary: SketchSummary,
                 with_error: bool, *, batched: bool = False) -> EstimateResult:
    """Attach the a-posteriori ErrorEstimate — one (possibly vmapped)
    probe evaluation per result, uniform across every registry cell."""
    if not with_error:
        return result
    from repro.core.error_engine import estimate_error
    fn = jax.vmap(estimate_error) if batched else estimate_error
    return result._replace(error=fn(summary, result.factors))
