"""Step 1 of SMP-PCA: one-pass sketching with side information.

Computes ``A_sketch = Pi @ A``, ``B_sketch = Pi @ B`` and the exact column
norms of A and B in a single pass over the row dimension ``d`` (the streamed
dimension). Supports:

* dense Gaussian JL (``Pi(i,j) ~ N(0, 1/k)``) — the paper's analyzed sketch,
* SRHT (subsampled randomized Hadamard transform) — the paper's Spark choice,
* arbitrary-order streaming: row ``i``'s sketch contribution depends only on
  ``(key, i)``, so rows may arrive in any order (paper's streaming-log claim),
* block-streamed single-pass accumulation (``sketch_pass``) mirroring what the
  fused Pallas kernel does tile-by-tile on TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import SketchSummary


# ---------------------------------------------------------------------------
# Pi generation
# ---------------------------------------------------------------------------

def gaussian_pi(key: jax.Array, k: int, d: int, dtype=jnp.float32) -> jax.Array:
    """Dense (k, d) Gaussian JL matrix with entries N(0, 1/k)."""
    return jax.random.normal(key, (k, d), dtype) / jnp.sqrt(k).astype(dtype)


def pi_rows(key: jax.Array, row_idx: jax.Array, k: int, dtype=jnp.float32) -> jax.Array:
    """Columns of Pi for the given data-row indices, order independent.

    Returns (len(row_idx), k): entry ``[t, :] = Pi[:, row_idx[t]]``. Each data
    row's projection vector is a pure function of ``(key, row_index)`` so a
    stream may deliver rows in arbitrary order and the final sketch is
    identical (tested in tests/core/test_sketch.py).
    """
    def _one(i):
        return jax.random.normal(jax.random.fold_in(key, i), (k,), dtype)

    return jax.vmap(_one)(row_idx.astype(jnp.uint32)) / jnp.sqrt(k).astype(dtype)


# ---------------------------------------------------------------------------
# Fast Walsh-Hadamard transform (reference path; MXU-blocked version lives in
# repro.kernels.hadamard)
# ---------------------------------------------------------------------------

def _next_pow2(x: int) -> int:
    p = 1
    while p < x:
        p *= 2
    return p


def fwht(x: jax.Array, axis: int = 0) -> jax.Array:
    """Unnormalized fast Walsh-Hadamard transform along ``axis`` (len = 2^p)."""
    x = jnp.moveaxis(x, axis, 0)
    d = x.shape[0]
    if d < 1 or d & (d - 1):
        raise ValueError(
            f"FWHT length must be a power of two, got {d} "
            f"(axis {axis} of shape {x.shape})")
    shape_rest = x.shape[1:]
    h = 1
    while h < d:
        x = x.reshape(d // (2 * h), 2, h, *shape_rest)
        a = x[:, 0]
        b = x[:, 1]
        x = jnp.stack([a + b, a - b], axis=1)
        h *= 2
    x = x.reshape(d, *shape_rest)
    return jnp.moveaxis(x, 0, axis)


def srht_sketch(key: jax.Array, X: jax.Array, k: int) -> jax.Array:
    """SRHT sketch: sqrt(1/k) * R H D X  (R = k sampled rows, H normalized).

    X: (d, n) -> (k, n). Pads d to the next power of two (zero rows do not
    change column norms or inner products).
    """
    d, _ = X.shape
    dp = _next_pow2(d)
    key_sign, key_rows = jax.random.split(key)
    signs = jax.random.rademacher(key_sign, (d,), dtype=X.dtype)
    Xp = X * signs[:, None]
    if dp != d:
        Xp = jnp.pad(Xp, ((0, dp - d), (0, 0)))
    HX = fwht(Xp, axis=0) / jnp.sqrt(dp).astype(X.dtype)
    rows = jax.random.choice(key_rows, dp, (k,), replace=False)
    return HX[rows] * jnp.sqrt(dp / k).astype(X.dtype)


# ---------------------------------------------------------------------------
# One-pass summaries — thin wrappers over the SummaryEngine (kept for API
# compatibility; the implementations are registered backends in
# repro.core.summary_engine)
# ---------------------------------------------------------------------------

def column_norms(X: jax.Array) -> jax.Array:
    """Exact L2 column norms, accumulated in float32."""
    return jnp.sqrt(jnp.sum(X.astype(jnp.float32) ** 2, axis=0))


def sketch_summary(key: jax.Array, A: jax.Array, B: jax.Array, k: int,
                   method: str = "gaussian") -> SketchSummary:
    """Direct (materialized-operator) summary == engine 'reference' backend."""
    from repro.core.summary_engine import build_summary
    return build_summary(key, A, B, k, method=method, backend="reference")


def sketch_pass(key: jax.Array, A: jax.Array, B: jax.Array, k: int,
                block: int = 1024) -> SketchSummary:
    """Block-streamed single pass == engine 'scan' backend (Gaussian Pi).

    Each block regenerates its Pi slice from (key, global row index) so the
    full (k, d) operator never exists — the memory model of the paper's
    streaming pass and of the fused TPU kernel.
    """
    from repro.core.summary_engine import build_summary
    return build_summary(key, A, B, k, backend="scan", block=block)


def streamed_rows_summary(key: jax.Array, row_idx: jax.Array,
                          A_rows: jax.Array, B_rows: jax.Array,
                          k: int) -> SketchSummary:
    """Arbitrary-order streaming: rows arrive as (index, A row, B row) triples.

    The result is independent of arrival order (sketching is a sum over rows).
    == engine ``rows_summary`` (which additionally supports srht).
    """
    from repro.core.summary_engine import rows_summary
    return rows_summary(key, row_idx, A_rows, B_rows, k)


def merge_summaries(a: SketchSummary, b: SketchSummary) -> SketchSummary:
    """Combine summaries of disjoint row shards (Spark treeAggregate combiner).

    Probe and co-sketch blocks (when retained) merge as plain sums — they
    are linear in the rows like the sketches; the shared test matrices are
    carried from ``a`` (both operands must descend from the same key)."""
    from repro.core.error_engine import merge_probes
    from repro.core.refinement import merge_cosketch
    return SketchSummary(
        a.A_sketch + b.A_sketch,
        a.B_sketch + b.B_sketch,
        jnp.sqrt(a.norm_A ** 2 + b.norm_A ** 2),
        jnp.sqrt(a.norm_B ** 2 + b.norm_B ** 2),
        probes=merge_probes(a.probes, b.probes),
        probe_omega=a.probe_omega,
        cosketch_Y=merge_cosketch(a.cosketch_Y, b.cosketch_Y),
        cosketch_W=merge_cosketch(a.cosketch_W, b.cosketch_W),
        cosketch_omega=a.cosketch_omega,
        cosketch_psi=a.cosketch_psi,
    )
