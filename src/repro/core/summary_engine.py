"""SummaryEngine — ONE entry point for the paper's Step-1 single pass.

``build_summary(key, A, B, k, method=..., backend=...)`` produces the
``SketchSummary`` (sketches + exact column norms) that every downstream
stage (sampling, rescaled-JL, WAltMin, gradient compression, serving)
consumes. The five historical implementations are registered here as
*backends* behind one shared randomness contract, following the
one-abstraction/many-instantiations design of Tropp et al.'s practical
sketching framework:

    reference    materialized projection operator, one dense matmul
                 (the semantic oracle every other backend is tested against)
    scan         block-streamed ``lax.scan`` over row blocks; the projection
                 slice for each block is regenerated on the fly so the full
                 (k, d) operator never exists (the paper's streaming pass)
    rows         arbitrary-order row streaming (``rows_summary``): rows may
                 arrive as (global index, A row, B row) triples in any order
    pallas       fused TPU kernel(s): one HBM pass produces the sketch on the
                 MXU and the column norms on the VPU (kernels/sketch_fused);
                 SRHT uses the blocked-FWHT MXU kernel (kernels/hadamard)
    distributed  row-sharded ``shard_map`` + psum — Spark treeAggregate as a
                 single ICI all-reduce (core/distributed)

Shared randomness contract (what makes the backends interchangeable):

* ``method='gaussian'``: the projection column for global row ``i`` is
  ``normal(fold_in(key, i), (k,)) / sqrt(k)`` — a pure function of
  ``(key, i)``, so any partition of the rows (blocks, shards, arbitrary
  streams) accumulates to the same summary.
* ``method='srht'``: signs and sampled Hadamard rows are derived once from
  ``key`` (``srht_plan``); the projection column for row ``i`` is
  ``signs[i] * H[rows, i] / sqrt(k)`` where ``H[r, i] = (-1)^popcount(r & i)``
  is the Sylvester Hadamard entry — computable pointwise, which is what lets
  SRHT stream row-by-row even though H globally mixes all rows.

Batched mode: pass ``A``/``B`` with a leading stack axis ``(L, d, n)`` and the
engine sketches all L pairs in one vmapped dispatch (one key per pair, either
``split(key, L)`` or an explicit key stack) — the per-layer case the gradient
compressor needs.

Precision: ``precision='bf16'`` casts inputs to bfloat16 while every
accumulation (MXU contraction and norm reduction) stays float32
(bf16-in/f32-accumulate); sketches and norms are always float32 outputs.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.sketch import (
    _next_pow2, column_norms, gaussian_pi, pi_rows)
from repro.core.types import SketchSummary

METHODS = ("gaussian", "srht")

_BACKENDS: Dict[str, Callable] = {}


def register_backend(name: str):
    """Register ``fn(key, A, B, k, *, method, block, precision, tuning,
    **kw)``. ``tuning`` is an optional hashable
    ``repro.kernels.tuning.TuningSpec``; only kernel-backed backends act on
    it (the others must accept and ignore it so one plan drives any
    backend)."""
    def _deco(fn):
        _BACKENDS[name] = fn
        return fn
    return _deco


def backends() -> tuple:
    """All registered summary backend names."""
    return tuple(sorted(_BACKENDS))


# ---------------------------------------------------------------------------
# Shared randomness + precision plumbing
# ---------------------------------------------------------------------------

def _cast(x: jax.Array, precision: Optional[str]) -> jax.Array:
    """precision=None keeps the input dtype (bf16 data stays bf16-in; no
    upcast copy is materialized) — accumulation is f32 regardless via
    ``preferred_element_type`` and the f32 norm reductions."""
    if precision is None:
        return x
    if precision == "f32":
        return x if x.dtype == jnp.float32 else x.astype(jnp.float32)
    if precision == "bf16":
        return x.astype(jnp.bfloat16)
    raise ValueError(f"unknown precision {precision!r} (use None|'f32'|'bf16')")


def srht_plan(key: jax.Array, d: int, k: int):
    """(signs (d,), sampled Hadamard rows (k,), dp): the SRHT randomness.

    The derivation (key split, rademacher signs, no-replacement row sample
    over the power-of-two padded dimension) matches ``core.sketch.srht_sketch``
    and ``kernels.ops.srht_sketch_kernel`` so all backends share one plan."""
    dp = _next_pow2(d)
    if k > dp:
        raise ValueError(
            f"srht needs k <= next_pow2(d): k={k} exceeds the padded "
            f"dimension dp={dp} (d={d}) — no-replacement row sampling "
            f"cannot draw k rows from dp")
    key_sign, key_rows = jax.random.split(key)
    signs = jax.random.rademacher(key_sign, (d,), dtype=jnp.float32)
    rows = jax.random.choice(key_rows, dp, (k,), replace=False)
    return signs, rows, dp


def hadamard_cols(sampled_rows: jax.Array, row_idx: jax.Array) -> jax.Array:
    """H[sampled_rows][:, row_idx] for the Sylvester Hadamard matrix, via
    ``H[r, i] = (-1)^popcount(r & i)`` — O(k * t) pointwise, no transform."""
    r = sampled_rows.astype(jnp.int32)[:, None]
    i = row_idx.astype(jnp.int32)[None, :]
    bit = jax.lax.population_count(r & i) & 1
    return (1 - 2 * bit).astype(jnp.float32)


def srht_rows_from_plan(signs_rows: jax.Array, sampled_rows: jax.Array,
                        row_idx: jax.Array, k: int) -> jax.Array:
    """(t, k) SRHT projection columns for global rows ``row_idx`` given the
    plan: ``signs_rows`` are the sign entries already gathered/sliced for
    ``row_idx``. THE one place the streamed-SRHT column formula lives — the
    reference, scan, rows, and distributed backends all call this, which is
    what the cross-backend parity contract rests on."""
    Hc = hadamard_cols(sampled_rows, row_idx)                   # (k, t)
    return (Hc * signs_rows[None, :]).T / jnp.sqrt(k)


def projection_rows(key: jax.Array, row_idx: jax.Array, k: int, *,
                    method: str = "gaussian", d_total: Optional[int] = None,
                    plan=None) -> jax.Array:
    """Columns of the (k, d) sketch operator for the given global row ids.

    Returns (t, k) with ``[t, :] = Pi[:, row_idx[t]]`` — the engine's
    randomness contract in one function. For srht, pass either ``d_total``
    (the global streamed dimension; the plan is derived from ``key``) or a
    precomputed ``plan = srht_plan(key, d_total, k)[:2]`` — streaming
    callers should derive the plan once and reuse it per chunk rather than
    paying the O(d_total) derivation every time."""
    if method == "gaussian":
        return pi_rows(key, row_idx, k)
    if method == "srht":
        if plan is not None:
            signs, rows = plan[0], plan[1]
        elif d_total is not None:
            signs, rows, _ = srht_plan(key, d_total, k)
        else:
            raise ValueError("method='srht' needs d_total or plan=")
        s = signs[jnp.clip(row_idx, 0, signs.shape[0] - 1)]     # pad rows -> 0 data
        return srht_rows_from_plan(s, rows, row_idx, k)
    raise ValueError(f"unknown sketch method {method!r} (use {METHODS})")


def _sketch_dot(P: jax.Array, X: jax.Array,
                precision: Optional[str]) -> jax.Array:
    """(t, k)^T @ (t, n) with f32 accumulation regardless of input dtype.

    The freshly generated projection is cast to X's (possibly reduced)
    dtype — never the data up — so low-precision inputs hit the MXU at
    full rate with f32 accumulation."""
    Xc = _cast(X, precision)
    return jax.lax.dot_general(
        _cast(P, precision).astype(Xc.dtype), Xc,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# Backends
# ---------------------------------------------------------------------------

@register_backend("reference")
@functools.partial(jax.jit, static_argnames=("k", "method", "block",
                                             "precision", "tuning"))
def _reference_backend(key, A, B, k: int, *, method: str = "gaussian",
                       block: int = 1024, precision: Optional[str] = None,
                       tuning=None) -> SketchSummary:
    """Materialized projection operator + one dense contraction per matrix."""
    del block, tuning
    d = A.shape[0]
    P = projection_rows(key, jnp.arange(d), k, method=method, d_total=d)
    Ac, Bc = _cast(A, precision), _cast(B, precision)
    return SketchSummary(
        _sketch_dot(P, Ac, precision), _sketch_dot(P, Bc, precision),
        column_norms(Ac), column_norms(Bc))


@register_backend("rows")
def _rows_backend(key, A, B, k: int, *, method: str = "gaussian",
                  block: int = 1024, precision: Optional[str] = None,
                  tuning=None) -> SketchSummary:
    """Row-stream semantics over the full in-memory pair (rows 0..d-1)."""
    del block, tuning
    d = A.shape[0]
    return rows_summary(key, jnp.arange(d), A, B, k, method=method,
                        d_total=d, precision=precision)


@functools.partial(jax.jit, static_argnames=("k", "method", "d_total",
                                             "precision"))
def rows_summary(key: jax.Array, row_idx: jax.Array, A_rows: jax.Array,
                 B_rows: jax.Array, k: int, *, method: str = "gaussian",
                 d_total: Optional[int] = None, plan=None,
                 precision: Optional[str] = None) -> SketchSummary:
    """Arbitrary-order streaming: rows arrive as (index, A row, B row)
    triples; the result is independent of arrival order (a sum over rows).
    Partial streams combine with ``core.sketch.merge_summaries``. For
    ``method='srht'`` pass ``d_total`` (the global streamed dimension) — or,
    when summarizing many chunks, derive ``plan = srht_plan(key, d, k)[:2]``
    once and pass it per chunk to skip the repeated O(d) plan derivation."""
    P = projection_rows(key, row_idx, k, method=method, d_total=d_total,
                        plan=plan)
    Ac, Bc = _cast(A_rows, precision), _cast(B_rows, precision)
    return SketchSummary(
        _sketch_dot(P, Ac, precision), _sketch_dot(P, Bc, precision),
        column_norms(Ac), column_norms(Bc))


@register_backend("scan")
@functools.partial(jax.jit, static_argnames=("k", "method", "block",
                                             "precision", "tuning"))
def _scan_backend(key, A, B, k: int, *, method: str = "gaussian",
                  block: int = 1024, precision: Optional[str] = None,
                  tuning=None) -> SketchSummary:
    """Single ``lax.scan`` pass over row blocks; each block regenerates its
    projection slice from (key, global row ids) so the (k, d) operator never
    exists — the memory model of the paper's streaming pass and of the fused
    TPU kernel."""
    del tuning
    d, n1 = A.shape
    n2 = B.shape[1]
    pad = (-d) % block
    Ablk = jnp.pad(A, ((0, pad), (0, 0))).reshape(-1, block, n1)
    Bblk = jnp.pad(B, ((0, pad), (0, 0))).reshape(-1, block, n2)
    nblk = Ablk.shape[0]

    if method == "srht":
        signs, srows, _ = srht_plan(key, d, k)
        # pad-row signs are irrelevant (their data rows are zero)
        signs_blk = jnp.pad(signs, (0, pad), constant_values=1.0
                            ).reshape(nblk, block)
    else:
        signs_blk = jnp.ones((nblk, block), jnp.float32)
        srows = None

    def _body(carry, inputs):
        As, Bs, na2, nb2 = carry
        bi, Ab, Bb, sb = inputs
        gids = bi * block + jnp.arange(block)
        if method == "gaussian":
            P_b = pi_rows(key, gids, k)                         # (block, k)
        else:
            P_b = srht_rows_from_plan(sb, srows, gids, k)
        Ac, Bc = _cast(Ab, precision), _cast(Bb, precision)
        As = As + _sketch_dot(P_b, Ac, precision)
        Bs = Bs + _sketch_dot(P_b, Bc, precision)
        na2 = na2 + jnp.sum(Ac.astype(jnp.float32) ** 2, axis=0)
        nb2 = nb2 + jnp.sum(Bc.astype(jnp.float32) ** 2, axis=0)
        return (As, Bs, na2, nb2), None

    init = (jnp.zeros((k, n1), jnp.float32), jnp.zeros((k, n2), jnp.float32),
            jnp.zeros((n1,), jnp.float32), jnp.zeros((n2,), jnp.float32))
    (As, Bs, na2, nb2), _ = jax.lax.scan(
        _body, init, (jnp.arange(nblk), Ablk, Bblk, signs_blk))
    return SketchSummary(As, Bs, jnp.sqrt(na2), jnp.sqrt(nb2))


@register_backend("pallas")
def _pallas_backend(key, A, B, k: int, *, method: str = "gaussian",
                    block: int = 1024, precision: Optional[str] = None,
                    tuning=None) -> SketchSummary:
    """Kernel-backed pass: the fused sketch+norms kernel for gaussian, the
    blocked-FWHT MXU kernel (sign flip fused into its first stage) for srht.
    ``interpret`` is auto-detected from the platform inside kernels/ops.
    ``tuning`` (a ``TuningSpec``) pins kernel block configs; absent ones
    resolve via the committed tuning table / frozen defaults inside ops."""
    from repro.kernels import ops as kops
    del block
    cfg_sketch = tuning.config_for("sketch_fused") if tuning else None
    cfg_fwht = tuning.config_for("blocked_fwht") if tuning else None
    d = A.shape[0]
    if method == "gaussian":
        P = projection_rows(key, jnp.arange(d), k).T             # (k, d)
        As, na = kops.sketch_fused(P, A, precision=precision, config=cfg_sketch)
        Bs, nb = kops.sketch_fused(P, B, precision=precision, config=cfg_sketch)
        return SketchSummary(As, Bs, na, nb)
    if method == "srht":
        signs, rows, dp = srht_plan(key, d, k)
        signs_p = jnp.pad(signs, (0, dp - d), constant_values=1.0)

        def _one(X):
            # the FWHT kernel casts tiles to f32 in its body; feed the
            # (possibly reduced-precision) input straight in
            Xp = jnp.pad(_cast(X, precision), ((0, dp - d), (0, 0)))
            HX = kops.blocked_fwht(Xp, signs_p, config=cfg_fwht) / jnp.sqrt(dp)
            return HX[rows] * jnp.sqrt(dp / k)

        Ac, Bc = _cast(A, precision), _cast(B, precision)
        return SketchSummary(_one(A), _one(B), column_norms(Ac),
                             column_norms(Bc))
    raise ValueError(f"unknown sketch method {method!r} (use {METHODS})")


@register_backend("distributed")
def _distributed_backend(key, A, B, k: int, *, method: str = "gaussian",
                         block: int = 1024, precision: Optional[str] = None,
                         tuning=None, mesh=None, axis: Optional[str] = None
                         ) -> SketchSummary:
    """Row-sharded shard_map pass; requires ``mesh`` and ``axis`` kwargs."""
    del block, tuning
    if mesh is None or axis is None:
        raise ValueError("backend='distributed' needs mesh=... and axis=...")
    from repro.core.distributed import distributed_sketch_summary
    return distributed_sketch_summary(mesh, axis, key, A, B, k,
                                      method=method, precision=precision)


# ---------------------------------------------------------------------------
# The entry point
# ---------------------------------------------------------------------------

def _is_key_stack(key, L: int) -> bool:
    """True if ``key`` is a stack of L per-pair keys (raw (L, 2) uint32 or a
    (L,) typed-key array) rather than one key to split L ways."""
    ndim = jnp.ndim(key)
    if ndim == 2:
        return key.shape[0] == L
    if ndim == 1 and jax.dtypes.issubdtype(key.dtype, jax.dtypes.prng_key):
        return key.shape[0] == L
    return False


def build_summary(key: jax.Array, A: jax.Array, B: jax.Array, k: int, *,
                  method: str = "gaussian", backend: str = "reference",
                  block: int = 1024, precision: Optional[str] = None,
                  probes: int = 0, cosketch: int = 0, tuning=None, mesh=None,
                  axis: Optional[str] = None) -> SketchSummary:
    """One-pass summary of (A, B): sketches (k, n) + exact column norms.

    A: (d, n1), B: (d, n2) — or stacked (L, d, n1)/(L, d, n2) for the batched
    mode, which vmaps the chosen backend over the L pairs in one dispatch
    (``key`` is split per pair, or pass a stack of L keys).

    method:  'gaussian' (the paper's analyzed JL sketch) | 'srht'
    backend: one of ``backends()`` — identical (key, global row id) randomness
             across backends, so outputs agree to float reassociation.
    block:   row-block size for the scan backend.
    precision: None/'f32' | 'bf16' (bf16 inputs, f32 accumulation).
    probes:  retain this many held-out probe columns ``(A^T B) @ Omega``
             alongside the sketches (same single pass over the rows; the
             probe stage is backend-independent, so the probe block is
             bit-identical across backends for a fixed ``block``). Powers
             the ErrorEngine's ``estimate_error``/``adaptive_rank``.
    cosketch: retain an s-column Tropp range/co-range pair
             ``(A^T B) @ Omega_c`` / ``Psi_c @ (A^T B)`` alongside the
             sketches (same single pass; backend-independent attach like the
             probe block). Powers the RefinementEngine's sketch-power/Tropp
             refinement (``estimate_product(method='power')``).
    tuning:  optional ``repro.kernels.tuning.TuningSpec`` pinning kernel
             block configs (acted on by the pallas backend; layout-only, so
             results stay within float reassociation of the default).
    mesh/axis: required for backend='distributed' (rows sharded over axis).

    >>> import jax, jax.numpy as jnp
    >>> key = jax.random.PRNGKey(0)
    >>> A = jax.random.normal(key, (64, 8))
    >>> B = jax.random.normal(jax.random.fold_in(key, 1), (64, 6))
    >>> s = build_summary(key, A, B, 16, backend="scan", block=32)
    >>> (s.A_sketch.shape, s.B_sketch.shape, s.norm_A.shape, s.norm_B.shape)
    ((16, 8), (16, 6), (8,), (6,))
    >>> ref = build_summary(key, A, B, 16)          # reference backend
    >>> bool(jnp.allclose(s.A_sketch, ref.A_sketch, atol=1e-5))
    True
    """
    if method not in METHODS:
        raise ValueError(f"unknown sketch method {method!r} (use {METHODS})")
    if backend not in _BACKENDS:
        raise ValueError(
            f"unknown summary backend {backend!r} (use one of {backends()})")
    fn = _BACKENDS[backend]
    kw = dict(method=method, block=block, precision=precision, tuning=tuning)
    if backend == "distributed":
        kw.update(mesh=mesh, axis=axis)

    if A.ndim == 3:
        if B.ndim != 3 or A.shape[0] != B.shape[0]:
            raise ValueError(f"batched mode needs matching leading axes, got "
                             f"{A.shape} vs {B.shape}")
        if backend == "distributed":
            raise NotImplementedError(
                "batched mode is not supported for backend='distributed'")
        L = A.shape[0]
        keys = key if _is_key_stack(key, L) else jax.random.split(key, L)
        out = jax.vmap(lambda kk, a, b: fn(kk, a, b, k, **kw))(keys, A, B)
        if probes:
            from repro.core import error_engine
            out = jax.vmap(lambda kk, a, b, s: error_engine.attach_probes(
                s, kk, a, b, probes, block=block, precision=precision)
            )(keys, A, B, out)
        if cosketch:
            from repro.core import refinement
            out = jax.vmap(lambda kk, a, b, s: refinement.attach_cosketch(
                s, kk, a, b, cosketch, block=block, precision=precision)
            )(keys, A, B, out)
        return out
    out = fn(key, A, B, k, **kw)
    if probes:
        from repro.core import error_engine
        out = error_engine.attach_probes(out, key, A, B, probes, block=block,
                                         precision=precision)
    if cosketch:
        from repro.core import refinement
        out = refinement.attach_cosketch(out, key, A, B, cosketch,
                                         block=block, precision=precision)
    return out


def norms_only_summary(A: jax.Array, B: jax.Array) -> SketchSummary:
    """A ``SketchSummary`` with exact column norms and empty (0, n) sketches —
    LELA's first pass, all a norm-driven estimator (lela_waltmin) consumes."""
    norm_A = jnp.sqrt(jnp.sum(A.astype(jnp.float32) ** 2, axis=0))
    norm_B = jnp.sqrt(jnp.sum(B.astype(jnp.float32) ** 2, axis=0))
    return SketchSummary(jnp.zeros((0, A.shape[1]), jnp.float32),
                         jnp.zeros((0, B.shape[1]), jnp.float32),
                         norm_A, norm_B)


def summary_stage(spec, key: jax.Array, A: jax.Array, B: jax.Array,
                  tuning=None) -> SketchSummary:
    """The step-1 pass as a fusable stage driven by a declarative spec.

    ``spec`` is any object with the ``SketchSpec`` fields (method, backend,
    k, block, precision, probes, cosketch) — ``core.pipeline`` owns the
    concrete type;
    taking it duck-typed keeps this module import-free of the pipeline layer.
    Pure and traceable: the PipelineEngine composes it with the estimation
    and error stages inside ONE jitted executable. ``method='norms_only'``
    is the sketch-free LELA first pass (the key is unused). ``tuning``
    rides the plan (``PipelinePlan.tuning``), not the spec, so one spec
    hash serves every tuning.
    """
    if spec.method == "norms_only":
        return norms_only_summary(A, B)
    return build_summary(key, A, B, spec.k, method=spec.method,
                         backend=spec.backend, block=spec.block,
                         precision=spec.precision, probes=spec.probes,
                         cosketch=getattr(spec, "cosketch", 0),
                         tuning=tuning)


# ---------------------------------------------------------------------------
# Structured-product summaries (engine-owned; no caller builds these by hand)
# ---------------------------------------------------------------------------

def identity_product_summary(key: jax.Array, G: jax.Array, k: int, *,
                             axis: Optional[str] = None, n_workers: int = 1,
                             precision: Optional[str] = None) -> SketchSummary:
    """Summary of the structured product A^T B with A = vstack_w(I), i.e.
    G = sum_w G_w — the gradient-compression mapping. A's sketch is each
    worker's Pi slice itself and ||A_i|| = sqrt(W) analytically, so A is
    never materialized. G: (n1, n2) or stacked (L, n1, n2) (batched mode).

    Inside ``shard_map`` pass ``axis``: G is the worker-local summand and the
    psum over workers IS the paper's treeAggregate."""
    if G.ndim == 3:
        keys = (key if _is_key_stack(key, G.shape[0])
                else jax.random.split(key, G.shape[0]))
        return jax.vmap(
            lambda kk, g: identity_product_summary(
                kk, g, k, axis=axis, n_workers=n_workers, precision=precision)
        )(keys, G)
    n1, n2 = G.shape
    if axis is not None:
        pi_key = jax.random.fold_in(key, jax.lax.axis_index(axis))
    else:
        pi_key = key
    Gc = _cast(G, precision)
    # ONE operator for both sides: the (possibly precision-rounded) Pi that
    # contracts with G is also what A_sketch reports (A slice = I), keeping
    # the estimator's shared-Pi assumption intact under reduced precision
    Pi = _cast(gaussian_pi(pi_key, k, n1), precision).astype(Gc.dtype)
    A_sk = Pi.astype(jnp.float32)                               # A slice = I
    B_sk = jax.lax.dot_general(Pi, Gc,
                               dimension_numbers=(((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    nb2 = jnp.sum(Gc.astype(jnp.float32) ** 2, axis=0)
    if axis is not None:
        A_sk = jax.lax.psum(A_sk, axis)
        B_sk = jax.lax.psum(B_sk, axis)
        nb2 = jax.lax.psum(nb2, axis)
    return SketchSummary(
        A_sk, B_sk,
        jnp.full((n1,), jnp.sqrt(float(n_workers)), jnp.float32),
        jnp.sqrt(nb2))


def tap_pair_summary(key: jax.Array, X: jax.Array, Y: jax.Array, k: int, *,
                     precision: Optional[str] = None):
    """One-pass (Pi X, Pi Y, col-norms^2) over X, Y (T x n) for the gradient
    tap. Returns the raw tuple (As, Bs, na2, nb2) — taps carry squared norms
    so DP all-reduce stays a plain sum.

    Deliberately ONE fused contraction over the token dimension (not the
    scan backend): under pjit the T-sharded contraction emits exactly one
    (k x n) psum per output, where a scan-over-blocks makes GSPMD emit a
    partial all-reduce per block. Pi is (T, k), sharded like X, never stored."""
    T = X.shape[0]
    Pi = jax.random.normal(key, (T, k)) / jnp.sqrt(k)
    Xc, Yc = _cast(X, precision), _cast(Y, precision)
    As = _sketch_dot(Pi, Xc, precision)
    Bs = _sketch_dot(Pi, Yc, precision)
    na2 = jnp.sum(Xc.astype(jnp.float32) ** 2, axis=0)
    nb2 = jnp.sum(Yc.astype(jnp.float32) ** 2, axis=0)
    return As, Bs, na2, nb2
