"""Baselines the paper compares against (Figs 3(b), 4(b), 4(c); Table 1).

* ``optimal_rank_r`` — truncated SVD of the exact product (the "Optimal" rows).
* ``sketch_svd``     — SVD(A~^T B~): sketch both matrices, then top-r SVD of
  the product of the sketches *without materializing it* (power iteration, as
  footnote 6 prescribes). The straightforward one-pass idea SMP-PCA beats.
* ``product_of_pcas`` — A_r^T B_r (Fig 4(c) failure mode): rank-r PCA of each
  matrix separately, then multiply.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import pipeline
from repro.core.estimation_engine import implicit_topr as _implicit_topr
from repro.core.types import LowRankFactors


def optimal_rank_r(A: jax.Array, B: jax.Array, r: int) -> LowRankFactors:
    """Oracle: exact top-r SVD of the materialized product A^T B."""
    M = A.T @ B
    U, s, Vt = jnp.linalg.svd(M, full_matrices=False)
    return LowRankFactors(U[:, :r] * s[:r], Vt[:r].T)


def sketch_svd(key: jax.Array, A: jax.Array, B: jax.Array, *, r: int, k: int,
               method: str = "gaussian", backend: str = "reference",
               est_backend: str = "jit") -> LowRankFactors:
    """SVD(A~^T B~): the sketch + direct_svd plan preset executed through the
    compile-once PipelineEngine (one fused dispatch; historical split(key)
    layout preserved bit-for-bit)."""
    plan = pipeline.sketch_svd_plan(r=r, k=k, method=method, backend=backend,
                                    est_backend=est_backend)
    return pipeline.get_engine().run(plan, key, A, B).estimate.factors


@functools.partial(jax.jit, static_argnames=("r",))
def product_of_pcas(key: jax.Array, A: jax.Array, B: jax.Array,
                    r: int) -> LowRankFactors:
    """A_r^T B_r — what you get from two independent streaming-PCA runs."""
    kA, kB = jax.random.split(key)
    d, n1 = A.shape
    Ar = _implicit_topr(lambda X: A @ X, lambda X: A.T @ X, d, n1, r, kA)
    Br = _implicit_topr(lambda X: B @ X, lambda X: B.T @ X, d, B.shape[1], r, kB)
    # A_r = U_A S_A V_A^T -> A_r^T B_r = V_A S_A U_A^T U_B S_B V_B^T
    core = Ar.U.T @ Br.U                      # (r, r)
    return LowRankFactors(Ar.V @ core, Br.V)
