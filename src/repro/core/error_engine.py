"""ErrorEngine — a-posteriori sketch-quality estimation from held-out probes.

The paper's central idea is that retaining *extra* summary information beyond
the sketches (the exact column norms) buys a better estimate of A^T B. This
module pushes the same idea one step further, following Tropp et al.,
"Practical sketching algorithms for low-rank matrix approximation"
(1609.00048): retain ``p`` extra held-out probe columns

    probes = (A^T B) @ Omega,    Omega (n2, p) standard Gaussian,

accumulated in the same single pass (``probes = sum_rows A_row^T (B_row
Omega)`` — linear in the rows, so the probe block rides the existing
streaming/merge monoid unchanged), and use them *after* estimation to
measure how good the factors actually are:

* ``estimate_error(summary, factors)`` — for Gaussian ``w``,
  ``E ||(M - UV^T) w||^2 = ||M - UV^T||_F^2`` exactly, so the p probes give
  an unbiased Frobenius-residual estimate with a confidence interval, plus
  a spectral-norm proxy (``max_j ||R w_j|| / ||w_j||``, a lower-bound
  estimator of ``||R||_2``);
* ``adaptive_rank(summary, tol, r_max)`` — the smallest rank whose
  *estimated* relative error meets ``tol``. ONE factorization of the
  rescaled sketch product is computed and ONE probe projection is reused
  across every candidate rank (the per-rank error curve is a cumulative
  sum; the rank search runs over that precomputed host-side curve), never
  one factorization per candidate.

Randomness contract: ``Omega`` is a pure function of the summary key —
``normal(fold_in(fold_in(key, _PROBE_TAG_0), _PROBE_TAG_1), (n2, p))`` — a
two-level fold that cannot collide with the engine's single-level per-row
``fold_in(key, i)`` derivations, so every backend, every chunking, and every
merge order sees the *identical* held-out probes (golden-tested in
tests/core/test_key_contract.py).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import estimator
from repro.core.types import ErrorEstimate, LowRankFactors, SketchSummary

# "prob"/"e!" — the two-level fold that reserves the probe key subtree
_PROBE_TAG_0 = 0x70726F62
_PROBE_TAG_1 = 0x6521

_EPS = 1e-12

# 97.5% normal quantile: the default two-sided 95% confidence interval
_Z95 = 1.959964


# ---------------------------------------------------------------------------
# The probe block (single-pass accumulation primitives)
# ---------------------------------------------------------------------------

def probe_key(key: jax.Array) -> jax.Array:
    """The reserved probe subtree of the summary key (two-level fold)."""
    return jax.random.fold_in(jax.random.fold_in(key, _PROBE_TAG_0),
                              _PROBE_TAG_1)


def probe_omega(key: jax.Array, n2: int, p: int) -> jax.Array:
    """(n2, p) standard-Gaussian held-out probes — a pure function of the
    summary key, identical on every backend/chunking/merge order."""
    return jax.random.normal(probe_key(key), (n2, p))


def probe_contribution(omega: jax.Array, A_chunk: jax.Array,
                       B_chunk: jax.Array,
                       precision: Optional[str] = None) -> jax.Array:
    """One row chunk's probe-block summand: ``A_chunk^T (B_chunk @ omega)``.

    (t, n1)^T @ ((t, n2) @ (n2, p)) with f32 accumulation regardless of the
    input dtype — the exact float ops the streaming update and the one-shot
    probe pass share, which is what the bit-parity contract rests on.
    A zero-row chunk contributes exact zeros (the monoid identity).
    """
    from repro.core.summary_engine import _cast
    Ac, Bc = _cast(A_chunk, precision), _cast(B_chunk, precision)
    Bw = jax.lax.dot_general(Bc, _cast(omega, precision).astype(Bc.dtype),
                             dimension_numbers=(((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    return jax.lax.dot_general(Ac, Bw.astype(Ac.dtype),
                               dimension_numbers=(((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block", "precision"))
def probe_pass(omega: jax.Array, A: jax.Array, B: jax.Array, *,
               block: int = 1024,
               precision: Optional[str] = None) -> jax.Array:
    """(n1, p) probe block over the whole in-memory pair: a ``lax.scan``
    over row blocks mirroring the scan backend's block structure (zero-padded
    trailing block), so sequential streamed ingestion at chunk ``block`` is
    bit-identical to this one-shot pass."""
    d, n1 = A.shape
    n2 = B.shape[1]
    pad = (-d) % block
    Ablk = jnp.pad(A, ((0, pad), (0, 0))).reshape(-1, block, n1)
    Bblk = jnp.pad(B, ((0, pad), (0, 0))).reshape(-1, block, n2)

    def _body(acc, ab):
        Ab, Bb = ab
        return acc + probe_contribution(omega, Ab, Bb, precision), None

    init = jnp.zeros((n1, omega.shape[1]), jnp.float32)
    acc, _ = jax.lax.scan(_body, init, (Ablk, Bblk))
    return acc


def attach_probes(summary: SketchSummary, key: jax.Array, A: jax.Array,
                  B: jax.Array, p: int, *, block: int = 1024,
                  precision: Optional[str] = None) -> SketchSummary:
    """Retain ``p`` held-out probes on an existing summary (the backend-
    independent stage ``build_summary(..., probes=p)`` runs after dispatch)."""
    omega = probe_omega(key, B.shape[-1], p)
    return summary._replace(
        probes=probe_pass(omega, A, B, block=block, precision=precision),
        probe_omega=omega)


def merge_probes(a: Optional[jax.Array],
                 b: Optional[jax.Array]) -> Optional[jax.Array]:
    """Monoid combine of two probe blocks over disjoint row sets: a plain
    sum (commutative bit-for-bit). Presence must agree on both operands."""
    if (a is None) != (b is None):
        raise ValueError("cannot merge a probe-carrying summary with a "
                         "probe-free one (build both with the same probes=)")
    return None if a is None else a + b


# ---------------------------------------------------------------------------
# A-posteriori error estimation
# ---------------------------------------------------------------------------

def _require_probes(summary: SketchSummary) -> None:
    if summary.probes is None or summary.probe_omega is None:
        raise ValueError(
            "summary carries no probe block — build it with "
            "build_summary(..., probes=p) / StreamingSummarizer(probes=p) "
            "to enable a-posteriori error estimation")


def estimate_error(summary: SketchSummary, factors: LowRankFactors, *,
                   confidence: float = 0.95) -> ErrorEstimate:
    """Unbiased a-posteriori residual estimate of ``A^T B ~= U V^T``.

    Each held-out probe ``w_j`` (a column of ``summary.probe_omega``) yields
    one unbiased sample ``||probes_j - U (V^T w_j)||^2`` of the squared
    Frobenius residual; the estimate is the sample mean, the confidence
    interval a normal approximation over the p samples, and the spectral
    proxy ``max_j ||R w_j|| / ||w_j||`` (a lower-bound estimator of
    ``||R||_2``; ``||R||_F`` bounds it from above). Pure jnp — jit/vmap
    friendly, so batched serving estimates all requests in one dispatch.

    >>> import jax, jax.numpy as jnp
    >>> from repro.core.summary_engine import build_summary
    >>> from repro.core.estimation_engine import estimate_product
    >>> key = jax.random.PRNGKey(0)
    >>> A = jax.random.normal(key, (256, 20))
    >>> B = jax.random.normal(jax.random.fold_in(key, 1), (256, 16))
    >>> s = build_summary(key, A, B, 64, probes=16)     # retain 16 probes
    >>> s.probes.shape, s.probe_omega.shape
    ((20, 16), (16, 16))
    >>> res = estimate_product(jax.random.fold_in(key, 2), s, r=4, m=600, T=3)
    >>> err = estimate_error(s, res.factors)
    >>> true = float(jnp.linalg.norm(A.T @ B - res.factors.dense()))
    >>> bool(0.5 * true < float(err.frob_est) < 2.0 * true)
    True
    >>> bool(err.frob_lo <= err.frob_est <= err.frob_hi)
    True
    """
    _require_probes(summary)
    probes, omega = summary.probes, summary.probe_omega
    p = probes.shape[-1]
    resid = probes - factors.U @ (factors.V.T @ omega)        # (n1, p)
    sq = jnp.sum(resid.astype(jnp.float32) ** 2, axis=0)      # (p,) unbiased
    frob_sq = jnp.mean(sq)
    # normal-approximation CI over the p probe samples (sample std, ddof=1;
    # a single probe carries no width information — report an honest
    # [0, inf) interval instead of a spuriously zero-width one)
    z = _Z95 if confidence == 0.95 else float(
        jax.scipy.stats.norm.ppf(0.5 + confidence / 2.0))
    if p >= 2:
        stderr = jnp.std(sq, ddof=1) / jnp.sqrt(float(p))
    else:
        stderr = jnp.asarray(jnp.inf, jnp.float32)
    frob_lo = jnp.sqrt(jnp.maximum(frob_sq - z * stderr, 0.0))
    frob_hi = jnp.sqrt(frob_sq + z * stderr)
    w_norms = jnp.sqrt(jnp.sum(omega.astype(jnp.float32) ** 2, axis=0))
    spectral = jnp.max(jnp.sqrt(sq) / jnp.maximum(w_norms, _EPS))
    # ||A^T B||_F from the same probes (unbiased, same argument)
    m_frob = jnp.sqrt(jnp.mean(
        jnp.sum(probes.astype(jnp.float32) ** 2, axis=0)))
    frob = jnp.sqrt(frob_sq)
    return ErrorEstimate(frob, frob_sq, frob_lo, frob_hi, spectral,
                         frob / jnp.maximum(m_frob, _EPS))


def rank_curve(summary: SketchSummary, r_max: int,
               refine=None) -> jax.Array:
    """Estimated relative-error curve for every rank 1..r_max (fusable stage).

    ``curve[i]`` is the estimated relative Frobenius error of the rank-(i+1)
    truncation of the rescaled sketch product, measured against the held-out
    probe block — ONE SVD and ONE probe projection for the whole curve (the
    ``adaptive_rank`` sweep, exposed as a pure traceable stage). This is what
    the PipelineEngine's quality-gated serving path reads once per bucket
    instead of re-running an estimation dispatch per candidate rank.

    ``refine`` (a ``repro.core.refinement.RefineSpec``) swaps the curve's
    factorization source from the rescaled sketch product to the
    sketch-power/Tropp refined reconstruction (needs a co-sketch-carrying
    summary) — the probe-measurement math is unchanged because the refined
    left basis is orthonormal too.
    """
    _require_probes(summary)
    rel, _, _, _ = _rank_curve(summary, r_max, refine=refine)
    return rel


# ---------------------------------------------------------------------------
# Adaptive rank selection
# ---------------------------------------------------------------------------

class AdaptiveRankResult(NamedTuple):
    """``adaptive_rank`` output: the chosen rank, its truncated factors, the
    a-posteriori estimate at that rank, and the full estimated relative-error
    curve (index i = rank i+1) the search ran over."""

    r: int
    factors: LowRankFactors
    error: ErrorEstimate
    curve: jax.Array          # (r_max,) estimated relative Frobenius errors


@functools.partial(jax.jit, static_argnames=("r_max", "refine"))
def _rank_curve(summary: SketchSummary, r_max: int, refine=None):
    """One factorization, one probe projection, every candidate rank.

    SVDs the rescaled sketch product ``M~ = D_A (A~^T B~) D_B`` once —
    or, with ``refine``, the sketch-power/Tropp refined reconstruction
    (``refinement.refined_svd``; its left basis is orthonormal, which is
    all the identity below needs) — then evaluates the estimated squared
    residual of its rank-r truncation against the probe block for ALL r in
    1..r_max via cumulative sums: with ``c = U^T probes`` and
    ``Z = diag(s) V^T Omega``,

        errsq(r)_j = ||probes_j||^2 + sum_{i<r} (Z_ij^2 - 2 c_ij Z_ij).

    Returns (rel_curve (r_max,), U, s, Vt) — O(q^2 max(n1,n2) + q p) total,
    independent of how many ranks the search probes. The whole curve is
    forced to float32 (matrix, probes, and test columns are cast before the
    reductions): a reduced-precision summary must not leak its dtype into
    the gate — on float32 inputs every cast is a bitwise no-op.
    """
    probes = summary.probes.astype(jnp.float32)
    omega = summary.probe_omega.astype(jnp.float32)
    if refine is not None:
        from repro.core.refinement import refined_svd
        U, s, Vt = refined_svd(summary, refine, r_max)
    else:
        M = estimator.rescaled_matrix(summary).astype(jnp.float32)
        U, s, Vt = jnp.linalg.svd(M, full_matrices=False)
        U, s, Vt = U[:, :r_max], s[:r_max], Vt[:r_max]
    c = U.T @ probes                                   # (r_max, p)
    Z = s[:, None] * (Vt @ omega)                      # (r_max, p)
    base = jnp.sum(probes ** 2, axis=0)                # (p,)
    deltas = Z ** 2 - 2.0 * c * Z                      # (r_max, p)
    errsq = jnp.maximum(base[None, :] + jnp.cumsum(deltas, axis=0), 0.0)
    m_frob = jnp.sqrt(jnp.mean(base))
    rel = jnp.sqrt(jnp.mean(errsq, axis=1)) / jnp.maximum(m_frob, _EPS)
    return rel, U, s, Vt


def adaptive_rank(summary: SketchSummary, tol: float,
                  r_max: Optional[int] = None,
                  refine=None) -> AdaptiveRankResult:
    """Smallest rank whose *estimated* relative Frobenius error meets ``tol``.

    ``tol`` is relative: the gate is ``frob_est <= tol * ||A^T B||_F`` with
    both sides estimated from the probe block. The whole per-rank error
    curve comes from ONE factorization + ONE probe projection (cumulative
    sums), so the rank search is a scan over ``r_max`` host-side floats —
    probe noise can dent the curve's monotonicity near the noise floor, so
    an exact scan is used rather than a bisection that would silently
    return a non-minimal rank there. When no rank within ``r_max`` meets
    ``tol``, the result is ``r_max`` (callers inspect ``error.rel_est`` to
    see the gate missed). Host-level: returns a Python int rank and its
    truncated factors.

    ``refine`` (a ``repro.core.refinement.RefineSpec``) gates on the
    sketch-power/Tropp refined reconstruction instead of the raw rescaled
    sketch product (needs a co-sketch-carrying summary) — its curve sits
    below the unrefined one, so the gate passes at lower rank for the same
    ``tol``; candidate ranks are additionally capped by the co-sketch
    width s.

    >>> import jax, jax.numpy as jnp
    >>> from repro.core.summary_engine import build_summary
    >>> key = jax.random.PRNGKey(0)
    >>> W, _ = jnp.linalg.qr(jax.random.normal(key, (512, 12)))
    >>> M = (jax.random.normal(jax.random.fold_in(key, 1), (12, 10))
    ...      * jnp.array([10.0, 6.0, 0.1, 0.05, 0.02, 0.01, 0.005, 0.002,
    ...                   0.001, 0.0005])[None, :])
    >>> A, B = W, W @ M              # A^T B == M: rank ~2 + tiny tail
    >>> res = adaptive_rank(build_summary(key, A, B, 128, probes=24),
    ...                     tol=0.3, r_max=8)
    >>> (res.r, res.factors.U.shape, res.curve.shape)
    (2, (12, 2), (8,))
    >>> bool(res.error.rel_est <= 0.3)       # the chosen rank meets the gate
    True
    >>> bool(res.curve[res.r - 2] > 0.3)     # ... and is the smallest that does
    True
    """
    _require_probes(summary)
    q = min(summary.n1, summary.n2)
    if refine is not None:
        from repro.core.refinement import require_cosketch
        require_cosketch(summary)
        q = min(q, summary.n_cosketch)
    r_max = q if r_max is None else min(r_max, q)
    if r_max < 1:
        raise ValueError(f"r_max must be >= 1, got {r_max}")
    rel, U, s, Vt = _rank_curve(summary, r_max, refine=refine)
    curve = np.asarray(jax.device_get(rel))
    meets = np.flatnonzero(curve <= tol)
    r = int(meets[0]) + 1 if meets.size else int(curve.shape[0])
    factors = LowRankFactors(U[:, :r] * s[:r], Vt[:r].T)
    return AdaptiveRankResult(r, factors, estimate_error(summary, factors),
                              rel)
