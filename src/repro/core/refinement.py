"""RefinementEngine — sketch-power iterations + Tropp-style reconstruction.

The paper's single-pass guarantee is fixed by the retained sketch; two
PAPERS.md upgrades buy more accuracy per retained byte *without extra data
passes*:

* **Tropp et al. 1609.00048** (practical sketching): retain a second
  *co-sketch* block alongside the JL sketches — the range/co-range pair

      Y = (A^T B) @ Omega_c          (n1, s)   range sketch
      W = Psi_c @ (A^T B)            (l, n2)   co-range sketch, l = 2s + 1

  with ``Omega_c`` (n2, s) and ``Psi_c`` (l, n1) Gaussian test matrices
  derived from the summary key (``l = 2s + 1`` is Tropp's recommended
  co-range oversampling — it keeps the reconstruction least-squares
  overdetermined). Both blocks are **linear in the rows** of
  (A, B) — per row ``a_t (b_t^T Omega_c)`` and ``(Psi_c a_t) b_t^T`` — so
  they accumulate in the same single pass, ride the streaming monoid as
  plain sums, and psum across shards exactly like the sketches and probes.
  The stabilized reconstruction is Tropp's Algorithm 7:
  ``Q = qr(Y)``, ``X = (Psi_c Q)^+ W``, ``A^T B ~= Q X`` — the co-range
  block *corrects* the range estimate, so the factorization error tracks
  the true tail of A^T B instead of the sketch noise floor.

* **Chang & Yang** (sketch-power iterations): power-iteration accuracy
  without revisiting the data — subspace-iterate the retained range basis
  against the *rescaled sketch product* ``M~ = D_A (A~^T B~) D_B`` (the
  paper's estimator, already in the summary), warm-started from the exact
  ``Y``, then apply the same Tropp reconstruction from the refined basis.

``RefineSpec`` is the declarative knob: ``method='tropp'`` is the pure
(Y, W) reconstruction, ``method='power'`` prepends ``iters`` sketch-power
iterations. It is a hashable NamedTuple, so it joins ``PipelinePlan`` (and
therefore every executable cache key) and the jitted estimator cells'
static arguments — warm serving under a pinned refinement never re-traces.

Randomness contract: the test matrices are pure functions of the summary
key through the reserved two-level fold ``fold_in(fold_in(key, 0x63736B21),
0 | 1)`` ("csk!"; sub-index 0 = Omega_c, 1 = Psi_c) — the same scheme as
the probe ("prob"/"e!"), window ("wdw!") and tenant ("tnt!") folds, so the
co-sketch randomness can never collide with any per-row single fold and is
identical across backends, chunkings, and merge orders (golden-pinned in
tests/core/test_key_contract.py).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import estimator
from repro.core.types import LowRankFactors, SketchSummary

# "csk!" — the reserved fold tag for the co-sketch key subtree
_COSKETCH_TAG = 0x63736B21

#: sub-indices under the tag fold: Omega_c (range test) / Psi_c (co-range)
_OMEGA_SUB = 0
_PSI_SUB = 1

REFINE_METHODS = ("tropp", "power")


class RefineSpec(NamedTuple):
    """Declarative refinement stage: how to rebuild factors from the
    retained co-sketch block.

    ``method='tropp'`` — the stabilized (Y, W) reconstruction alone
    (``iters`` is ignored); ``method='power'`` — ``iters`` sketch-power
    subspace iterations against the rescaled sketch product first, then
    the same reconstruction from the refined basis. Hashable: joins
    ``PipelinePlan`` and the jitted estimator cells' static arguments.
    """

    iters: int = 0
    method: str = "tropp"


def validate_refine(refine: "RefineSpec") -> None:
    """Reject a malformed RefineSpec eagerly (before any trace)."""
    if not isinstance(refine, RefineSpec):
        raise TypeError(
            f"expected a RefineSpec, got {type(refine).__name__}")
    if refine.method not in REFINE_METHODS:
        raise ValueError(f"unknown refinement method {refine.method!r} "
                         f"(use one of {REFINE_METHODS})")
    if isinstance(refine.iters, bool) or not isinstance(refine.iters, int) \
            or refine.iters < 0:
        raise ValueError(
            f"RefineSpec.iters must be a non-negative int, "
            f"got {refine.iters!r}")


# ---------------------------------------------------------------------------
# The co-sketch block (single-pass accumulation primitives)
# ---------------------------------------------------------------------------

def cosketch_key(key: jax.Array) -> jax.Array:
    """The reserved co-sketch subtree of the summary key (the tag fold)."""
    return jax.random.fold_in(key, _COSKETCH_TAG)


def cosketch_omega(key: jax.Array, n2: int, s: int) -> jax.Array:
    """(n2, s) Gaussian range test matrix Omega_c — a pure function of the
    summary key, identical on every backend/chunking/merge order."""
    return jax.random.normal(
        jax.random.fold_in(cosketch_key(key), _OMEGA_SUB), (n2, s))


def cosketch_width(s: int) -> int:
    """Co-range rows l for a width-s range sketch: Tropp's l = 2s + 1.

    The stabilized reconstruction solves ``min_X ||(Psi_c Q) X - W||`` with
    ``Psi_c Q`` of shape (l, q <= s); l > s keeps that least-squares problem
    overdetermined and well-conditioned (a square system degenerates to an
    oblique projection whose error blows up with cond(Psi_c Q))."""
    return 2 * s + 1


def cosketch_psi(key: jax.Array, n1: int, s: int) -> jax.Array:
    """(l, n1) Gaussian co-range test matrix Psi_c with ``l =
    cosketch_width(s)`` — same key contract as ``cosketch_omega`` under the
    sibling sub-fold."""
    return jax.random.normal(
        jax.random.fold_in(cosketch_key(key), _PSI_SUB),
        (cosketch_width(s), n1))


def cosketch_contribution(omega: jax.Array, psi: jax.Array,
                          A_chunk: jax.Array, B_chunk: jax.Array,
                          precision: Optional[str] = None
                          ) -> Tuple[jax.Array, jax.Array]:
    """One row chunk's (dY, dW) co-sketch summands.

    ``dY = A_chunk^T (B_chunk @ Omega_c)`` (n1, s) and
    ``dW = (Psi_c @ A_chunk^T) B_chunk`` (l, n2), both with f32
    accumulation regardless of input dtype — the exact float ops the
    streaming update and the one-shot ``cosketch_pass`` share (the
    bit-parity contract). A zero-row chunk contributes exact zeros (the
    monoid identity).
    """
    from repro.core.summary_engine import _cast
    Ac, Bc = _cast(A_chunk, precision), _cast(B_chunk, precision)
    Bw = jax.lax.dot_general(Bc, _cast(omega, precision).astype(Bc.dtype),
                             dimension_numbers=(((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    dY = jax.lax.dot_general(Ac, Bw.astype(Ac.dtype),
                             dimension_numbers=(((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    pA = jax.lax.dot_general(_cast(psi, precision).astype(Ac.dtype), Ac,
                             dimension_numbers=(((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    dW = jax.lax.dot_general(pA.astype(Bc.dtype), Bc,
                             dimension_numbers=(((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    return dY, dW


@functools.partial(jax.jit, static_argnames=("block", "precision"))
def cosketch_pass(omega: jax.Array, psi: jax.Array, A: jax.Array,
                  B: jax.Array, *, block: int = 1024,
                  precision: Optional[str] = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """(Y, W) over the whole in-memory pair: a ``lax.scan`` over row blocks
    mirroring the scan backend's block structure (zero-padded trailing
    block), so sequential streamed ingestion at chunk ``block`` is
    bit-identical to this one-shot pass."""
    d, n1 = A.shape
    n2 = B.shape[1]
    s, l = omega.shape[1], psi.shape[0]
    pad = (-d) % block
    Ablk = jnp.pad(A, ((0, pad), (0, 0))).reshape(-1, block, n1)
    Bblk = jnp.pad(B, ((0, pad), (0, 0))).reshape(-1, block, n2)

    def _body(acc, ab):
        Ab, Bb = ab
        dY, dW = cosketch_contribution(omega, psi, Ab, Bb, precision)
        return (acc[0] + dY, acc[1] + dW), None

    init = (jnp.zeros((n1, s), jnp.float32), jnp.zeros((l, n2), jnp.float32))
    (Y, W), _ = jax.lax.scan(_body, init, (Ablk, Bblk))
    return Y, W


def attach_cosketch(summary: SketchSummary, key: jax.Array, A: jax.Array,
                    B: jax.Array, s: int, *, block: int = 1024,
                    precision: Optional[str] = None) -> SketchSummary:
    """Retain an s-column co-sketch block on an existing summary (the
    backend-independent stage ``build_summary(..., cosketch=s)`` runs after
    dispatch, exactly like the probe attach).

    >>> import jax
    >>> key = jax.random.PRNGKey(0)
    >>> A = jax.random.normal(key, (64, 6))
    >>> B = jax.random.normal(jax.random.fold_in(key, 1), (64, 4))
    >>> from repro.core.summary_engine import build_summary
    >>> s = build_summary(key, A, B, 8, cosketch=3)
    >>> (s.cosketch_Y.shape, s.cosketch_W.shape)    # W rows: l = 2s + 1
    ((6, 3), (7, 4))
    >>> (s.cosketch_omega.shape, s.cosketch_psi.shape)
    ((4, 3), (7, 6))
    """
    omega = cosketch_omega(key, B.shape[-1], s)
    psi = cosketch_psi(key, A.shape[-1], s)
    Y, W = cosketch_pass(omega, psi, A, B, block=block, precision=precision)
    return summary._replace(cosketch_Y=Y, cosketch_W=W,
                            cosketch_omega=omega, cosketch_psi=psi)


def merge_cosketch(a: Optional[jax.Array],
                   b: Optional[jax.Array]) -> Optional[jax.Array]:
    """Monoid combine of two co-sketch blocks (Y with Y, W with W) over
    disjoint row sets: a plain sum (commutative bit-for-bit). Presence
    must agree on both operands."""
    if (a is None) != (b is None):
        raise ValueError(
            "cannot merge a cosketch-carrying summary with a cosketch-free "
            "one (build both with the same cosketch=)")
    return None if a is None else a + b


def require_cosketch(summary: SketchSummary) -> None:
    """Reject summaries without the retained (Y, W) pair."""
    if summary.cosketch_Y is None or summary.cosketch_W is None or \
            summary.cosketch_psi is None:
        raise ValueError(
            "summary carries no co-sketch block — build it with "
            "build_summary(..., cosketch=s) / StreamingSummarizer(cosketch="
            "s) to enable sketch-power/Tropp refinement "
            "(estimate_product(method='power') / rank_curve(refine=...))")


# ---------------------------------------------------------------------------
# Refined factorization
# ---------------------------------------------------------------------------

def refined_svd(summary: SketchSummary, refine: RefineSpec, r_max: int
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """(U, s, Vt) of the Tropp-stabilized reconstruction, truncated to
    ``r_max`` — the refined drop-in for ``svd(rescaled_matrix(summary))``.

    ``method='tropp'``: ``Q = qr(Y)``, ``X = (Psi_c Q)^+ W`` (least
    squares), SVD(X) rotated back through Q. ``method='power'``: the basis
    is first subspace-iterated ``iters`` times against the rescaled sketch
    product ``M~`` (QR re-orthonormalization each step; no data pass —
    everything lives in the retained summary), then reconstructed the same
    way. All in float32: the curve/gate downstream must not inherit a
    low-precision summary dtype. Pure jnp — jit/vmap friendly.
    """
    Y = summary.cosketch_Y.astype(jnp.float32)
    W = summary.cosketch_W.astype(jnp.float32)
    psi = summary.cosketch_psi.astype(jnp.float32)
    Q, _ = jnp.linalg.qr(Y)
    if refine.method == "power" and refine.iters > 0:
        M = estimator.rescaled_matrix(summary).astype(jnp.float32)
        for _ in range(refine.iters):          # iters is static (RefineSpec)
            Q, _ = jnp.linalg.qr(M @ (M.T @ Q))
    X = jnp.linalg.lstsq(psi @ Q, W)[0]        # (q, n2) stabilized co-range
    Ub, sv, Vt = jnp.linalg.svd(X, full_matrices=False)
    U = Q @ Ub
    return U[:, :r_max], sv[:r_max], Vt[:r_max]


def refine_factors(summary: SketchSummary, r: int,
                   refine: RefineSpec) -> LowRankFactors:
    """Rank-r factors of A^T B from the refined reconstruction.

    >>> import jax, jax.numpy as jnp
    >>> from repro.core.summary_engine import build_summary
    >>> key = jax.random.PRNGKey(0)
    >>> W0, _ = jnp.linalg.qr(jax.random.normal(key, (256, 10)))
    >>> M = jax.random.normal(jax.random.fold_in(key, 1), (10, 8))
    >>> A, B = W0, W0 @ M                       # A^T B == M exactly
    >>> s = build_summary(key, A, B, 32, cosketch=8)
    >>> f = refine_factors(s, 3, RefineSpec(iters=1, method='power'))
    >>> (f.U.shape, f.V.shape)
    ((10, 3), (8, 3))
    """
    require_cosketch(summary)
    U, sv, Vt = refined_svd(summary, refine, r)
    return LowRankFactors(U * sv, Vt.T)
