"""Gradient-tap dense layer: the paper's single-pass product sketch applied
to the *true* factored form of the weight gradient.

For a dense layer y = x W, autodiff gives dW = X^T dY with X (T x n_in),
dY (T x n_out), T = tokens — exactly the paper's A^T B with the huge streamed
dimension d = T. Stable ranks of activations/cotangents are far below T, so
the paper's bounds bite at small sketch k (unlike the A=I mapping used by the
grads-level baseline in optim.grad_compression, whose A has stable rank n_in
— that contrast is benchmarked in benchmarks/grad_compression.py).

Mechanics (jit/pjit-pure, no side channels):
  * the layer's params carry zero-initialized *tap* leaves
    {a: (k, n_in), b: (k, n_out), na2: (n_in,), nb2: (n_out,)};
  * a custom_vjp writes the one-pass summary of (X, dY) into the taps'
    cotangents and `zeros` into W's cotangent — the sketches ride the
    ordinary grads pytree, so DP all-reduce / GSPMD contraction over the
    token dimension aggregates them exactly like the paper's treeAggregate
    (sketches and squared norms are linear/additive over row shards);
  * the optimizer-side ``decompress_tapped_grads`` runs the same-seeded
    SMP-PCA completion to materialize the rank-r dW on every worker.

Under pjit the contraction Pi @ X over the sharded token dimension becomes a
(k x n_in)-sized all-reduce instead of the (n_in x n_out) gradient
all-reduce: communication drops by ~ n_out / k per layer with zero extra
passes over activations (the sketch is computed from the same X/dY tiles the
backward matmul would have read — the paper's one-pass principle).
"""
from __future__ import annotations

import functools
from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import streaming
from repro.core.smppca import smppca_from_summary
from repro.core.summary_engine import tap_pair_summary


class TapConfig(NamedTuple):
    sketch_k: int = 64
    rank: int = 8
    sample_factor: int = 8
    als_iters: int = 4
    block: int = 2048           # streaming block for the Pi generation


def tap_init(n_in: int, n_out: int, k: int) -> Dict[str, jax.Array]:
    return {"a": jnp.zeros((k, n_in), jnp.float32),
            "b": jnp.zeros((k, n_out), jnp.float32),
            "na2": jnp.zeros((n_in,), jnp.float32),
            "nb2": jnp.zeros((n_out,), jnp.float32)}


def _sketch_pair(key, X, Y, k, block):
    """One-pass (Pi X, Pi Y, col-norms^2) over X, Y (T x n) — delegated to
    the SummaryEngine's tap path (``tap_pair_summary``), which keeps the
    single fused contraction over the token dimension: under pjit the
    T-sharded contraction produces exactly ONE (k x n) psum per output.
    (The original scan-over-blocks variant made GSPMD emit a partial
    all-reduce per block — the C1 refutation in EXPERIMENTS.md §Perf.)"""
    del block
    return tap_pair_summary(key, X, Y, k)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def sketched_dense(w, taps, x, key, k: int = 64, block: int = 2048):
    """y = x @ w; the backward pass emits sketch taps instead of dW."""
    del taps, key
    return jax.lax.dot_general(
        x, w.astype(x.dtype), (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _fwd(w, taps, x, key, k, block):
    y = jax.lax.dot_general(
        x, w.astype(x.dtype), (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    return y, (w, x, key)


def _bwd(k, block, res, gy):
    w, x, key = res
    n_in, n_out = w.shape
    dx = jax.lax.dot_general(
        gy.astype(x.dtype), w.astype(x.dtype),
        (((gy.ndim - 1,), (1,)), ((), ()))).astype(x.dtype)
    X2 = x.reshape(-1, n_in).astype(jnp.float32)
    G2 = gy.reshape(-1, n_out).astype(jnp.float32)
    a, b, na2, nb2 = _sketch_pair(key, X2, G2, k, block)
    dw = jnp.zeros_like(w)          # never materialized/communicated
    dtaps = {"a": a, "b": b, "na2": na2, "nb2": nb2}
    return dw, dtaps, dx, None


sketched_dense.defvjp(_fwd, _bwd)


def tap_state(tap_grads: Dict[str, jax.Array]) -> streaming.StreamState:
    """View a tap-grads dict as a ``streaming.StreamState`` partial summary.

    The taps ARE a stream state over token chunks: {a, b} are the running
    sketches, {na2, nb2} the running *squared* norms — exactly the mergeable
    accumulator layout (squared norms so the DP all-reduce stays a plain
    sum). The Pi here is the tap path's own (fused, per-call) draw rather
    than the per-global-row fold_in — token ids are not globally meaningful
    across microbatches — so the state carries no key/plan; it can be merged
    and finalized, not updated further.
    """
    na2 = jnp.maximum(tap_grads["na2"], 0.0)
    nb2 = jnp.maximum(tap_grads["nb2"], 0.0)
    return streaming.StreamState(
        key=None, A_acc=tap_grads["a"], B_acc=tap_grads["b"],
        na2=na2, nb2=nb2, rows_seen=jnp.zeros((), jnp.int32),
        row_high=jnp.zeros((), jnp.int32),
        d_total=jnp.asarray(-1, jnp.int32), signs=None, srows=None)


def accumulate_taps(t1: Dict[str, jax.Array],
                    t2: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    """Merge tap grads from two microbatches (gradient accumulation).

    Delegates to ``streaming.merge_states`` — the same commutative monoid
    the chunked ingestion and the distributed tree-reduce use, so
    accumulate-then-decompress equals decompressing the concatenated-token
    summary.
    """
    m = streaming.merge_states(tap_state(t1), tap_state(t2))
    return {"a": m.A_acc, "b": m.B_acc, "na2": m.na2, "nb2": m.nb2}


def decompress_tap(key: jax.Array, tap_grads: Dict[str, jax.Array],
                   cfg: TapConfig) -> jax.Array:
    """Same-seeded SMP-PCA completion of the tapped summary -> rank-r dW."""
    summary = streaming.finalize_state(tap_state(tap_grads))
    n1, n2 = summary.n1, summary.n2
    m = int(cfg.sample_factor * (n1 + n2) * cfg.rank)
    res = smppca_from_summary(key, summary, r=cfg.rank, m=m, T=cfg.als_iters)
    return res.factors.U @ res.factors.V.T


def decompress_tapped_grads(key: jax.Array, grads, cfg: TapConfig):
    """Walk a grads pytree; wherever a dict holds {'w', 'taps'}, replace the
    zero dW with the SMP-PCA reconstruction and zero out the tap grads."""
    def walk(subkey, node):
        if isinstance(node, dict) and "taps" in node and "w" in node:
            node = dict(node)
            a = node["taps"]["a"]
            if a.ndim == 3:      # scan-stacked layer group: vmap over layers
                keys = jax.random.split(subkey, a.shape[0])
                recon = jax.vmap(lambda kk, tg: decompress_tap(kk, tg, cfg))(
                    keys, node["taps"])
            else:
                recon = decompress_tap(subkey, node["taps"], cfg)
            node["w"] = recon.astype(node["w"].dtype)
            node["taps"] = jax.tree.map(jnp.zeros_like, node["taps"])
            return node
        if isinstance(node, dict):
            return {kk: walk(jax.random.fold_in(subkey, i), vv)
                    for i, (kk, vv) in enumerate(sorted(node.items()))}
        if isinstance(node, (list, tuple)):
            walked = [walk(jax.random.fold_in(subkey, i), vv)
                      for i, vv in enumerate(node)]
            return type(node)(walked)
        return node
    return walk(key, grads)
