"""Training step: microbatched gradient accumulation, optional SMP-PCA
gradient compression (tap path or A=I baseline path), AdamW update.

The microbatch loop is a lax.scan, so with tap-compression enabled the
sketch taps ACCUMULATE across microbatches — the one-pass streaming claim of
the paper applied to gradient accumulation (the full dW never exists)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.optim import grad_compression as gc
from repro.optim.adamw import AdamW, AdamWState, global_norm
from repro.train import sketched_dense as sd


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    compression: str = "none"          # none | lowrank | taps
    comp_cfg: gc.CompressionConfig = gc.CompressionConfig()
    tap_cfg: sd.TapConfig = sd.TapConfig()
    dp_axis: Optional[str] = None      # set inside shard_map DP training
    n_workers: int = 1


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    comp: Any                          # gc.CompressionState or ()
    step: jax.Array
    key: jax.Array


def init_state(key: jax.Array, params, optimizer: AdamW,
               tcfg: TrainConfig) -> TrainState:
    comp = ()
    if tcfg.compression == "lowrank":
        comp = gc.init_state(params)
    return TrainState(params, optimizer.init(params), comp,
                      jnp.zeros((), jnp.int32), key)


def _split_microbatches(batch: Dict[str, jax.Array], n: int):
    def sp(x):
        B = x.shape[0]
        assert B % n == 0, (B, n)
        return x.reshape(n, B // n, *x.shape[1:])
    return jax.tree.map(sp, batch)


def make_train_step(loss_fn: Callable, optimizer: AdamW, tcfg: TrainConfig):
    """loss_fn(params, microbatch) -> scalar. Returns jit-able step fn."""

    def train_step(state: TrainState, batch) -> tuple[TrainState, Dict]:
        mbs = _split_microbatches(batch, tcfg.microbatches)

        def mb_body(carry, mb):
            gsum, lsum = carry
            loss, grads = jax.value_and_grad(loss_fn)(state.params, mb)
            gsum = jax.tree.map(jnp.add, gsum, grads)
            return (gsum, lsum + loss), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             state.params)
        (gsum, lsum), _ = jax.lax.scan(mb_body, (zeros, jnp.float32(0.0)), mbs)
        grads = jax.tree.map(lambda g: g / tcfg.microbatches, gsum)
        loss = lsum / tcfg.microbatches

        key_step = jax.random.fold_in(state.key, state.step)
        comp_state = state.comp
        stats: Dict[str, Any] = {}
        if tcfg.compression == "lowrank":
            grads, comp_state, stats = gc.compress_grads(
                key_step, grads, state.comp, tcfg.comp_cfg,
                axis=tcfg.dp_axis, n_workers=tcfg.n_workers)
        elif tcfg.compression == "taps":
            grads = sd.decompress_tapped_grads(key_step, grads, tcfg.tap_cfg)
        elif tcfg.dp_axis is not None:
            grads = jax.lax.pmean(grads, tcfg.dp_axis)

        gnorm = global_norm(grads)
        params, opt = optimizer.update(grads, state.opt, state.params)
        new_state = TrainState(params, opt, comp_state, state.step + 1,
                               state.key)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "lr": optimizer._lr(opt.step), **stats}
        return new_state, metrics

    return train_step
