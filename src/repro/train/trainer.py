"""Training loop with production fault-tolerance mechanics:

* checkpoint/restart (atomic, keep-N, resume from latest on boot),
* failure recovery: a step exception rolls back to the last checkpoint and
  replays (the data pipeline is a pure function of step, so replay is exact),
* straggler watchdog: per-step wall time vs. a running median; slow steps are
  logged (on real fleets this feeds the coordinator's preemption logic; the
  interface is the same here),
* deterministic skip-ahead: resuming at step k consumes batch(k) directly.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.ckpt import checkpoint
from repro.optim.adamw import AdamW
from repro.train import train_step as ts

log = logging.getLogger("repro.trainer")


@dataclasses.dataclass
class TrainerConfig:
    num_steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    max_retries: int = 2


class Trainer:
    def __init__(self, loss_fn: Callable, optimizer: AdamW,
                 data, tcfg: ts.TrainConfig, cfg: TrainerConfig,
                 init_params_fn: Callable[[jax.Array], Any],
                 seed: int = 0):
        self.loss_fn = loss_fn
        self.optimizer = optimizer
        self.data = data
        self.tcfg = tcfg
        self.cfg = cfg
        self.init_params_fn = init_params_fn
        self.seed = seed
        self.step_fn = jax.jit(ts.make_train_step(loss_fn, optimizer, tcfg))
        self.metrics_history: List[Dict] = []
        self.straggler_events: List[int] = []

    # ------------------------------------------------------------------
    def _fresh_state(self) -> ts.TrainState:
        key = jax.random.PRNGKey(self.seed)
        params = self.init_params_fn(jax.random.fold_in(key, 1))
        return ts.init_state(jax.random.fold_in(key, 2), params,
                             self.optimizer, self.tcfg)

    def _restore_or_init(self) -> ts.TrainState:
        state = self._fresh_state()
        if self.cfg.ckpt_dir and checkpoint.latest_step(self.cfg.ckpt_dir) is not None:
            state = checkpoint.restore(self.cfg.ckpt_dir, state)
            log.info("restored checkpoint at step %d", int(state.step))
        return state

    # ------------------------------------------------------------------
    def run(self, fault_hook: Optional[Callable[[int], None]] = None
            ) -> ts.TrainState:
        """fault_hook(step): test hook that may raise to simulate node
        failure; the trainer recovers from the last checkpoint."""
        state = self._restore_or_init()
        retries = 0
        times: List[float] = []
        step = int(state.step)
        while step < self.cfg.num_steps:
            batch = self.data.batch(step)
            t0 = time.monotonic()
            try:
                if fault_hook is not None:
                    fault_hook(step)
                state, metrics = self.step_fn(state, batch)
                metrics = {k: float(v) for k, v in metrics.items()
                           if np.ndim(v) == 0}
            except Exception as e:  # noqa: BLE001 — node-failure recovery
                retries += 1
                if retries > self.cfg.max_retries:
                    raise
                log.warning("step %d failed (%s); restoring last checkpoint",
                            step, e)
                state = self._restore_or_init()
                step = int(state.step)
                continue
            dt = time.monotonic() - t0
            times.append(dt)
            med = float(np.median(times[-20:]))
            if len(times) > 5 and dt > self.cfg.straggler_factor * med:
                self.straggler_events.append(step)
                log.warning("straggler: step %d took %.3fs (median %.3fs)",
                            step, dt, med)
            if step % self.cfg.log_every == 0:
                log.info("step %d: %s", step, metrics)
            self.metrics_history.append({"step": step, **metrics})
            step += 1
            if (self.cfg.ckpt_dir and step % self.cfg.ckpt_every == 0):
                checkpoint.save(self.cfg.ckpt_dir, step, state,
                                keep=self.cfg.keep)
        if self.cfg.ckpt_dir:
            checkpoint.save(self.cfg.ckpt_dir, step, state, keep=self.cfg.keep)
        return state
