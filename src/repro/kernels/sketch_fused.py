"""Fused sketch kernel: A_sketch = Pi @ A  AND  column norms, one HBM pass.

The paper's step 1 reads the data once and produces both the sketch and the
column-norm side information. On TPU the analogous resource is HBM->VMEM
traffic: this kernel streams each (bd, bn) tile of A into VMEM exactly once
and feeds it to (a) the MXU for the sketch matmul and (b) the VPU for the
squared-column-norm accumulation.

Design (TPU v5e):
  * The sketch dimension k is small by construction (that is the point of
    sketching), so the whole (k, bn) output tile stays resident in VMEM for
    the entire d-loop: grid = (n/bn, d/bd) with d innermost -> A is read from
    HBM exactly once, the output is flushed exactly once per n-tile.
  * Block shapes are MXU-aligned (multiples of 8 x 128 for f32); the matmul
    contracts over bd with preferred_element_type=f32 so bf16 inputs hit the
    MXU at full rate with f32 accumulation.
  * Column norms ride the same pass: a (1, bn) f32 row accumulated on the VPU.

VMEM budget per grid step: k*bd (Pi tile) + bd*bn (A tile) + k*bn (out) +
bn (norms) floats. Defaults (k<=2048, bd=512, bn=256) stay under ~4.5 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(pi_ref, a_ref, out_ref, norm_ref):
    di = pl.program_id(1)

    @pl.when(di == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)
        norm_ref[...] = jnp.zeros_like(norm_ref)

    a_tile = a_ref[...]
    out_ref[...] += jax.lax.dot_general(
        pi_ref[...], a_tile,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    norm_ref[...] += jnp.sum(
        a_tile.astype(jnp.float32) ** 2, axis=0, keepdims=True)


@functools.partial(jax.jit,
                   static_argnames=("bn", "bd", "interpret", "precision"))
def sketch_fused(Pi: jax.Array, A: jax.Array, *, bn: int = 256, bd: int = 512,
                 interpret: bool | None = None,
                 precision: str | None = None) -> tuple[jax.Array, jax.Array]:
    """Returns (Pi @ A as f32, squared column norms of A as f32 (n,)).

    Pi: (k, d), A: (d, n). d must divide by bd and n by bn (callers pad; the
    ops.py wrapper handles padding for arbitrary shapes).

    ``interpret=None`` auto-detects from the platform (one policy for all
    kernels: ``kernels.ops._interpret`` — compiled on TPU, interpreted
    elsewhere). ``precision='bf16'`` feeds bf16 tiles to the MXU; both
    outputs still accumulate in f32 (``preferred_element_type`` / VPU cast
    in the body).
    """
    if interpret is None:
        from repro.kernels.ops import _interpret
        interpret = _interpret()
    if precision == "bf16":
        Pi = Pi.astype(jnp.bfloat16)
        A = A.astype(jnp.bfloat16)
    elif precision not in (None, "f32"):
        raise ValueError(f"unknown precision {precision!r} (None|'f32'|'bf16')")
    k, d = Pi.shape
    d2, n = A.shape
    if d != d2:
        raise ValueError(f"sketch_fused: Pi {Pi.shape} and A {A.shape} "
                         f"disagree on d ({d} != {d2})")
    if d % bd or n % bn:
        raise ValueError(f"sketch_fused: shape (d={d}, n={n}) not divisible "
                         f"by blocks (bd={bd}, bn={bn}); pad first "
                         f"(kernels.ops.sketch_fused does this)")

    grid = (n // bn, d // bd)
    out, norm2 = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k, bd), lambda ni, di: (0, di)),   # Pi tile
            pl.BlockSpec((bd, bn), lambda ni, di: (di, ni)),  # A tile (1 read)
        ],
        out_specs=[
            pl.BlockSpec((k, bn), lambda ni, di: (0, ni)),    # sketch tile
            pl.BlockSpec((1, bn), lambda ni, di: (0, ni)),    # norms row
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, n), jnp.float32),
            jax.ShapeDtypeStruct((1, n), jnp.float32),
        ],
        interpret=interpret,
    )(Pi, A)
    return out, norm2[0]
