"""Tunable kernel configurations + a roofline-seeded autotuner.

Every Pallas kernel in this package used to ship hard-coded block shapes
(``bn=256, bd=512`` in ``sketch_fused``, ``b=128`` in ``hadamard``,
``bq=128`` in the flash-attention wrapper). This module makes those knobs
first-class:

* ``KernelConfig`` — a hashable description of one kernel's layout knobs
  (block sizes, grid traversal order, input precision). Hashability is the
  point: a config can ride a ``PipelinePlan`` and key the compile-once
  executable cache, so warm repeat-shape traffic under a pinned config
  never re-traces.
* ``candidate_configs`` — enumerate the legal configs for a kernel at a
  concrete shape, under the MXU-alignment constraints (last block dim a
  multiple of 128, sublane a multiple of 8) and the per-step VMEM budget
  documented in each kernel's header.
* ``roofline_cost`` / ``rank_candidates`` — a static cost model in the
  terms of ``repro.roofline.analysis`` (HBM bytes moved per call at
  ``HBM_BW``, MXU flops at ``PEAK_FLOPS`` derated by 128x128 tile
  occupancy, plus a per-grid-step overhead) so interpret-mode CPU runs
  still produce a meaningful, deterministic ranking.
* ``autotune`` — optionally measure the top-N ranked candidates on the
  real backend and persist winners to a versioned JSON ``TuningTable``
  (``kernels/tunings/<backend>.json``) keyed by
  ``(kernel, pow2 shape bucket, dtype)``.
* ``lookup`` — the resolution every ``kernels.ops`` wrapper uses when no
  explicit config is passed: tuning-table hit for the shape bucket, else
  the frozen ``DEFAULTS`` (bit-identical to the historical hard-coded
  values).

The tuner never changes numerics beyond float reassociation: it only
enumerates layout knobs (blocks, grid order). ``precision`` is carried on
the config so a pinned config fully determines the kernel call, but
candidates always inherit the caller's precision rather than sweeping it.

>>> from repro.kernels import tuning
>>> tuning.lookup("sketch_fused", (64, 1024, 256)).block   # table miss ->
(256, 512)
>>> cands = tuning.candidate_configs("sketch_fused", (64, 1024, 256))
>>> all(tuning.vmem_bytes(c, (64, 1024, 256)) <= tuning.VMEM_BUDGET_BYTES
...     for c in cands)
True
>>> best = tuning.rank_candidates("sketch_fused", (64, 1024, 256))[0]
>>> best == tuning.rank_candidates("sketch_fused", (64, 1024, 256))[0]
True
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, NamedTuple, Optional, Tuple

from repro.roofline.analysis import HBM_BW, PEAK_FLOPS, kernel_time_lb

#: Per-step VMEM working-set budget (bytes). The v5e core has ~16 MB of
#: VMEM; 12 MB leaves headroom for Mosaic spills and semaphores. Streamed
#: input tiles are counted twice (double-buffered by the grid pipeline),
#: resident outputs once.
VMEM_BUDGET_BYTES = 12 * 2 ** 20

#: Fixed cost charged per grid step in the static model — breaks
#: bandwidth ties toward larger tiles (fewer steps) the way real grid
#: dispatch overhead does.
STEP_OVERHEAD_S = 5e-7

LANE = 128      # last block dim granularity (all dtypes)
SUBLANE = 8     # second-to-last granularity for f32

#: Kernel name -> canonical shape tuple documented per kernel:
#:   sketch_fused     (k, d, n)       Pi: (k, d), A: (d, n)
#:   blocked_fwht     (d, n)          X: (d, n), d a power of two
#:   sampled_dot      (n1, n2, k, m)  row sketches + m sampled pairs
#:   flash_attention  (BH, S, Dh)     folded heads x sequence x head dim
KERNELS = ("sketch_fused", "blocked_fwht", "sampled_dot", "flash_attention")

#: Legal grid traversal orders per kernel (None = the kernel's default).
#: ``sketch_fused`` admits only its default: the d-loop MUST stay
#: innermost so the revisited (k, bn) output block is accumulated over
#: consecutive grid steps (Pallas TPU only guarantees revisit-in-place
#: for consecutive steps). ``blocked_fwht`` stage 1 has no revisited
#: output, so either loop may be inner.
GRID_ORDERS: Dict[str, Tuple[str, ...]] = {
    "sketch_fused": ("d_inner",),
    "blocked_fwht": ("n_inner", "p_inner"),
    "sampled_dot": (),
    "flash_attention": ("k_inner",),
}


class KernelConfig(NamedTuple):
    """One kernel's layout knobs as a hashable value.

    ``block`` is kernel-specific (see ``DEFAULTS``): ``(bn, bd)`` for
    ``sketch_fused``, ``(b, bn)`` for ``blocked_fwht``, ``()`` for
    ``sampled_dot`` (its grid is per-sample), ``(bq, bk)`` for
    ``flash_attention``. ``grid_order=None`` means the kernel's default
    traversal; ``precision`` mirrors the engine-wide None|'f32'|'bf16'
    policy (inputs cast, accumulation always f32).
    """

    kernel: str
    block: Tuple[int, ...] = ()
    grid_order: Optional[str] = None
    precision: Optional[str] = None

    def tag(self) -> str:
        """Stable short label for bench records and table entries."""
        parts = [f"b{'x'.join(str(b) for b in self.block)}" if self.block
                 else "scalar"]
        if self.grid_order:
            parts.append(self.grid_order)
        if self.precision:
            parts.append(self.precision)
        return "_".join(parts)


#: The frozen historical defaults — ``lookup`` falls back to these on a
#: table miss, which is what keeps default-config results bit-identical
#: to the pre-tuning hard-coded kernels.
DEFAULTS: Dict[str, KernelConfig] = {
    "sketch_fused": KernelConfig("sketch_fused", (256, 512)),
    "blocked_fwht": KernelConfig("blocked_fwht", (128, 256)),
    "sampled_dot": KernelConfig("sampled_dot", ()),
    "flash_attention": KernelConfig("flash_attention", (128, 128)),
}

_BLOCK_ARITY = {"sketch_fused": 2, "blocked_fwht": 2, "sampled_dot": 0,
                "flash_attention": 2}


class TuningSpec(NamedTuple):
    """A hashable bundle of per-kernel configs — the ``PipelinePlan``
    field. ``configs`` holds at most one config per kernel name;
    ``config_for`` returns it (or None, meaning table lookup/defaults).

    >>> from repro.kernels.tuning import KernelConfig, TuningSpec
    >>> ts = TuningSpec((KernelConfig("sketch_fused", (128, 256)),))
    >>> ts.config_for("sketch_fused").block
    (128, 256)
    >>> ts.config_for("blocked_fwht") is None
    True
    """

    configs: Tuple[KernelConfig, ...] = ()

    def config_for(self, kernel: str) -> Optional[KernelConfig]:
        """The pinned config for ``kernel``, or None (resolve via table)."""
        for cfg in self.configs:
            if cfg.kernel == kernel:
                return cfg
        return None

    def validate(self) -> None:
        """Structural validation of every pinned config (ValueError)."""
        seen = set()
        for cfg in self.configs:
            validate_config(cfg)
            if cfg.kernel in seen:
                raise ValueError(
                    f"TuningSpec pins kernel {cfg.kernel!r} more than once")
            seen.add(cfg.kernel)


def _next_pow2(x: int) -> int:
    return 1 if x <= 1 else 1 << (int(x) - 1).bit_length()


def _round_up(x: int, mult: int) -> int:
    return ((int(x) + mult - 1) // mult) * mult


def validate_config(cfg: KernelConfig) -> None:
    """Reject structurally illegal configs with a ValueError naming the
    offending field. Shape-dependent feasibility (VMEM at a concrete
    shape) is the tuner's job — ``candidate_configs`` filters on it — so
    a structurally valid config is usable at any shape the kernel pads.
    """
    if not isinstance(cfg, KernelConfig):
        raise TypeError(f"expected a KernelConfig, got {type(cfg).__name__}")
    if cfg.kernel not in KERNELS:
        raise ValueError(f"unknown kernel {cfg.kernel!r} (use one of "
                         f"{KERNELS})")
    arity = _BLOCK_ARITY[cfg.kernel]
    if len(cfg.block) != arity:
        raise ValueError(
            f"{cfg.kernel} takes {arity} block sizes, got {cfg.block!r}")
    if any((not isinstance(b, int)) or b <= 0 for b in cfg.block):
        raise ValueError(f"block sizes must be positive ints, got "
                         f"{cfg.block!r}")
    if cfg.kernel == "sketch_fused":
        bn, bd = cfg.block
        if bn % LANE:
            raise ValueError(f"sketch_fused bn must be a multiple of "
                             f"{LANE}, got bn={bn}")
        if bd % SUBLANE:
            raise ValueError(f"sketch_fused bd must be a multiple of "
                             f"{SUBLANE}, got bd={bd}")
    elif cfg.kernel == "blocked_fwht":
        b, bn = cfg.block
        if b & (b - 1):
            raise ValueError(f"blocked_fwht b must be a power of two, "
                             f"got b={b}")
        if bn % LANE:
            raise ValueError(f"blocked_fwht bn must be a multiple of "
                             f"{LANE}, got bn={bn}")
    elif cfg.kernel == "flash_attention":
        bq, bk = cfg.block
        if bq % SUBLANE or bk % SUBLANE:
            raise ValueError(f"flash_attention bq/bk must be multiples of "
                             f"{SUBLANE}, got {cfg.block}")
    if cfg.grid_order is not None and \
            cfg.grid_order not in GRID_ORDERS[cfg.kernel]:
        raise ValueError(
            f"illegal grid_order {cfg.grid_order!r} for {cfg.kernel} "
            f"(legal: {GRID_ORDERS[cfg.kernel] or 'none'})")
    if cfg.precision not in (None, "f32", "bf16"):
        raise ValueError(f"unknown precision {cfg.precision!r} "
                         f"(use None|'f32'|'bf16')")


def _itemsize(precision: Optional[str], dtype_bytes: int = 4) -> int:
    if precision == "bf16":
        return 2
    if precision == "f32":
        return 4
    return dtype_bytes


def vmem_bytes(cfg: KernelConfig, shape: Tuple[int, ...]) -> int:
    """Per-grid-step VMEM working set (bytes, f32 accounting): streamed
    input tiles double-buffered, resident outputs/scratch single. This is
    the arithmetic from each kernel's header, made executable.
    """
    validate_config(cfg)
    if cfg.kernel == "sketch_fused":
        k, d, n = shape
        bn, bd = cfg.block
        bd = min(bd, _round_up(d, SUBLANE))
        return 4 * (2 * (k * bd + bd * bn) + k * bn + bn)
    if cfg.kernel == "blocked_fwht":
        d, n = shape
        b, bn = cfg.block
        b = min(b, d)
        a = d // b
        stage1 = 4 * (b * b + 2 * (b + b * bn) + b * bn)
        stage2 = 0 if a <= 1 else 4 * (a * a + 3 * a * b * bn)
        return max(stage1, stage2)
    if cfg.kernel == "sampled_dot":
        n1, n2, k, m = shape
        return 4 * (4 * k + n1 + n2 + 2)
    if cfg.kernel == "flash_attention":
        BH, S, Dh = shape
        bq, bk = (min(b, S) for b in cfg.block)
        return 4 * (2 * (bq * Dh + 2 * bk * Dh) + bq * Dh + bq * (Dh + 2))
    raise AssertionError(cfg.kernel)


@dataclasses.dataclass(frozen=True)
class RooflineCost:
    """Static cost terms for one kernel call at one shape and config."""

    hbm_bytes: float          # total HBM traffic per call
    flops: float              # MXU/VPU flops per call
    steps: int                # grid steps per call
    mxu_occupancy: float      # fraction of the 128x128 array the tiles fill
    t_memory: float           # hbm_bytes / HBM_BW
    t_compute: float          # flops / (peak * occupancy)
    t_total: float            # max(mem, compute) + steps * STEP_OVERHEAD_S

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def _mxu_occupancy(*dims: int) -> float:
    occ = 1.0
    for d in dims:
        occ *= d / _round_up(d, LANE)
    return occ


def roofline_cost(cfg: KernelConfig, shape: Tuple[int, ...], *,
                  dtype_bytes: int = 4) -> RooflineCost:
    """The static model the ranking runs on. Bytes/flops are modeled over
    the *padded* shapes the ops wrappers actually launch, so a config
    whose blocks force heavy padding is charged for it.
    """
    validate_config(cfg)
    ds = _itemsize(cfg.precision, dtype_bytes)
    if cfg.kernel == "sketch_fused":
        k, d, n = shape
        bn, bd = cfg.block
        bd = min(bd, _round_up(d, SUBLANE))
        dp, np_ = _round_up(d, bd), _round_up(n, bn)
        # A streamed once; the (k, bd) Pi stripe re-fetched per n-tile;
        # f32 sketch + norm rows written once
        hbm = dp * np_ * ds + (np_ // bn) * k * dp * ds + 4 * (k + 1) * np_
        flops = 2.0 * k * dp * np_
        steps = (np_ // bn) * (dp // bd)
        occ = _mxu_occupancy(k, bn)
    elif cfg.kernel == "blocked_fwht":
        d, n = shape
        b, bn = cfg.block
        b = min(b, d)
        a = d // b
        np_ = _round_up(n, bn)
        # stage 1: X in (signs fused), Y out; stage 2 (a > 1): Y in, Z out
        hbm = d * np_ * ds + 4 * d * np_ + 4 * d + 4 * b * b
        flops = 2.0 * d * np_ * b
        steps = a * (np_ // bn)
        if a > 1:
            hbm += 8 * d * np_ + 4 * a * a
            flops += 2.0 * d * np_ * a
            steps += np_ // bn
        occ = _mxu_occupancy(b, bn)
    elif cfg.kernel == "sampled_dot":
        n1, n2, k, m = shape
        # two (1, k) gathered rows + one f32 output element per step;
        # norm rows resident (fetched once)
        hbm = m * (2 * k * ds + 4) + 4 * (n1 + n2) + 8 * m
        flops = 6.0 * m * k
        steps = m
        occ = 1.0            # VPU reduction, no MXU tile to fill
    elif cfg.kernel == "flash_attention":
        BH, S, Dh = shape
        bq, bk = (min(b, S) for b in cfg.block)
        # q/o move once; k/v re-streamed once per q-block
        hbm = 2 * BH * S * Dh * ds + 2 * BH * (S // bq) * S * Dh * ds
        flops = 4.0 * BH * S * S * Dh
        steps = BH * (S // bq) * (S // bk)
        occ = _mxu_occupancy(bq, bk)
    else:
        raise AssertionError(cfg.kernel)
    peak = PEAK_FLOPS * (1.0 if ds == 2 else 0.5)   # f32 MXU at half rate
    t_mem = hbm / HBM_BW
    t_comp = flops / (peak * max(occ, 1e-6))
    t_total = kernel_time_lb(flops, hbm, peak_flops=peak * max(occ, 1e-6),
                             steps=steps, step_overhead=STEP_OVERHEAD_S)
    return RooflineCost(hbm_bytes=float(hbm), flops=float(flops),
                        steps=int(steps), mxu_occupancy=float(occ),
                        t_memory=t_mem, t_compute=t_comp, t_total=t_total)


_BLOCK_CHOICES = {
    "sketch_fused": ((128, 256, 512), (128, 256, 512, 1024, 2048)),
    "blocked_fwht": ((32, 64, 128, 256), (128, 256, 512)),
    "flash_attention": ((64, 128, 256), (64, 128, 256)),
}


def candidate_configs(kernel: str, shape: Tuple[int, ...], *,
                      precision: Optional[str] = None,
                      vmem_budget: int = VMEM_BUDGET_BYTES
                      ) -> List[KernelConfig]:
    """All legal configs for ``kernel`` at ``shape``: block choices from
    the MXU-aligned menus, every legal grid order, filtered by the VMEM
    budget. ``precision`` is inherited, never swept (the tuner must not
    change numerics). Always contains at least one entry: if every menu
    candidate busts the budget (huge operand dims), the smallest-footprint
    one is kept so ranking has something to return.
    """
    if kernel not in KERNELS:
        raise ValueError(f"unknown kernel {kernel!r} (use one of {KERNELS})")
    if kernel == "sampled_dot":
        return [DEFAULTS[kernel]._replace(precision=precision)]
    choices_a, choices_b = _BLOCK_CHOICES[kernel]
    if kernel == "sketch_fused":
        k, d, n = shape
        cap_a, cap_b = _next_pow2(max(n, LANE)), _next_pow2(max(d, SUBLANE))
    elif kernel == "blocked_fwht":
        d, n = shape
        cap_a, cap_b = d, _next_pow2(max(n, LANE))
    else:                                   # flash_attention
        BH, S, Dh = shape
        cap_a = cap_b = S
    orders = GRID_ORDERS[kernel] or (None,)
    cands: List[KernelConfig] = []
    for ba in choices_a:
        if ba > cap_a:
            continue
        for bb in choices_b:
            if bb > cap_b:
                continue
            if kernel == "flash_attention" and (S % ba or S % bb):
                continue
            for order in orders:
                cands.append(KernelConfig(kernel, (ba, bb), order,
                                          precision))
    cands = [c._replace(grid_order=None)
             if c.grid_order == (GRID_ORDERS[kernel] or (None,))[0]
             else c for c in cands]
    if not cands:
        cands = [DEFAULTS[kernel]._replace(precision=precision)]
    fitting = [c for c in cands if vmem_bytes(c, shape) <= vmem_budget]
    if not fitting:
        fitting = [min(cands, key=lambda c: (vmem_bytes(c, shape), c.block))]
    return fitting


def rank_candidates(kernel: str, shape: Tuple[int, ...], *,
                    precision: Optional[str] = None, dtype_bytes: int = 4,
                    vmem_budget: int = VMEM_BUDGET_BYTES
                    ) -> List[KernelConfig]:
    """Candidates sorted best-first by the static roofline cost.

    Fully deterministic: ties on modeled time break on the config tuple
    itself, so two runs (or CI and a laptop) always agree on the order —
    which is what lets interpret-mode CPU CI pin a static ranking.
    """
    cands = candidate_configs(kernel, shape, precision=precision,
                              vmem_budget=vmem_budget)
    return sorted(cands, key=lambda c: (
        roofline_cost(c, shape, dtype_bytes=dtype_bytes).t_total,
        c.block, c.grid_order or "", c.precision or ""))


# ---------------------------------------------------------------------------
# Measurement (real-hardware half of the tuner)
# ---------------------------------------------------------------------------

def measure_config(cfg: KernelConfig, shape: Tuple[int, ...], *,
                   reps: int = 3) -> float:
    """Wall-time one kernel call (us/call) with synthetic inputs at
    ``shape`` under ``cfg``. Runs on whatever backend jax resolves —
    compiled on TPU, interpret elsewhere — so CPU numbers are only
    meaningful relative to other configs of the same kernel.
    """
    import time

    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    validate_config(cfg)
    key = jax.random.PRNGKey(0)
    if cfg.kernel == "sketch_fused":
        k, d, n = shape
        Pi = jax.random.normal(key, (k, d))
        A = jax.random.normal(jax.random.fold_in(key, 1), (d, n))
        fn = lambda: ops.sketch_fused(Pi, A, config=cfg)
    elif cfg.kernel == "blocked_fwht":
        d, n = shape
        X = jax.random.normal(key, (d, n))
        signs = jax.random.rademacher(jax.random.fold_in(key, 1), (d,),
                                      dtype=jnp.float32)
        fn = lambda: ops.blocked_fwht(X, signs, config=cfg)
    elif cfg.kernel == "sampled_dot":
        n1, n2, k, m = shape
        As = jax.random.normal(key, (n1, k))
        Bs = jax.random.normal(jax.random.fold_in(key, 1), (n2, k))
        na = jnp.ones((n1,))
        nb = jnp.ones((n2,))
        rows = jax.random.randint(jax.random.fold_in(key, 2), (m,), 0, n1)
        cols = jax.random.randint(jax.random.fold_in(key, 3), (m,), 0, n2)
        fn = lambda: ops.sampled_rescaled_dot(As, Bs, na, nb, rows, cols,
                                              config=cfg)
    elif cfg.kernel == "flash_attention":
        BH, S, Dh = shape
        qkv = jax.random.normal(key, (3, BH, S, 1, Dh))
        fn = lambda: ops.flash_attention(qkv[0], qkv[1], qkv[2],
                                         config=cfg)
    else:
        raise AssertionError(cfg.kernel)
    jax.block_until_ready(fn())                     # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def autotune(kernel: str, shape: Tuple[int, ...], *,
             precision: Optional[str] = None, dtype_bytes: int = 4,
             measure_top: int = 0, reps: int = 3,
             table: Optional["TuningTable"] = None
             ) -> Tuple[KernelConfig, List[dict]]:
    """Pick the best config for ``kernel`` at ``shape``.

    ``measure_top=0`` (the static mode CI uses) returns the roofline
    ranking's head. ``measure_top=N`` wall-times the N best-ranked
    candidates and picks the fastest measured — the real-hardware mode.
    If ``table`` is given the winner is recorded under the shape bucket.
    Returns ``(winner, records)`` where each record carries the config
    tag, the model's cost terms, and (when measured) us/call +
    achieved GB/s.
    """
    ranked = rank_candidates(kernel, shape, precision=precision,
                             dtype_bytes=dtype_bytes)
    records = []
    for cfg in ranked[:max(measure_top, 1)]:
        cost = roofline_cost(cfg, shape, dtype_bytes=dtype_bytes)
        rec = {"config": cfg.tag(), "block": list(cfg.block),
               "grid_order": cfg.grid_order, "precision": cfg.precision,
               **cost.as_dict()}
        if measure_top > 0:
            us = measure_config(cfg, shape, reps=reps)
            rec["us_per_call"] = us
            rec["achieved_gbps"] = cost.hbm_bytes / (us * 1e-6) / 1e9
        records.append((cfg, rec))
    if measure_top > 0:
        winner = min(records, key=lambda cr: cr[1]["us_per_call"])[0]
    else:
        winner = ranked[0]
    if table is not None:
        winning = next(r for c, r in records if c == winner)
        table.put(kernel, shape, winner,
                  stats={k: winning[k] for k in
                         ("us_per_call", "achieved_gbps")
                         if k in winning})
    return winner, [r for _, r in records]


# ---------------------------------------------------------------------------
# The versioned tuning table
# ---------------------------------------------------------------------------

TABLE_VERSION = 1

_DTYPE_TAGS = {2: "bf16", 4: "f32"}


def table_key(kernel: str, shape: Tuple[int, ...],
              dtype_bytes: int = 4) -> str:
    """``kernel|dtype|pow2-bucketed-shape`` — the table's lookup key.
    Bucketing each dim up to a power of two lets one measured winner
    serve the whole neighborhood of shapes that pad/tile identically.
    """
    bucket = "x".join(str(_next_pow2(s)) for s in shape)
    return f"{kernel}|{_DTYPE_TAGS.get(dtype_bytes, dtype_bytes)}|{bucket}"


@dataclasses.dataclass
class TuningTable:
    """Persisted winners: ``{table_key: config dict}`` + provenance.

    >>> from repro.kernels.tuning import (DEFAULTS, KernelConfig,
    ...                                   TuningTable)
    >>> t = TuningTable(backend="cpu")
    >>> t.put("sketch_fused", (64, 1000, 300),
    ...       KernelConfig("sketch_fused", (128, 1024)))
    >>> t.get("sketch_fused", (64, 1024, 512)).block    # same pow2 bucket
    (128, 1024)
    >>> t.get("sketch_fused", (64, 4096, 512)) is None  # unknown bucket
    True
    """

    backend: str = "any"
    version: int = TABLE_VERSION
    entries: Dict[str, dict] = dataclasses.field(default_factory=dict)

    def put(self, kernel: str, shape: Tuple[int, ...], cfg: KernelConfig,
            *, dtype_bytes: int = 4, stats: Optional[dict] = None) -> None:
        """Record ``cfg`` as the winner for the shape's bucket."""
        validate_config(cfg)
        entry = {"block": list(cfg.block), "grid_order": cfg.grid_order,
                 "precision": cfg.precision}
        if stats:
            entry["stats"] = dict(stats)
        self.entries[table_key(kernel, shape, dtype_bytes)] = entry

    def get(self, kernel: str, shape: Tuple[int, ...],
            dtype_bytes: int = 4) -> Optional[KernelConfig]:
        """The recorded winner for the shape's bucket, or None."""
        entry = self.entries.get(table_key(kernel, shape, dtype_bytes))
        if entry is None:
            return None
        return KernelConfig(kernel, tuple(entry["block"]),
                            entry.get("grid_order"),
                            entry.get("precision"))

    def save(self, path: str) -> None:
        """Write the versioned JSON artifact."""
        payload = {"version": self.version, "backend": self.backend,
                   "entries": self.entries}
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")

    @classmethod
    def load(cls, path: str) -> "TuningTable":
        """Read a table; a version newer than this code understands is an
        error (the format is versioned precisely so stale readers fail
        loudly instead of silently mis-tuning)."""
        with open(path) as f:
            payload = json.load(f)
        version = payload.get("version")
        if version != TABLE_VERSION:
            raise ValueError(
                f"{path}: tuning-table version {version!r} not supported "
                f"(this build reads version {TABLE_VERSION})")
        return cls(backend=payload.get("backend", "any"),
                   version=version, entries=dict(payload.get("entries", {})))


_TUNINGS_DIR = os.path.join(os.path.dirname(__file__), "tunings")
_TABLE_CACHE: Dict[str, TuningTable] = {}


def table_path(backend: str) -> str:
    """Where the committed table for a backend lives."""
    return os.path.join(_TUNINGS_DIR, f"{backend}.json")


def builtin_table(backend: Optional[str] = None) -> TuningTable:
    """The committed table for ``backend`` (default: the jax backend),
    cached per process; an absent file is an empty table. Call
    ``reload_tables()`` after editing a table on disk — resolutions are
    read at trace time, so already-compiled executables keep the config
    they were traced with.
    """
    if backend is None:
        import jax
        backend = jax.default_backend()
    if backend not in _TABLE_CACHE:
        path = table_path(backend)
        _TABLE_CACHE[backend] = (TuningTable.load(path)
                                 if os.path.exists(path)
                                 else TuningTable(backend=backend))
    return _TABLE_CACHE[backend]


def reload_tables() -> None:
    """Drop the per-process table cache (next lookup re-reads disk)."""
    _TABLE_CACHE.clear()


def lookup(kernel: str, shape: Tuple[int, ...], *, dtype_bytes: int = 4,
           backend: Optional[str] = None) -> KernelConfig:
    """The ops-wrapper resolution: committed-table hit for the shape
    bucket, else the frozen default. Never returns None and never changes
    numerics — an unknown shape gets exactly the historical block sizes.
    """
    if kernel not in KERNELS:
        raise ValueError(f"unknown kernel {kernel!r} (use one of {KERNELS})")
    hit = builtin_table(backend).get(kernel, shape, dtype_bytes)
    return hit if hit is not None else DEFAULTS[kernel]


def dtype_bytes_of(x) -> int:
    """Map an array (or dtype) to the table's dtype granularity."""
    try:
        size = x.dtype.itemsize
    except AttributeError:
        import numpy as np
        size = np.dtype(x).itemsize
    return 2 if size == 2 else 4


def retune(shapes: Dict[str, List[Tuple[int, ...]]], *, backend: str,
           measure_top: int = 4, reps: int = 3,
           out_path: Optional[str] = None) -> TuningTable:
    """Measure-and-persist for a dict of ``{kernel: [shapes...]}`` — the
    re-tune-on-new-hardware entry point (see docs/kernels.md). Returns
    the table (written to ``out_path`` or the committed location).
    """
    table = TuningTable(backend=backend)
    for kernel, shape_list in shapes.items():
        for shape in shape_list:
            autotune(kernel, shape, measure_top=measure_top, reps=reps,
                     table=table)
    table.save(out_path or table_path(backend))
    return table


def achieved_gbps(cfg: KernelConfig, shape: Tuple[int, ...],
                  us_per_call: float, *, dtype_bytes: int = 4) -> float:
    """Modeled HBM bytes over measured wall time — the bench suite's
    bandwidth metric (meaningful on real hardware; on interpret-mode CPU
    it is a relative figure only)."""
    cost = roofline_cost(cfg, shape, dtype_bytes=dtype_bytes)
    return cost.hbm_bytes / (us_per_call * 1e-6) / 1e9


def _occupancy_note() -> str:   # pragma: no cover - doc helper
    return (f"MXU occupancy derates {PEAK_FLOPS / 1e12:.0f} TFLOP/s peak; "
            f"HBM terms assume {HBM_BW / 1e9:.0f} GB/s")
