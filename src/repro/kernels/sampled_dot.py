"""Sampled rescaled-JL dot products (paper step 2, O(mk) term) as a gather
kernel with scalar-prefetched indices.

Given row-major sketches As (n1, k), Bs (n2, k) (columns of the original
sketch transposed once at the end of the pass — k is small so this is cheap),
exact norms, and the sampled index pairs (rows, cols), computes

    out[t] = ||A_rows[t]|| * ||B_cols[t]|| * <As[rows[t]], Bs[cols[t]]>
             / (||As[rows[t]]|| * ||Bs[cols[t]]||)

TPU design: the Omega indices live in SMEM via scalar prefetch
(``pltpu.PrefetchScalarGridSpec``), and each operand's BlockSpec index_map
*dereferences the prefetched index* to DMA exactly the (1, k) sketch row the
grid step needs — the standard TPU fused-embedding-gather pattern (no (n, k)
tile ever enters VMEM). Grid pipelining overlaps the row DMAs with compute.

bm rows are processed per grid step by unrolling the index_map over a
(bm, k) stripe when the sample list is pre-sorted; the default bm=1 handles
arbitrary order. Norm vectors are tiny (n floats) and stay fully resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_EPS = 1e-12


def _kernel(rows_ref, cols_ref, a_ref, b_ref, na_ref, nb_ref, out_ref):
    g = pl.program_id(0)
    a = a_ref[...].astype(jnp.float32)        # (1, k)
    b = b_ref[...].astype(jnp.float32)        # (1, k)
    dot = jnp.sum(a * b)
    sa = jnp.sqrt(jnp.sum(a * a))
    sb = jnp.sqrt(jnp.sum(b * b))
    na = na_ref[0, rows_ref[g]]
    nb = nb_ref[0, cols_ref[g]]
    out_ref[0, 0] = dot * na * nb / jnp.maximum(sa * sb, _EPS)


@functools.partial(jax.jit, static_argnames=("interpret", "precision"))
def sampled_rescaled_dot(As_rows: jax.Array, Bs_rows: jax.Array,
                         norm_A: jax.Array, norm_B: jax.Array,
                         rows: jax.Array, cols: jax.Array, *,
                         interpret: bool = True,
                         precision: str | None = None) -> jax.Array:
    """As_rows: (n1, k), Bs_rows: (n2, k), rows/cols: (m,) int32 -> (m,) f32.

    ``m`` is the static sample budget: any m >= 0 works, including m = 0
    (an empty Omega — no grid to launch, return the empty result directly;
    a zero-size grid would slice zero-size operands) and m > n1 * n2 (more
    samples than distinct entries — duplicates gather the same sketch rows,
    each grid step is independent).

    ``precision='bf16'`` casts the gathered sketch rows (halves the per-step
    row DMA — the kernel has no block knobs, this is its one tunable); the
    body always reduces in f32, so ``None``/``'f32'`` on f32 inputs are
    bit-identical. Norm vectors stay f32 (they rescale the final estimate).
    """
    if precision == "bf16":
        As_rows = As_rows.astype(jnp.bfloat16)
        Bs_rows = Bs_rows.astype(jnp.bfloat16)
    elif precision not in (None, "f32"):
        raise ValueError(
            f"unknown precision {precision!r} (None|'f32'|'bf16')")
    m = rows.shape[0]
    k = As_rows.shape[1]
    n1, n2 = As_rows.shape[0], Bs_rows.shape[0]
    if m == 0:
        return jnp.zeros((0,), jnp.float32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(m,),
        in_specs=[
            pl.BlockSpec((1, k), lambda g, rows, cols: (rows[g], 0)),
            pl.BlockSpec((1, k), lambda g, rows, cols: (cols[g], 0)),
            pl.BlockSpec((1, n1), lambda g, rows, cols: (0, 0)),
            pl.BlockSpec((1, n2), lambda g, rows, cols: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda g, rows, cols: (g, 0)),
    )
    out = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.float32),
        interpret=interpret,
    )(rows.astype(jnp.int32), cols.astype(jnp.int32),
      As_rows, Bs_rows, norm_A[None, :], norm_B[None, :])
    return out[:, 0]
