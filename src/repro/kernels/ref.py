"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-12


def sketch_fused_ref(Pi: jax.Array, A: jax.Array):
    """(Pi @ A, squared column norms)."""
    out = Pi.astype(jnp.float32) @ A.astype(jnp.float32)
    norm2 = jnp.sum(A.astype(jnp.float32) ** 2, axis=0)
    return out, norm2


def sampled_rescaled_dot_ref(As_rows: jax.Array, Bs_rows: jax.Array,
                             norm_A: jax.Array, norm_B: jax.Array,
                             rows: jax.Array, cols: jax.Array) -> jax.Array:
    a = As_rows[rows].astype(jnp.float32)     # (m, k)
    b = Bs_rows[cols].astype(jnp.float32)
    dots = jnp.sum(a * b, axis=1)
    sa = jnp.linalg.norm(a, axis=1)
    sb = jnp.linalg.norm(b, axis=1)
    return dots * norm_A[rows] * norm_B[cols] / jnp.maximum(sa * sb, _EPS)


def blocked_fwht_ref(X: jax.Array, signs: jax.Array) -> jax.Array:
    """Unnormalized FWHT of the sign-flipped input (butterfly reference)."""
    from repro.core.sketch import fwht
    return fwht(X.astype(jnp.float32) * signs[:, None].astype(jnp.float32),
                axis=0)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True) -> jax.Array:
    """Naive softmax attention oracle. q/k/v: (BH, S, Dh)."""
    import math
    BH, S, Dh = q.shape
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(Dh)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
