"""Blocked fast Walsh-Hadamard transform — the SRHT sketch on the MXU.

The paper's Spark implementation uses SRHT (sqrt(d/k) R H D) to cut the
sketch cost from O(ndk) to O(nd log d). A recursive butterfly FWHT is
pointer-chasing and hostile to the TPU; instead we use the Kronecker
factorization (Sylvester): for d = a * b with row-major index split i = p*b+j,

    H_d = H_a (x) H_b   =>   H_d X = stage2( stage1(X) )
    stage1: Y[p] = H_b @ X[p]      -- a independent (b x n) MXU matmuls
    stage2: Z[q] = sum_p H_a[q,p] Y[p]  == H_a @ Y  viewed as (a, b*n)

Both stages are dense matmuls against small constant Hadamard tiles
(<=256x256, resident in VMEM), which run on the systolic MXU at full rate —
this is the TPU-native adaptation of the GPU butterfly described in
DESIGN.md §4. The SRHT sign flips (D) are fused into stage 1's input read.

Cost: 2 * d * n * max(a, b) MACs; with a = b = sqrt(d) that is O(n d sqrt(d))
MXU work but only O(n d) HBM traffic per stage — on TPU the MXU is free
relative to HBM here (arithmetic intensity ~ b), so the matmul form beats an
O(n d log d) scalar butterfly by keeping everything in 128x128 systolic tiles.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def hadamard_matrix(n: int, dtype=jnp.float32) -> jax.Array:
    """Sylvester Hadamard matrix H_n (n a power of two), unnormalized."""
    if n < 1 or n & (n - 1):
        raise ValueError(
            f"Hadamard matrix size must be a power of two, got n={n}")
    H = np.array([[1.0]], dtype=np.float32)
    while H.shape[0] < n:
        H = np.block([[H, H], [H, -H]])
    return jnp.asarray(H, dtype)


def _stage1_kernel(h_ref, sign_ref, x_ref, out_ref):
    xs = x_ref[...].astype(jnp.float32) * sign_ref[...].astype(jnp.float32)
    out_ref[...] = jax.lax.dot_general(
        h_ref[...], xs, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _stage2_kernel(h_ref, y_ref, out_ref):
    out_ref[...] = jax.lax.dot_general(
        h_ref[...], y_ref[...].astype(jnp.float32),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("b", "bn", "grid_order", "interpret"))
def blocked_fwht(X: jax.Array, signs: jax.Array, *, b: int = 128,
                 bn: int = 256, grid_order: str | None = None,
                 interpret: bool = True) -> jax.Array:
    """H_d @ (signs[:, None] * X), unnormalized. X: (d, n), d = a*b, both
    powers of two, n % bn == 0 (ops.py pads).

    ``grid_order`` picks stage 1's traversal: ``None``/``'n_inner'`` walks
    n-tiles innermost (one Hb/sign stripe resident per p), ``'p_inner'``
    walks p innermost (one X column stripe's tiles consecutive — better when
    bn is wide and b small). Legal because stage 1 writes each output block
    exactly once (no revisit/accumulation), so traversal order cannot change
    the result — bit-identical by construction, which tests/kernels assert.
    """
    d, n = X.shape
    if d % b:
        raise ValueError(f"blocked_fwht: d={d} not divisible by block b={b}")
    a = d // b
    if (a & (a - 1)) or (b & (b - 1)):
        raise ValueError(f"blocked_fwht: tile split d = a*b needs both "
                         f"powers of two, got a={a}, b={b}")
    if n % bn:
        raise ValueError(f"blocked_fwht: n={n} not divisible by bn={bn}; "
                         f"pad first (kernels.ops.blocked_fwht does this)")
    if grid_order not in (None, "n_inner", "p_inner"):
        raise ValueError(f"blocked_fwht: unknown grid_order {grid_order!r} "
                         f"(None|'n_inner'|'p_inner')")
    Hb = hadamard_matrix(b)
    Ha = hadamard_matrix(a)

    # stage 1: per-p tile, out[p*b:(p+1)*b, :] = Hb @ (D X)[p*b:(p+1)*b, :]
    if grid_order == "p_inner":
        grid1 = (n // bn, a)
        ix = lambda ni, p: (p, ni)      # (p_idx, n_idx) from (outer, inner)
        iy = lambda ni, p: (p, 0)
    else:
        grid1 = (a, n // bn)
        ix = lambda p, ni: (p, ni)
        iy = lambda p, ni: (p, 0)
    Y = pl.pallas_call(
        _stage1_kernel,
        grid=grid1,
        in_specs=[
            pl.BlockSpec((b, b), lambda *_: (0, 0)),
            pl.BlockSpec((b, 1), iy),
            pl.BlockSpec((b, bn), ix),
        ],
        out_specs=pl.BlockSpec((b, bn), ix),
        out_shape=jax.ShapeDtypeStruct((d, n), jnp.float32),
        interpret=interpret,
    )(Hb, signs.reshape(d, 1), X)

    if a == 1:
        return Y

    # stage 2: combine across tiles: view Y as (a, b*n), Z = Ha @ Y_mat.
    # The (d, n) row-major buffer *is* (a, b*n) row-major — a free reshape.
    Ym = Y.reshape(a, b * n)
    bm = b * bn
    Z = pl.pallas_call(
        _stage2_kernel,
        grid=(b * n // bm,),
        in_specs=[
            pl.BlockSpec((a, a), lambda c: (0, 0)),
            pl.BlockSpec((a, bm), lambda c: (0, c)),
        ],
        out_specs=pl.BlockSpec((a, bm), lambda c: (0, c)),
        out_shape=jax.ShapeDtypeStruct((a, b * n), jnp.float32),
        interpret=interpret,
    )(Ha, Ym)
    return Z.reshape(d, n)
