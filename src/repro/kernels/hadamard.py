"""Blocked fast Walsh-Hadamard transform — the SRHT sketch on the MXU.

The paper's Spark implementation uses SRHT (sqrt(d/k) R H D) to cut the
sketch cost from O(ndk) to O(nd log d). A recursive butterfly FWHT is
pointer-chasing and hostile to the TPU; instead we use the Kronecker
factorization (Sylvester): for d = a * b with row-major index split i = p*b+j,

    H_d = H_a (x) H_b   =>   H_d X = stage2( stage1(X) )
    stage1: Y[p] = H_b @ X[p]      -- a independent (b x n) MXU matmuls
    stage2: Z[q] = sum_p H_a[q,p] Y[p]  == H_a @ Y  viewed as (a, b*n)

Both stages are dense matmuls against small constant Hadamard tiles
(<=256x256, resident in VMEM), which run on the systolic MXU at full rate —
this is the TPU-native adaptation of the GPU butterfly described in
DESIGN.md §4. The SRHT sign flips (D) are fused into stage 1's input read.

Cost: 2 * d * n * max(a, b) MACs; with a = b = sqrt(d) that is O(n d sqrt(d))
MXU work but only O(n d) HBM traffic per stage — on TPU the MXU is free
relative to HBM here (arithmetic intensity ~ b), so the matmul form beats an
O(n d log d) scalar butterfly by keeping everything in 128x128 systolic tiles.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def hadamard_matrix(n: int, dtype=jnp.float32) -> jax.Array:
    """Sylvester Hadamard matrix H_n (n a power of two), unnormalized."""
    assert n & (n - 1) == 0, n
    H = np.array([[1.0]], dtype=np.float32)
    while H.shape[0] < n:
        H = np.block([[H, H], [H, -H]])
    return jnp.asarray(H, dtype)


def _stage1_kernel(h_ref, sign_ref, x_ref, out_ref):
    xs = x_ref[...].astype(jnp.float32) * sign_ref[...].astype(jnp.float32)
    out_ref[...] = jax.lax.dot_general(
        h_ref[...], xs, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _stage2_kernel(h_ref, y_ref, out_ref):
    out_ref[...] = jax.lax.dot_general(
        h_ref[...], y_ref[...].astype(jnp.float32),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("b", "bn", "interpret"))
def blocked_fwht(X: jax.Array, signs: jax.Array, *, b: int = 128,
                 bn: int = 256, interpret: bool = True) -> jax.Array:
    """H_d @ (signs[:, None] * X), unnormalized. X: (d, n), d = a*b, both
    powers of two, n % bn == 0 (ops.py pads)."""
    d, n = X.shape
    assert d % b == 0, (d, b)
    a = d // b
    assert a & (a - 1) == 0 and b & (b - 1) == 0, (a, b)
    assert n % bn == 0, (n, bn)
    Hb = hadamard_matrix(b)
    Ha = hadamard_matrix(a)

    # stage 1: per-p tile, out[p*b:(p+1)*b, :] = Hb @ (D X)[p*b:(p+1)*b, :]
    Y = pl.pallas_call(
        _stage1_kernel,
        grid=(a, n // bn),
        in_specs=[
            pl.BlockSpec((b, b), lambda p, ni: (0, 0)),
            pl.BlockSpec((b, 1), lambda p, ni: (p, 0)),
            pl.BlockSpec((b, bn), lambda p, ni: (p, ni)),
        ],
        out_specs=pl.BlockSpec((b, bn), lambda p, ni: (p, ni)),
        out_shape=jax.ShapeDtypeStruct((d, n), jnp.float32),
        interpret=interpret,
    )(Hb, signs.reshape(d, 1), X)

    if a == 1:
        return Y

    # stage 2: combine across tiles: view Y as (a, b*n), Z = Ha @ Y_mat.
    # The (d, n) row-major buffer *is* (a, b*n) row-major — a free reshape.
    Ym = Y.reshape(a, b * n)
    bm = b * bn
    Z = pl.pallas_call(
        _stage2_kernel,
        grid=(b * n // bm,),
        in_specs=[
            pl.BlockSpec((a, a), lambda c: (0, 0)),
            pl.BlockSpec((a, bm), lambda c: (0, c)),
        ],
        out_specs=pl.BlockSpec((a, bm), lambda c: (0, c)),
        out_shape=jax.ShapeDtypeStruct((a, b * n), jnp.float32),
        interpret=interpret,
    )(Ha, Ym)
    return Z.reshape(d, n)
