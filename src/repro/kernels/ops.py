"""Public wrappers around the Pallas kernels, with tunable configs.

Handles padding to kernel-aligned shapes, backend dispatch (compiled Pallas on
TPU, interpret=True elsewhere — the kernel *body* runs either way so CPU CI
validates the real TPU code path), and integration glue used by repro.core
and the gradient compressor.

Every wrapper takes an optional ``config: tuning.KernelConfig``. Resolution
happens host-side, *before* the jitted impl (so the block sizes are concrete
static arguments and repeat calls hit jax's compile cache):

    explicit kwarg (bn=..., precision=...)   wins over
    explicit ``config``                      wins over
    committed tuning-table hit for the shape bucket   wins over
    ``tuning.DEFAULTS`` (the historical hard-coded values)

With no table entry and no config the resolved blocks are exactly the old
hard-coded defaults, so default-path outputs are bit-identical to the
pre-tuning kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _flash
from repro.kernels import hadamard as _hadamard
from repro.kernels import sampled_dot as _sampled_dot
from repro.kernels import sketch_fused as _sketch_fused
from repro.kernels import tuning as _tuning
from repro.core.types import SketchSummary


def _interpret() -> bool:
    """Single source of the interpret policy: compile the Pallas kernels only
    on TPU; interpret everywhere else. CPU CI still runs the real TPU kernel
    bodies tile-by-tile. GPU must stay interpreted too: the kernels accumulate
    across a grid dimension (``out_ref[...] +=`` with a revisited output
    block), which relies on TPU's sequential grid — Pallas GPU runs grid
    cells in parallel and would race."""
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _resolved(kernel: str, shape: tuple, ref: jax.Array,
              config: "_tuning.KernelConfig | None") -> _tuning.KernelConfig:
    """The effective config: validated explicit one, else table/defaults."""
    if config is None:
        return _tuning.lookup(kernel, shape,
                              dtype_bytes=_tuning.dtype_bytes_of(ref))
    _tuning.validate_config(config)
    if config.kernel != kernel:
        raise ValueError(f"config is for kernel {config.kernel!r}, "
                         f"wrapper is {kernel!r}")
    return config


@functools.partial(jax.jit, static_argnames=("bn", "bd", "precision"))
def _sketch_fused_call(Pi: jax.Array, A: jax.Array, *, bn: int, bd: int,
                       precision: str | None
                       ) -> tuple[jax.Array, jax.Array]:
    n = A.shape[1]
    bd_eff = min(bd, _pad_to(A, 0, 8).shape[0])
    Ap = _pad_to(_pad_to(A, 0, bd_eff), 1, bn)
    Pip = _pad_to(Pi, 1, bd_eff)
    out, norm2 = _sketch_fused.sketch_fused(
        Pip, Ap, bn=bn, bd=bd_eff, interpret=_interpret(),
        precision=precision)
    return out[:, :n], jnp.sqrt(norm2[:n])


def sketch_fused(Pi: jax.Array, A: jax.Array, *, bn: int | None = None,
                 bd: int | None = None, precision: str | None = None,
                 config: "_tuning.KernelConfig | None" = None
                 ) -> tuple[jax.Array, jax.Array]:
    """Fused (Pi @ A, column norms) for arbitrary shapes; pads then crops.

    Zero padding is exact for both outputs (zero rows/cols add nothing).
    ``precision='bf16'`` casts the inputs; accumulation stays f32."""
    k, d = Pi.shape
    n = A.shape[1]
    cfg = _resolved("sketch_fused", (k, d, n), A, config)
    return _sketch_fused_call(
        Pi, A, bn=bn if bn is not None else cfg.block[0],
        bd=bd if bd is not None else cfg.block[1],
        precision=precision if precision is not None else cfg.precision)


def sketch_summary_fused(key: jax.Array, A: jax.Array, B: jax.Array,
                         k: int, method: str = "gaussian",
                         precision: str | None = None) -> SketchSummary:
    """Kernel-backed summary == the SummaryEngine's 'pallas' backend."""
    from repro.core.summary_engine import build_summary
    return build_summary(key, A, B, k, method=method, backend="pallas",
                         precision=precision)


@functools.partial(jax.jit, static_argnames=("precision",))
def _sampled_dot_call(As_rows: jax.Array, Bs_rows: jax.Array,
                      norm_A: jax.Array, norm_B: jax.Array,
                      rows: jax.Array, cols: jax.Array, *,
                      precision: str | None) -> jax.Array:
    return _sampled_dot.sampled_rescaled_dot(
        As_rows, Bs_rows, norm_A, norm_B, rows, cols,
        interpret=_interpret(), precision=precision)


def sampled_rescaled_dot(As_rows: jax.Array, Bs_rows: jax.Array,
                         norm_A: jax.Array, norm_B: jax.Array,
                         rows: jax.Array, cols: jax.Array, *,
                         precision: str | None = None,
                         config: "_tuning.KernelConfig | None" = None
                         ) -> jax.Array:
    """Kernel-backed rescaled-JL estimates on Omega (row-major sketches)."""
    n1, k = As_rows.shape
    n2, m = Bs_rows.shape[0], rows.shape[0]
    cfg = _resolved("sampled_dot", (n1, n2, k, m), As_rows, config)
    return _sampled_dot_call(
        As_rows, Bs_rows, norm_A, norm_B, rows, cols,
        precision=precision if precision is not None else cfg.precision)


@functools.partial(jax.jit, static_argnames=("b", "bn", "grid_order"))
def _blocked_fwht_call(X: jax.Array, signs: jax.Array, *, b: int, bn: int,
                       grid_order: str | None) -> jax.Array:
    d, n = X.shape
    b_eff = min(b, d)
    Xp = _pad_to(X, 1, bn)
    out = _hadamard.blocked_fwht(Xp, signs, b=b_eff, bn=bn,
                                 grid_order=grid_order,
                                 interpret=_interpret())
    return out[:, :n]


def blocked_fwht(X: jax.Array, signs: jax.Array, *, b: int | None = None,
                 bn: int | None = None, grid_order: str | None = None,
                 config: "_tuning.KernelConfig | None" = None) -> jax.Array:
    """Kernel-backed unnormalized FWHT of (signs * X); pads n, crops back."""
    d, n = X.shape
    if d & (d - 1):
        raise ValueError(
            f"blocked_fwht: d must be a power of two (got d={d}); "
            f"pad first (srht_sketch_kernel does this)")
    cfg = _resolved("blocked_fwht", (d, n), X, config)
    return _blocked_fwht_call(
        X, signs, b=b if b is not None else cfg.block[0],
        bn=bn if bn is not None else cfg.block[1],
        grid_order=grid_order if grid_order is not None else cfg.grid_order)


@functools.partial(jax.jit, static_argnames=("k", "config"))
def srht_sketch_kernel(key: jax.Array, X: jax.Array, k: int,
                       config: "_tuning.KernelConfig | None" = None
                       ) -> jax.Array:
    """Kernel-backed SRHT: sqrt(1/k) R H D X with the blocked-FWHT kernel."""
    d, n = X.shape
    dp = 1
    while dp < d:
        dp *= 2
    key_sign, key_rows = jax.random.split(key)
    signs = jax.random.rademacher(key_sign, (d,), dtype=X.dtype)
    signs_p = jnp.pad(signs, (0, dp - d), constant_values=1)
    Xp = jnp.pad(X, ((0, dp - d), (0, 0)))
    HX = blocked_fwht(Xp, signs_p, config=config) / jnp.sqrt(dp)
    rows = jax.random.choice(key_rows, dp, (k,), replace=False)
    return HX[rows] * jnp.sqrt(dp / k)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk"))
def _flash_call(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool,
                bq: int, bk: int) -> jax.Array:
    B, S, H, Dh = q.shape
    Hkv = k.shape[2]
    rep = H // Hkv
    kf = jnp.repeat(k, rep, axis=2)
    vf = jnp.repeat(v, rep, axis=2)
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, S, Dh)
    out = _flash.flash_attention(fold(q), fold(kf), fold(vf), causal=causal,
                                 bq=bq, bk=bk, interpret=_interpret())
    return out.reshape(B, H, S, Dh).transpose(0, 2, 1, 3)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    config: "_tuning.KernelConfig | None" = None
                    ) -> jax.Array:
    """Fused-attention kernel entry point. q: (B, S, H, Dh), k/v GQA
    (B, S, Hkv, Dh); expands KV groups and folds (B, H) for the kernel."""
    B, S, H, Dh = q.shape
    cfg = _resolved("flash_attention", (B * H, S, Dh), q, config)
    return _flash_call(q, k, v, causal=causal,
                       bq=min(cfg.block[0], S), bk=min(cfg.block[1], S))
