"""Flash attention (forward) as a Pallas TPU kernel — the §Perf cell-A mover.

The roofline analysis (EXPERIMENTS.md §Perf) showed dense-transformer train
and prefill cells are memory-bound on materialized (S x S) score tensors:
~90% of phi3 train's 73 TB/device/step. This kernel keeps the whole
score -> softmax -> PV chain in VMEM with the online-softmax recurrence, so
the only HBM traffic is Q/K/V in and O out — the same fuse-the-chain
principle the paper's one-pass sketch applies to its own hot loop
(kernels/sketch_fused.py).

Design (TPU v5e):
  grid = (B*H, S/bq, S/bk), k-blocks innermost; the (bq, d) accumulator and
  the (bq,) running max / denominator live in VMEM scratch that persists
  across the k-steps of one q-block. Causal masking is computed in-register;
  fully-masked k-blocks still occupy grid steps (a production kernel would
  clamp the k-range per q-block — noted as the next iteration).
  Block shapes default to (128, 128): MXU-aligned, ~0.6 MB VMEM working set.

Backward: not implemented here — dQ/dK/dV need the same fusion applied to
the two backward matmuls (documented in EXPERIMENTS.md as the remaining
step); training paths fall back to the chunked pure-JAX attention.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bq: int, bk: int, scale: float, causal: bool):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
    k = k_ref[0].astype(jnp.float32)                  # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)
    if causal:
        qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(kpos <= qpos, s, _NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=-1)[:, None]              # (bq, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                            # (bq, bk)
    corr = jnp.exp(m_prev - m_new)                    # (bq, 1)
    l_new = l_prev * corr + jnp.sum(p, axis=-1)[:, None]
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, bq: int = 128, bk: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q/k/v: (BH, S, Dh) with heads pre-expanded (GQA handled by the ops
    wrapper). Returns (BH, S, Dh) in q's dtype."""
    BH, S, Dh = q.shape
    if S % bq or S % bk:
        raise ValueError(f"flash_attention: S={S} not divisible by blocks "
                         f"(bq={bq}, bk={bk})")
    scale = 1.0 / math.sqrt(Dh)
    grid = (BH, S // bq, S // bk)
    kernel = functools.partial(_kernel, bq=bq, bk=bk, scale=scale,
                               causal=causal)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, Dh), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, Dh), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bk, Dh), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, Dh), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # running denominator
            pltpu.VMEM((bq, Dh), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
