"""End-to-end training driver (CPU-runnable at reduced scale, pjit-ready).

    PYTHONPATH=src python -m repro.launch.train --arch phi3-mini-3.8b \
        --reduced --steps 100 --batch 8 --seq 128 --compression taps
"""
from __future__ import annotations

import argparse
import json
import logging


from repro.configs import get_config
from repro.data.pipeline import SyntheticLM
from repro.models import build
from repro.optim import AdamW, warmup_cosine
from repro.train import TrainConfig, Trainer, TrainerConfig
from repro.train.sketched_dense import TapConfig
from repro.optim.grad_compression import CompressionConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compression", default="none",
                    choices=["none", "taps", "lowrank"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build(cfg)
    data = SyntheticLM(vocab_size=cfg.vocab_size, batch_size=args.batch,
                      seq_len=args.seq, seed=0)
    opt = AdamW(lr=warmup_cosine(args.lr, max(args.steps // 10, 1),
                                 args.steps), weight_decay=0.01)
    tcfg = TrainConfig(microbatches=args.microbatches,
                       compression=args.compression,
                       comp_cfg=CompressionConfig(),
                       tap_cfg=TapConfig())
    trainer = Trainer(model.loss, opt, data, tcfg,
                      TrainerConfig(num_steps=args.steps,
                                    ckpt_dir=args.ckpt_dir,
                                    log_every=args.log_every),
                      init_params_fn=model.init_params)
    state = trainer.run()
    hist = trainer.metrics_history
    print(json.dumps({"first_loss": hist[0]["loss"],
                      "last_loss": hist[-1]["loss"],
                      "steps": int(state.step),
                      "stragglers": trainer.straggler_events}))


if __name__ == "__main__":
    main()
