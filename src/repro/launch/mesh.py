"""Production mesh builders. Functions, not module constants — importing this
module never touches jax device state (required by the dry-run contract)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 4):
    """Small mesh for subprocess tests (8 host devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def dp_axes(mesh) -> tuple:
    """All data-parallel axes of a mesh ('pod' included when present)."""
    names = mesh.axis_names
    return tuple(a for a in names if a in ("pod", "data"))
