"""Serving driver: batched generation with the Engine (reduced-scale CPU).

    PYTHONPATH=src python -m repro.launch.serve --arch phi3-mini-3.8b \
        --reduced --batch 4 --prompt-len 16 --new-tokens 16
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build
from repro.serve.engine import Engine, ServeConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init_params(key)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    for name, sds in model.aux_input_shapes(args.batch).items():
        batch[name] = jnp.zeros(sds.shape, sds.dtype)
    eng = Engine(model, params,
                 ServeConfig(max_new_tokens=args.new_tokens,
                             temperature=args.temperature))
    out = eng.generate(batch)
    print(json.dumps({"arch": cfg.name, "output_shape": list(out.shape),
                      "sample_row": out[0].tolist()[:24]}))


if __name__ == "__main__":
    main()
