"""Serving drivers: LM generation with the Engine, and sketch serving on
the continuously-batched ``ServingLoop`` (reduced-scale CPU).

    PYTHONPATH=src python -m repro.launch.serve --arch phi3-mini-3.8b \
        --reduced --batch 4 --prompt-len 16 --new-tokens 16

    PYTHONPATH=src python -m repro.launch.serve --mode sketch \
        --requests 32 --max-batch 8 --deadline-ms 200 --tenants acme,globex

``--mode sketch`` runs the async serving stack end to end: a ServingLoop is
started on its background pump, requests are submitted (returning futures
immediately), and the driver just waits on the futures — batching,
deadlines, and dispatch all happen on the loop thread. The JSON line it
prints carries the loop stats (dispatches, occupancy, shed) so the driver
doubles as a smoke check that continuous batching is actually batching.
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build
from repro.serve.engine import Engine, ServeConfig


def run_generate(args) -> dict:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init_params(key)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    for name, sds in model.aux_input_shapes(args.batch).items():
        batch[name] = jnp.zeros(sds.shape, sds.dtype)
    eng = Engine(model, params,
                 ServeConfig(max_new_tokens=args.new_tokens,
                             temperature=args.temperature))
    out = eng.generate(batch)
    return {"arch": cfg.name, "output_shape": list(out.shape),
            "sample_row": out[0].tolist()[:24]}


def run_sketch(args) -> dict:
    from repro.core import pipeline
    from repro.serve.scheduler import LoopConfig, PipelineWork, ServingLoop

    plan = pipeline.PipelinePlan(
        sketch=pipeline.SketchSpec(k=args.k, backend="scan", block=1024),
        estimation=pipeline.EstimationSpec(m=args.m, T=args.T),
        rank=pipeline.RankPolicy(r=args.r),
        key_layout="service")
    loop = ServingLoop(config=LoopConfig(
        max_batch=args.max_batch,
        max_queue=args.max_queue,
        default_deadline=args.deadline_ms / 1e3,
        pad="pow2"))
    tenants = [t or None for t in args.tenants.split(",")] if args.tenants \
        else [None]
    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (args.d, args.n))
    B = jax.random.normal(jax.random.fold_in(key, 1), (args.d, args.n))

    loop.start()
    try:
        futures = [
            loop.submit(jax.random.fold_in(key, i), A, B,
                        work=PipelineWork(plan),
                        tenant=tenants[i % len(tenants)])
            for i in range(args.requests)]
        ranks = sorted({f.result(timeout=600).estimate.factors.U.shape[-1]
                        for f in futures})
    finally:
        loop.stop()
    stats = loop.stats
    return {"mode": "sketch", "requests": args.requests,
            "completed": stats.completed,
            "dispatches": stats.dispatches,
            "occupancy": round(stats.occupancy, 3),
            "shed": dict(stats.shed),
            "dispatch_triggers": dict(stats.dispatched),
            "served_ranks": ranks}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("generate", "sketch"),
                    default="generate")
    # generate mode
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    # sketch mode
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-queue", type=int, default=None)
    ap.add_argument("--deadline-ms", type=float, default=200.0)
    ap.add_argument("--tenants", default="",
                    help="comma-separated tenant ids cycled over requests")
    ap.add_argument("--d", type=int, default=512)
    ap.add_argument("--n", type=int, default=32)
    ap.add_argument("--k", type=int, default=64)
    ap.add_argument("--r", type=int, default=4)
    ap.add_argument("--m", type=int, default=800)
    ap.add_argument("--T", type=int, default=3)
    args = ap.parse_args(argv)

    out = run_sketch(args) if args.mode == "sketch" else run_generate(args)
    print(json.dumps(out))


if __name__ == "__main__":
    main()
