import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST be the first lines, before ANY other import: jax locks the device
#    count at first init. Only the dry-run sees 512 placeholder devices.

import argparse
import json
import sys
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.configs.shapes import SHAPES, cell_applicable, get_shape
from repro.dist import sharding as shr
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.models import build
from repro.optim.adamw import AdamW
from repro.roofline import analysis as roof
from repro.roofline import hlo_analyzer
from repro.train import train_step as ts


def _abstract(tree, shardings):
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        tree, shardings)


def _batch_specs(model, mesh, dp, B, S, kind):
    specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32,
             sharding=NamedSharding(mesh, P(dp, None)))}
    if kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct(
            (B, S), jnp.int32, sharding=NamedSharding(mesh, P(dp, None)))
    for name, sds in model.aux_input_shapes(B).items():
        specs[name] = jax.ShapeDtypeStruct(
            sds.shape, sds.dtype,
            sharding=NamedSharding(mesh, P(dp, None, None)))
    return specs


def _fit_dp(mesh, dp, B):
    """Largest prefix of dp axes that divides B (long_500k has B=1)."""
    out = []
    rem = B
    for a in dp:
        if rem % mesh.shape[a] == 0:
            out.append(a)
            rem //= mesh.shape[a]
    return tuple(out) if out else None


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               policy: Optional[str] = None, moments: Optional[str] = None,
               compression: str = "none",
               extra_overrides: Optional[Dict[str, Any]] = None):
    """Lower + compile one (arch x shape x mesh) cell; returns record dict."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, reason = cell_applicable(cfg.family, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "SKIP", "reason": reason}

    overrides: Dict[str, Any] = dict(extra_overrides or {})
    if shape.kind != "train":
        overrides.setdefault("param_dtype", "bfloat16")
        overrides.setdefault("remat", False)
    model = build(cfg, **overrides)
    cfg = model.cfg

    mesh = make_production_mesh(multi_pod=multi_pod)
    from repro.dist import meshctx
    meshctx.set_mesh(mesh)
    chips = mesh.size
    dp = _fit_dp(mesh, dp_axes(mesh), shape.global_batch)
    big = cfg.n_params() > 2e10
    if policy is None:
        policy = "fsdp_tp" if (shape.kind == "train" or big) else "tp_only"
    if moments is None:
        moments = "bfloat16" if cfg.n_params() > 5e10 else "float32"

    pshard = shr.params_shardings(mesh, model.param_shapes(), policy=policy,
                                  dp=dp or ("data",), tp="model")

    t0 = time.time()
    if shape.kind == "train":
        opt = AdamW(moment_dtype=jnp.bfloat16 if moments == "bfloat16"
                    else jnp.float32)
        tcfg = ts.TrainConfig(microbatches=1, compression=compression)
        step_fn = ts.make_train_step(model.loss, opt, tcfg)
        params_abs = model.param_shapes()
        state_abs = jax.eval_shape(
            lambda p: ts.init_state(jax.random.PRNGKey(0), p, opt, tcfg),
            params_abs)
        # moments mirror param structure -> same sharding rules
        state_shardings = ts.TrainState(
            params=pshard,
            opt=type(state_abs.opt)(NamedSharding(mesh, P()), pshard, pshard),
            comp=(),
            step=NamedSharding(mesh, P()), key=NamedSharding(mesh, P()))
        state_in = _abstract(state_abs, state_shardings)
        # microbatch dim folded in: (1, B, ...) per _split_microbatches
        batch_in = _batch_specs(model, mesh, dp, shape.global_batch,
                                shape.seq_len, "train")
        fn = jax.jit(step_fn, donate_argnums=(0,))
        lowered = fn.lower(state_in, batch_in)
        tokens = shape.global_batch * shape.seq_len
        mf = roof.model_flops("train", cfg.n_active_params(), tokens)
    elif shape.kind == "prefill":
        cache_abs = model.cache_shapes(shape.global_batch, shape.seq_len)
        cshard = shr.cache_shardings(mesh, cache_abs, dp=dp or ("data",))
        batch_in = _batch_specs(model, mesh, dp, shape.global_batch,
                                shape.seq_len, "prefill")
        fn = jax.jit(lambda p, b, c: model.prefill(p, b, c),
                     donate_argnums=(2,))
        lowered = fn.lower(_abstract(model.param_shapes(), pshard), batch_in,
                           _abstract(cache_abs, cshard))
        tokens = shape.global_batch * shape.seq_len
        mf = roof.model_flops("prefill", cfg.n_active_params(), tokens)
    else:  # decode
        cache_abs = model.cache_shapes(shape.global_batch, shape.seq_len)
        cshard = shr.cache_shardings(mesh, cache_abs, dp=dp or ("data",))
        tok = jax.ShapeDtypeStruct(
            (shape.global_batch, 1), jnp.int32,
            sharding=NamedSharding(mesh, P(dp, None)))
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        fn = jax.jit(lambda p, c, t, i: model.decode_step(p, c, t, i),
                     donate_argnums=(1,))
        lowered = fn.lower(_abstract(model.param_shapes(), pshard),
                           _abstract(cache_abs, cshard), tok, pos)
        tokens = shape.global_batch
        mf = roof.model_flops("decode", cfg.n_active_params(), tokens)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = None
    try:
        ma = compiled.memory_analysis()
        print(ma)
        mem = {k: int(getattr(ma, k)) for k in
               ("argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes")
               if hasattr(ma, k)}
    except Exception as e:  # noqa: BLE001
        print(f"memory_analysis unavailable: {e}")

    cost = compiled.cost_analysis()
    print({k: v for k, v in sorted(cost.items())
           if k in ("flops", "bytes accessed", "transcendentals")})
    hlo = compiled.as_text()
    # XLA's cost_analysis counts while bodies ONCE; use the trip-count-aware
    # analyzer for the roofline terms (see repro.roofline.hlo_analyzer).
    acc = hlo_analyzer.analyze(hlo)

    rl = roof.Roofline(
        flops=float(acc.flops),
        bytes_accessed=float(acc.bytes),
        coll_bytes=float(acc.coll_bytes),
        model_flops_per_device=mf / chips,
        chips=chips)
    coll_dict = {"total_bytes": acc.coll_bytes, "by_op": acc.coll_by_op,
                 "xla_cost_analysis_flops": float(cost.get("flops", 0.0))}

    record = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "OK", "policy": policy, "moments": moments,
        "compression": compression,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": mem, "collectives": coll_dict,
        "roofline": rl.as_dict(),
    }
    return record


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--policy", default=None,
                    choices=[None, "fsdp_tp", "tp_only"])
    ap.add_argument("--moments", default=None,
                    choices=[None, "float32", "bfloat16"])
    ap.add_argument("--compression", default="none",
                    choices=["none", "taps", "lowrank"])
    ap.add_argument("--scores-bf16", action="store_true")
    ap.add_argument("--remat-policy", default=None,
                    choices=[None, "full", "save_attn_out"])
    ap.add_argument("--sketched-mlp", action="store_true")
    ap.add_argument("--constrain-acts", action="store_true")
    ap.add_argument("--tag", default="", help="extra label in the record")
    ap.add_argument("--out", default=None, help="append JSONL record here")
    args = ap.parse_args(argv)

    overrides = {}
    if args.scores_bf16:
        overrides["attn_scores_dtype"] = "bfloat16"
    if args.remat_policy:
        overrides["remat_policy"] = args.remat_policy
    if args.sketched_mlp:
        overrides["sketched_mlp"] = True
    if args.constrain_acts:
        overrides["constrain_activations"] = True
    rec = lower_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                     policy=args.policy, moments=args.moments,
                     compression=args.compression,
                     extra_overrides=overrides or None)
    if args.tag:
        rec["tag"] = args.tag
    print(json.dumps(rec, indent=2))
    if args.out:
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")
    return 0 if rec["status"] in ("OK", "SKIP") else 1


if __name__ == "__main__":
    sys.exit(main())
