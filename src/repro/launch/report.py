"""Render the dry-run / roofline results (JSONL) as the EXPERIMENTS.md
markdown tables.  Usage: python -m repro.launch.report results/dryrun.jsonl"""
from __future__ import annotations

import json
import sys


def fmt(rows):
    out = []
    out.append("| arch | shape | mesh | policy | t_compute (s) | t_memory (s)"
               " | t_collective (s) | bottleneck | MODEL/HLO flops |"
               " roofline frac | would move the dominant term |")
    out.append("|---|---|---|---|---|---|---|---|---|---|---|")
    hints = {
        ("memory", "train"): "fuse attention score chain (Pallas flash) / "
                             "bf16 scores / save_attn_out remat",
        ("memory", "prefill"): "flash-fused attention scores; bf16 KV",
        ("memory", "decode"): "KV-cache quantization; larger per-chip batch",
        ("collective", "train"): "overlap grad all-reduce w/ bwd; SMP-PCA "
                                 "gradient compression; activation sharding "
                                 "constraints",
        ("collective", "prefill"): "2D weight-stationary sharding",
        ("collective", "decode"): "weight-stationary 2D sharding (kill "
                                  "per-step weight all-gather)",
        ("compute", "train"): "near roofline — raise per-chip batch",
        ("compute", "prefill"): "near roofline",
        ("compute", "decode"): "near roofline",
    }
    for r in rows:
        if r["status"] == "SKIP":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — |"
                       f" — | — | SKIP | — | — | {r['reason'][:60]} |")
            continue
        if r["status"] != "OK":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} |"
                       f" {r['status']} | | | | | | | |")
            continue
        rl = r["roofline"]
        kind = ("train" if r["shape"].startswith("train") else
                "prefill" if r["shape"].startswith("prefill") else "decode")
        hint = hints.get((rl["bottleneck"], kind), "")
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r.get('policy','')} "
            f"| {rl['t_compute_s']:.3g} | {rl['t_memory_s']:.3g} "
            f"| {rl['t_collective_s']:.3g} | **{rl['bottleneck']}** "
            f"| {min(rl['useful_flops_fraction'], 9.99):.3f} "
            f"| {rl['roofline_fraction']:.4f} | {hint} |")
    return "\n".join(out)


def memory_table(rows):
    out = ["| arch | shape | mesh | args (GB/dev) | temps (GB/dev) |"
           " collective GB/dev (by op) |",
           "|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "OK":
            continue
        m = r.get("memory") or {}
        arg = m.get("argument_size_in_bytes", 0) / 2**30
        tmp = m.get("temp_size_in_bytes", 0) / 2**30
        by = r["collectives"]["by_op"]
        bys = " ".join(f"{k.replace('all-','a').replace('collective-','c')}:"
                       f"{v/2**30:.1f}" for k, v in sorted(by.items()))
        out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | {arg:.1f} "
                   f"| {tmp:.1f} | {bys} |")
    return "\n".join(out)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun.jsonl"
    rows = [json.loads(l) for l in open(path)]
    print("## Roofline table\n")
    print(fmt(rows))
    print("\n## Memory / collective detail\n")
    print(memory_table(rows))


if __name__ == "__main__":
    main()
