"""Dry-run grid driver: every (arch x shape x mesh) cell as a subprocess
(fresh XLA per cell, no jit-cache growth), resumable via the JSONL output.

    PYTHONPATH=src python -m repro.launch.grid --out results/dryrun.jsonl
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

ARCHS = [
    "xlstm-350m", "whisper-small", "phi3-mini-3.8b", "granite-3-8b",
    "recurrentgemma-9b", "llama-3.2-vision-11b", "starcoder2-15b",
    "moonshot-v1-16b-a3b", "mistral-large-123b", "kimi-k2-1t-a32b",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def done_cells(path):
    out = set()
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    out.add((r["arch"], r["shape"], r["mesh"]))
                except Exception:   # noqa: BLE001
                    pass
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--timeout", type=int, default=3600)
    ap.add_argument("--archs", default=None, help="comma list subset")
    ap.add_argument("--meshes", default="16x16,2x16x16")
    args = ap.parse_args(argv)

    archs = args.archs.split(",") if args.archs else ARCHS
    meshes = args.meshes.split(",")
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = done_cells(args.out)
    cells = [(a, s, m) for a in archs for s in SHAPES for m in meshes]
    todo = [(a, s, m) for a, s, m in cells if (a, s, m) not in done]
    print(f"{len(todo)}/{len(cells)} cells to run", flush=True)

    for i, (arch, shape, mesh) in enumerate(todo):
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--out", args.out]
        if mesh != "16x16":
            cmd.append("--multi-pod")
        t0 = time.time()
        print(f"[{i+1}/{len(todo)}] {arch} {shape} {mesh} ...", flush=True)
        try:
            p = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout)
            ok = p.returncode == 0
            if not ok:
                tail = (p.stdout + p.stderr)[-2000:]
                with open(args.out, "a") as f:
                    f.write(json.dumps({
                        "arch": arch, "shape": shape, "mesh": mesh,
                        "status": "FAIL", "error": tail}) + "\n")
        except subprocess.TimeoutExpired:
            with open(args.out, "a") as f:
                f.write(json.dumps({"arch": arch, "shape": shape,
                                    "mesh": mesh, "status": "TIMEOUT"}) + "\n")
            ok = False
        print(f"    -> {'ok' if ok else 'FAIL'} ({time.time()-t0:.0f}s)",
              flush=True)


if __name__ == "__main__":
    main()
