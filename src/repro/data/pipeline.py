"""Deterministic synthetic data pipelines.

``SyntheticLM``: batch(step) is a *pure function* of (seed, step, host) — no
iterator state. Restarting after a failure resumes at exactly the right
sample with zero coordination (deterministic skip-ahead; DESIGN.md §8), and
host-sharding falls out of folding host_id into the key.

The token process is learnable: a noisy affine walk over the vocab
(next = cur*mult + 1 mod V with prob 1-noise, else uniform), so training
loss decreasing is a meaningful integration test signal.

``cooccurrence_stream``: the paper's query x ad / bag-of-words setting — a
stream of (row, col-of-A, col-of-B) observations in ARBITRARY order, feeding
examples/streaming_cooccurrence.py.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab_size: int
    batch_size: int            # per-host batch
    seq_len: int
    seed: int = 0
    noise: float = 0.1
    mult: int = 3
    n_hosts: int = 1
    host_id: int = 0

    def batch(self, step: int) -> Dict[str, jax.Array]:
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), step),
            self.host_id)
        k_start, k_noise, k_rand = jax.random.split(key, 3)
        B, S, V = self.batch_size, self.seq_len, self.vocab_size
        start = jax.random.randint(k_start, (B,), 0, V)
        flip = jax.random.bernoulli(k_noise, self.noise, (B, S))
        rand = jax.random.randint(k_rand, (B, S), 0, V)

        def step_fn(cur, inputs):
            f, r = inputs
            nxt = jnp.where(f, r, (cur * self.mult + 1) % V)
            return nxt, nxt

        _, toks = jax.lax.scan(step_fn, start, (flip.T, rand.T))
        toks = jnp.concatenate([start[:, None], toks.T], axis=1)  # (B, S+1)
        return {"tokens": toks[:, :-1].astype(jnp.int32),
                "labels": toks[:, 1:].astype(jnp.int32)}


def cooccurrence_stream(seed: int, d: int, n1: int, n2: int, rank: int,
                        chunk: int) -> Iterator[Tuple[np.ndarray, np.ndarray,
                                                      np.ndarray]]:
    """Yields (row_ids, A_rows, B_rows) chunks in a shuffled (arbitrary)
    order. The underlying A, B are low-rank-plus-noise so A^T B has planted
    structure for SMP-PCA to find."""
    rng = np.random.default_rng(seed)
    UA = rng.normal(size=(d, rank)) / np.sqrt(rank)
    VA = rng.normal(size=(rank, n1))
    UB = 0.5 * UA + 0.5 * rng.normal(size=(d, rank)) / np.sqrt(rank)
    VB = rng.normal(size=(rank, n2))
    A = UA @ VA + 0.1 * rng.normal(size=(d, n1))
    B = UB @ VB + 0.1 * rng.normal(size=(d, n2))
    order = rng.permutation(d)
    for i in range(0, d, chunk):
        rows = order[i:i + chunk]
        yield rows, A[rows].astype(np.float32), B[rows].astype(np.float32)
