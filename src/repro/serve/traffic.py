"""Synthetic traffic generator: measured requests/sec through ServingLoop.

Drives the continuously-batched serving stack with an open-loop arrival
process — Poisson inter-arrival times x a shape mix x a tenant mix — and
measures what the ROADMAP's "millions of users" north star actually asks
for: requests/sec, p50/p99 latency, batch occupancy (requests per fused
dispatch), and shed rate, all in warm steady state with zero new
``PipelineEngine`` traces.

The generator is service-time calibrated: it first warms every power-of-two
batch width per shape bucket (the loop runs ``pad='pow2'`` so variable
occupancy maps onto a bounded executable set), times one warm full batch,
and then offers load at ``rate = target_occupancy / batch_service_time`` —
while one dispatch runs, ``target_occupancy`` new requests arrive, so the
steady-state batch size lands near the target. Deadlines and queue bounds
are likewise expressed in service-time multiples (``deadline_x`` etc.) so
one config describes the same *relative* regime on any machine.

The drive loop is single-threaded and open-loop: arrivals that are due are
submitted (never waiting on earlier results — queueing delay is measured,
not avoided), then the loop is polled; between events it sleeps to the next
arrival. Per-request latency is ``future.completed_at - submit time`` on
the loop's own clock.
"""
from __future__ import annotations

import time
from typing import NamedTuple, Optional, Tuple, Union

import numpy as np

import jax

from repro.core import pipeline
from repro.serve.scheduler import (
    LoopConfig,
    PipelineWork,
    Rejected,
    ServingLoop,
)

Shape = Tuple[int, int, int]          # (d, n1, n2): A is (d, n1), B is (d, n2)
Tenant = Optional[Union[int, str]]


class TrafficConfig(NamedTuple):
    """One traffic cell: an arrival process against one serving config.

    ``target_occupancy`` (requests arriving per batch service time) and the
    ``*_x`` knobs are in units of the measured warm full-batch service
    time, so the cell describes a load *regime*, not a wall-clock rate.
    ``rate_x`` scales the calibrated offered rate (>1 with a bounded queue
    = overload -> shedding). ``pairs_per_shape`` distinct payloads per
    shape are cycled so the device sees varied data without the generator
    paying per-request normal() sampling.
    """

    name: str = "traffic"
    n_requests: int = 128
    shapes: Tuple[Shape, ...] = ((512, 32, 24),)
    tenants: Tuple[Tenant, ...] = (None,)
    target_occupancy: float = 4.0
    rate_x: float = 1.0
    max_batch: int = 8
    max_queue: Optional[int] = None
    deadline_x: Optional[float] = 8.0   # SLO budget, x batch service time
    max_wait_x: Optional[float] = None  # shed limit, x batch service time
    k: int = 64
    backend: str = "scan"
    block: int = 1024
    r: int = 4
    m: int = 800
    T: int = 3
    pairs_per_shape: int = 4
    seed: int = 0


def _plan(cfg: TrafficConfig) -> pipeline.PipelinePlan:
    return pipeline.PipelinePlan(
        sketch=pipeline.SketchSpec(k=cfg.k, backend=cfg.backend,
                                   block=cfg.block),
        estimation=pipeline.EstimationSpec(m=cfg.m, T=cfg.T),
        rank=pipeline.RankPolicy(r=cfg.r),
        key_layout="service")


def _payloads(cfg: TrafficConfig):
    """Per-shape pools of (A, B) pairs, realized before the clock starts."""
    pools = []
    for s, (d, n1, n2) in enumerate(cfg.shapes):
        pool = []
        for p in range(cfg.pairs_per_shape):
            kp = jax.random.fold_in(jax.random.fold_in(
                jax.random.PRNGKey(cfg.seed), s), p)
            A = jax.random.normal(kp, (d, n1))
            B = jax.random.normal(jax.random.fold_in(kp, 1), (d, n2))
            pool.append((jax.block_until_ready(A), jax.block_until_ready(B)))
        pools.append(pool)
    return pools


def _warmup(cfg: TrafficConfig, engine, plan, pools) -> float:
    """Compile every pow2 batch width per shape; return the measured warm
    service time (seconds) of one full-width batch dispatch."""
    loop = ServingLoop(engine=engine, config=LoopConfig(pad="pow2"))
    widths = []
    w = 1
    full = 1 << (cfg.max_batch - 1).bit_length()
    while w <= full:
        widths.append(w)
        w <<= 1
    base = jax.random.PRNGKey(cfg.seed + 1)
    for s in range(len(cfg.shapes)):
        A, B = pools[s][0]
        for width in widths:
            for i in range(width):
                loop.submit(jax.random.fold_in(base, i), A, B,
                            work=PipelineWork(plan))
            loop.drain()
    # warm full batch on the first shape = the calibration unit
    A, B = pools[0][0]
    t0 = time.perf_counter()
    fs = [loop.submit(jax.random.fold_in(base, i), A, B,
                      work=PipelineWork(plan)) for i in range(full)]
    loop.drain()
    jax.block_until_ready(fs[-1].result().estimate.factors.U)
    return time.perf_counter() - t0


def run_traffic(cfg: TrafficConfig, *, engine=None) -> dict:
    """Run one traffic cell; returns the benchmark record (a JSON dict)."""
    engine = engine if engine is not None else pipeline.PipelineEngine()
    plan = _plan(cfg)
    pools = _payloads(cfg)

    service_s = _warmup(cfg, engine, plan, pools)
    traces_after_warmup = engine.stats.traces

    deadline = None if cfg.deadline_x is None else cfg.deadline_x * service_s
    max_wait = None if cfg.max_wait_x is None else cfg.max_wait_x * service_s
    loop = ServingLoop(engine=engine, clock=time.perf_counter,
                       config=LoopConfig(
                           max_batch=cfg.max_batch,
                           max_queue=cfg.max_queue,
                           max_wait=max_wait,
                           default_deadline=deadline,
                           dispatch_margin=0.1 * service_s,
                           pad="pow2"))

    n = cfg.n_requests
    rng = np.random.default_rng(cfg.seed)
    offered_rps = cfg.rate_x * cfg.target_occupancy / service_s
    arrivals = np.cumsum(rng.exponential(1.0 / offered_rps, n))
    shape_of = rng.integers(0, len(cfg.shapes), n)
    pair_of = rng.integers(0, cfg.pairs_per_shape, n)
    tenant_of = rng.integers(0, len(cfg.tenants), n)
    keys = jax.block_until_ready(
        jax.random.split(jax.random.PRNGKey(cfg.seed + 2), n))

    futures, submit_at = [], {}
    i = 0
    t0 = time.perf_counter()
    while i < n or loop.depth > 0:
        now = time.perf_counter() - t0
        while i < n and arrivals[i] <= now:
            A, B = pools[shape_of[i]][pair_of[i]]
            try:
                f = loop.submit(keys[i], A, B, work=PipelineWork(plan),
                                tenant=cfg.tenants[tenant_of[i]])
                submit_at[f.seq] = time.perf_counter()
                futures.append(f)
            except Rejected:
                pass                  # counted in loop.stats.shed
            i += 1
        dispatched = loop.poll()
        if i >= n and loop.depth and deadline is None:
            loop.drain()              # no SLO to force the tail out
        elif not dispatched:
            sleep = min(arrivals[i] - (time.perf_counter() - t0), 2e-3) \
                if i < n else 5e-4
            if sleep > 0:
                time.sleep(sleep)
    wall_s = time.perf_counter() - t0

    stats = loop.stats
    lat_ms = sorted(
        (f.completed_at - submit_at[f.seq]) * 1e3
        for f in futures if f.done and f.shed_reason is None)

    def pct(q):
        return float(np.percentile(lat_ms, q)) if lat_ms else float("nan")
    return {
        "name": cfg.name,
        "n_requests": n,
        "offered_rps": offered_rps,
        "measured_rps": stats.completed / wall_s if wall_s > 0 else 0.0,
        "p50_ms": pct(50),
        "p99_ms": pct(99),
        "mean_ms": float(np.mean(lat_ms)) if lat_ms else float("nan"),
        "occupancy": stats.occupancy,
        "shed_rate": stats.shed_total / n,
        "shed": dict(stats.shed),
        "dispatch_triggers": dict(stats.dispatched),
        "completed": stats.completed,
        "dispatches": stats.dispatches,
        "service_us_per_request": service_s / max(
            1 << (cfg.max_batch - 1).bit_length(), 1) * 1e6,
        "traces_warmup": traces_after_warmup,
        "traces_steady": engine.stats.traces - traces_after_warmup,
        "config": {
            "shapes": [list(s) for s in cfg.shapes],
            "tenants": [str(t) for t in cfg.tenants],
            "target_occupancy": cfg.target_occupancy,
            "rate_x": cfg.rate_x,
            "max_batch": cfg.max_batch,
            "max_queue": cfg.max_queue,
            "deadline_x": cfg.deadline_x,
            "max_wait_x": cfg.max_wait_x,
            "k": cfg.k, "r": cfg.r, "m": cfg.m, "T": cfg.T,
            "seed": cfg.seed,
        },
    }
