"""Continuously-batched serving loop — the scheduler/dispatcher split.

The paper's sketch->estimate->error recipe is a cheap, fixed-shape
computation a high-traffic service wants to run millions of times, and the
compile-once ``PipelineEngine`` makes every warm request one cache lookup
plus one fused dispatch. This module puts a production front-end on that
warm path:

* ``Scheduler`` — pure host-side queueing (no jax): an admission queue with
  **continuous batching** (a request joins its shape bucket's open batch
  slot the moment it arrives; the batch dispatches when full *or* when the
  oldest member's deadline budget forces it), earliest-deadline-first
  priority ordering, and bounded queues with **backpressure and
  load-shedding** (reject-with-reason when depth or wait-time limits are
  exceeded).
* ``Dispatcher`` — executes one ready batch as ONE fused dispatch through
  the shared ``PipelineEngine`` executable cache (stack keys/A/B, run the
  plan, unstack per request) and resolves the requests' futures.
* ``ServingLoop`` — composes the two behind a clock: ``submit`` admits a
  request and returns a ``ServeFuture`` immediately; ``poll`` sheds expired
  requests and dispatches every ready batch; ``drain`` force-dispatches
  everything queued (the synchronous ``SketchService.flush`` path);
  ``start``/``stop`` run ``poll`` on a background thread for fully async
  serving.

**Multi-tenant key namespacing**: a request submitted under ``tenant=``
has its key folded through the reserved two-level
``pipeline.tenant_key`` derivation *before* batching, so many tenants
share one warm executable cache (same plans, same shapes, same compiled
code) while two tenants submitting the *same* user key get bit-different
sketches. Tenancy never enters the batch signature — mixed-tenant traffic
batches together.

Everything is deterministic under an injected ``clock`` (tests drive a
virtual clock; production uses ``time.monotonic``):

>>> import jax
>>> from repro.core import pipeline
>>> from repro.serve.scheduler import LoopConfig, PipelineWork, ServingLoop
>>> key = jax.random.PRNGKey(0)
>>> A = jax.random.normal(key, (64, 6))
>>> B = jax.random.normal(jax.random.fold_in(key, 1), (64, 4))
>>> plan = pipeline.PipelinePlan(
...     sketch=pipeline.SketchSpec(k=8, backend="scan", block=32),
...     estimation=pipeline.EstimationSpec(m=64, T=2),
...     rank=pipeline.RankPolicy(r=2), key_layout="service")
>>> now = [0.0]
>>> loop = ServingLoop(config=LoopConfig(max_batch=2),
...                    clock=lambda: now[0])
>>> f1 = loop.submit(key, A, B, work=PipelineWork(plan))
>>> f2 = loop.submit(jax.random.fold_in(key, 7), A, B,
...                  work=PipelineWork(plan), tenant="acme")
>>> loop.poll()                    # batch full (2/2): ONE fused dispatch
1
>>> f1.done and f2.done
True
>>> f1.result().estimate.factors.U.shape
(6, 2)
>>> loop.stats.occupancy           # continuous batching: 2 requests/dispatch
2.0
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import math
import threading
import time
from typing import Callable, List, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import pipeline
from repro.core.pipeline import PipelineResult
from repro.core.types import ErrorEstimate, LowRankFactors, SketchSummary
from repro.kernels.tuning import TuningSpec

#: Load-shed reasons (``Rejected.reason`` / ``LoopStats.shed`` keys).
SHED_QUEUE_FULL = "queue_full"        # admission: depth limit exceeded
SHED_WAIT_EXCEEDED = "wait_exceeded"  # scheduling: waited past max_wait

#: Dispatch triggers (``LoopStats.dispatched`` keys).
DISPATCH_FULL = "full"                # batch slot reached max_batch
DISPATCH_DEADLINE = "deadline"        # oldest member's budget forced it
DISPATCH_DRAIN = "drain"              # explicit drain()/flush


class Rejected(RuntimeError):
    """A request the service refused (admission) or shed (scheduling).

    ``reason`` is one of the SHED_* constants; the message carries the
    limit that was exceeded so callers can apply backpressure upstream.
    """

    def __init__(self, reason: str, message: str):
        super().__init__(message)
        self.reason = reason


class SummaryWork(NamedTuple):
    """Step-1-only work: the request resolves to a ``SketchSummary``.

    ``tuning`` optionally pins Pallas kernel configs (a hashable
    ``repro.kernels.tuning.TuningSpec``) exactly like
    ``PipelinePlan.tuning`` does for full-pipeline work; it is part of the
    work value, hence part of the batch signature and the executable cache
    key — warm repeat-shape traffic under a pinned tuning never re-traces.
    """

    spec: pipeline.SketchSpec
    tuning: Optional[TuningSpec] = None


class PipelineWork(NamedTuple):
    """Full-pipeline work: the request resolves to a ``PipelineResult``."""

    plan: pipeline.PipelinePlan


class LoopConfig(NamedTuple):
    """Scheduling policy knobs (all limits optional; None = unbounded).

    * ``max_batch`` — dispatch a bucket's open batch the moment it holds
      this many requests (None: only deadlines or ``drain`` dispatch).
    * ``max_queue`` — admission bound on total queued requests; past it
      ``submit`` raises ``Rejected(SHED_QUEUE_FULL)`` (backpressure).
    * ``max_wait`` — requests queued longer than this are shed at the next
      ``poll`` with ``Rejected(SHED_WAIT_EXCEEDED)``.
    * ``default_deadline`` — deadline budget (seconds from arrival) for
      requests submitted without one; None = no deadline.
    * ``dispatch_margin`` — dispatch a partial batch this many seconds
      *before* its most urgent deadline (headroom for service time).
    * ``pad`` — ``'none'``: dispatch batches at their exact size (every new
      size is a new executable signature); ``'pow2'``: right-pad each batch
      to the next power of two by replicating its last request, then slice
      the padding off — per-request results are bit-identical (vmapped
      lanes are independent) but variable-occupancy traffic compiles at
      most log2(max_batch)+1 executables per bucket instead of one per
      batch size.
    """

    max_batch: Optional[int] = None
    max_queue: Optional[int] = None
    max_wait: Optional[float] = None
    default_deadline: Optional[float] = None
    dispatch_margin: float = 0.0
    pad: str = "none"


@dataclasses.dataclass
class LoopStats:
    """Observable serving counters (the traffic benchmark's raw cells)."""

    admitted: int = 0             # requests accepted into the queue
    completed: int = 0            # requests resolved with a result
    dispatches: int = 0           # fused device dispatches (batches)
    batched_requests: int = 0     # requests across all dispatches
    shed: collections.Counter = dataclasses.field(
        default_factory=collections.Counter)        # reason -> count
    dispatched: collections.Counter = dataclasses.field(
        default_factory=collections.Counter)        # trigger -> count

    @property
    def occupancy(self) -> float:
        """Mean requests per fused dispatch (continuous-batching win)."""
        return self.batched_requests / self.dispatches if self.dispatches \
            else 0.0

    @property
    def shed_total(self) -> int:
        """Requests refused or shed, over every reason."""
        return sum(self.shed.values())


class ServeFuture:
    """Handle for one in-flight request.

    ``done`` flips when the dispatcher resolves or the scheduler sheds the
    request; ``result()`` returns the work's value (``SketchSummary`` or
    ``PipelineResult``) or raises ``Rejected`` if the request was shed.
    ``result(timeout=...)`` blocks, so futures work identically whether
    the loop is polled inline or pumped by the background thread.
    """

    def __init__(self, seq: int):
        self.seq = seq
        self.dispatch_seq: Optional[int] = None   # which dispatch served it
        self.completed_at: Optional[float] = None
        self._event = threading.Event()
        self._value = None
        self._shed: Optional[Rejected] = None

    @property
    def done(self) -> bool:
        """True once resolved (with a result or a shed)."""
        return self._event.is_set()

    @property
    def shed_reason(self) -> Optional[str]:
        """The SHED_* reason if the request was shed, else None."""
        return None if self._shed is None else self._shed.reason

    def result(self, timeout: Optional[float] = None):
        """The served value; raises ``Rejected`` for shed requests."""
        if not self._event.wait(timeout):
            raise TimeoutError(f"request {self.seq} still pending")
        if self._shed is not None:
            raise self._shed
        return self._value

    def _resolve(self, value, dispatch_seq: int, now: float) -> None:
        self._value = value
        self.dispatch_seq = dispatch_seq
        self.completed_at = now
        self._event.set()

    def _reject(self, exc: Rejected, now: float) -> None:
        self._shed = exc
        self.completed_at = now
        self._event.set()


@dataclasses.dataclass
class _Request:
    """One admitted request: payload + scheduling state."""

    seq: int
    key: jax.Array                # tenant fold already applied
    A: jax.Array
    B: jax.Array
    work: Union[SummaryWork, PipelineWork]
    arrival: float
    deadline: Optional[float]     # absolute clock time, None = none
    future: ServeFuture

    @property
    def urgency(self) -> float:
        """EDF sort key (requests without a deadline sort last)."""
        return math.inf if self.deadline is None else self.deadline


class _Batch(NamedTuple):
    """A dispatch-ready group of same-signature requests."""

    requests: List[_Request]
    trigger: str                  # DISPATCH_FULL / _DEADLINE / _DRAIN

    @property
    def urgency(self) -> Tuple[float, int]:
        """Inter-batch EDF order: most urgent member, then oldest seq."""
        return (min(r.urgency for r in self.requests),
                min(r.seq for r in self.requests))


def _signature(req: _Request) -> tuple:
    """Batch bucket key: the work spec plus shapes AND dtypes (of A, B and
    the key) so stacking never promotes a request's arrays — results stay
    bit-identical to solo dispatches. Tenancy is deliberately absent."""
    return (req.work, req.A.shape, str(req.A.dtype), req.B.shape,
            str(req.B.dtype), req.key.shape, str(req.key.dtype))


class Scheduler:
    """Admission + continuous batching + EDF ordering (pure queueing).

    Requests live in per-signature buckets; each bucket IS its open batch
    slot — a request joins it on arrival and leaves when the batch
    dispatches (full / deadline-forced / drained) or when it is shed.
    No jax work happens here; the dispatcher owns the device.
    """

    def __init__(self, config: LoopConfig):
        if config.max_batch is not None and config.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {config.max_batch}")
        if config.max_queue is not None and config.max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {config.max_queue}")
        self.config = config
        self._buckets: "collections.OrderedDict[tuple, List[_Request]]" = \
            collections.OrderedDict()
        self._depth = 0

    @property
    def depth(self) -> int:
        """Total queued (not yet dispatched or shed) requests."""
        return self._depth

    def admit(self, req: _Request) -> None:
        """Queue a request into its bucket's open batch slot, or raise
        ``Rejected(SHED_QUEUE_FULL)`` when the depth bound is hit — the
        backpressure signal callers propagate upstream."""
        cfg = self.config
        if cfg.max_queue is not None and self._depth >= cfg.max_queue:
            raise Rejected(
                SHED_QUEUE_FULL,
                f"queue depth limit reached ({self._depth} >= "
                f"{cfg.max_queue} queued requests)")
        self._buckets.setdefault(_signature(req), []).append(req)
        self._depth += 1

    def shed_expired(self, now: float) -> List[_Request]:
        """Remove (and return) every request that has waited past
        ``max_wait`` — the wait-time load-shedding limit."""
        cfg = self.config
        if cfg.max_wait is None:
            return []
        expired: List[_Request] = []
        for sig in list(self._buckets):
            keep = []
            for req in self._buckets[sig]:
                if now - req.arrival > cfg.max_wait:
                    expired.append(req)
                else:
                    keep.append(req)
            self._prune(sig, keep)
        self._depth -= len(expired)
        return expired

    def ready(self, now: float) -> List[_Batch]:
        """Pop every dispatch-ready batch, most urgent first.

        A bucket's open batch is ready when it is **full** (``max_batch``
        members — repeatedly, so a backlog drains in max_batch-sized
        dispatches) or when its most urgent member's deadline budget
        **forces** it (``deadline - now <= dispatch_margin``), however
        few requests it holds. Members leave earliest-deadline-first, so
        an overfull bucket serves its most urgent requests in the first
        batch; batches are returned EDF-ordered across buckets, so a
        late-deadline pile-up in one bucket cannot starve an earlier
        deadline in another.
        """
        cfg = self.config
        batches: List[_Batch] = []
        for sig in list(self._buckets):
            pending = sorted(self._buckets[sig], key=lambda r:
                             (r.urgency, r.seq))
            while cfg.max_batch is not None and \
                    len(pending) >= cfg.max_batch:
                batches.append(_Batch(pending[:cfg.max_batch],
                                      DISPATCH_FULL))
                pending = pending[cfg.max_batch:]
            if pending and pending[0].deadline is not None and \
                    pending[0].deadline - now <= cfg.dispatch_margin:
                batches.append(_Batch(pending, DISPATCH_DEADLINE))
                pending = []
            self._prune(sig, pending)
        self._depth -= sum(len(b.requests) for b in batches)
        batches.sort(key=lambda b: b.urgency)
        return batches

    def force_all(self) -> List[_Batch]:
        """Pop EVERYTHING as one whole-bucket batch per signature (the
        ``drain``/flush path — batch sizes ignore ``max_batch`` so a
        manual flush stays one fused dispatch per shape bucket)."""
        batches = [_Batch(reqs, DISPATCH_DRAIN)
                   for reqs in self._buckets.values() if reqs]
        self._buckets.clear()
        self._depth = 0
        batches.sort(key=lambda b: b.urgency)
        return batches

    def _prune(self, sig: tuple, keep: List[_Request]) -> None:
        if keep:
            self._buckets[sig] = keep
        else:
            self._buckets.pop(sig, None)


class Dispatcher:
    """Executes one ready batch as ONE fused PipelineEngine dispatch.

    Stacks the batch's keys/A/B for the engine's batched/vmapped mode,
    runs the work's plan (or summary spec) through the shared executable
    cache, slices the batched result back out per request, and resolves
    the futures — bit-identical to dispatching each request alone.
    ``pad='pow2'`` replicates the last request up to the next power of two
    before stacking (and discards the padded lanes), bounding the number
    of batch-size executable signatures under variable occupancy;
    replicated lanes cannot move a quality gate because the gate takes a
    max over the batch and duplicates add no new values."""

    def __init__(self, engine: pipeline.PipelineEngine, pad: str = "none"):
        if pad not in ("none", "pow2"):
            raise ValueError(f"pad must be 'none' or 'pow2', got {pad!r}")
        self.engine = engine
        self.pad = pad

    def _padded(self, reqs: List[_Request]) -> List[_Request]:
        if self.pad == "none":
            return reqs
        width = 1 << (len(reqs) - 1).bit_length()
        return reqs + [reqs[-1]] * (width - len(reqs))

    def dispatch(self, batch: _Batch, dispatch_seq: int, now: float) -> None:
        """Run the batch and resolve every member's future."""
        reqs = batch.requests
        lanes = self._padded(reqs)
        keys = jnp.stack([r.key for r in lanes])
        A = jnp.stack([r.A for r in lanes])
        B = jnp.stack([r.B for r in lanes])
        work = reqs[0].work
        if isinstance(work, SummaryWork):
            out = self.engine.summarize(work.spec, keys, A, B, work.tuning)
        else:
            out = self.engine.run(work.plan, keys, A, B)
        for i, req in enumerate(reqs):
            sliced = jax.tree.map(lambda x, i=i: x[i], out)
            req.future._resolve(sliced, dispatch_seq, now)


class ServingLoop:
    """The serving stack: clock + Scheduler + Dispatcher + stats.

    ``submit`` is non-blocking admission (returns a ``ServeFuture`` or
    raises ``Rejected`` — the backpressure signal); ``poll`` advances the
    loop one step (shed expired, dispatch ready); ``drain`` synchronously
    force-flushes everything queued. ``start``/``stop`` run ``poll`` on a
    daemon thread for async serving — admission and result futures are
    thread-safe, and dispatches happen outside the queue lock so slow
    device work never blocks admission.
    """

    def __init__(self, *, engine: Optional[pipeline.PipelineEngine] = None,
                 config: LoopConfig = LoopConfig(),
                 clock: Callable[[], float] = time.monotonic):
        self.engine = engine if engine is not None else pipeline.get_engine()
        self.config = config
        self.clock = clock
        self.scheduler = Scheduler(config)
        self.dispatcher = Dispatcher(self.engine, pad=config.pad)
        self.stats = LoopStats()
        self._lock = threading.Lock()
        self._seq = itertools.count()
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    @property
    def depth(self) -> int:
        """Currently queued requests (the backpressure observable)."""
        with self._lock:
            return self.scheduler.depth

    # -- admission ---------------------------------------------------------

    def submit(self, key: jax.Array, A: jax.Array, B: jax.Array, *,
               work: Union[SummaryWork, PipelineWork],
               tenant: Optional[Union[int, str]] = None,
               deadline: Optional[float] = None) -> ServeFuture:
        """Admit one request; returns its future immediately.

        ``tenant`` namespaces the request key through
        ``pipeline.tenant_key`` before batching (None leaves the key
        untouched — bit-compatible with pre-tenant behavior).
        ``deadline`` is the request's SLO budget in seconds from arrival
        (None uses ``config.default_deadline``); the scheduler
        force-dispatches a partial batch rather than let it lapse. Raises
        ``Rejected(SHED_QUEUE_FULL)`` when the queue bound is hit.
        """
        now = self.clock()
        if tenant is not None:
            key = pipeline.tenant_key(key, tenant)
        if deadline is None:
            deadline = self.config.default_deadline
        seq = next(self._seq)
        req = _Request(
            seq=seq, key=key, A=A, B=B, work=work, arrival=now,
            deadline=None if deadline is None else now + deadline,
            future=ServeFuture(seq))
        with self._lock:
            try:
                self.scheduler.admit(req)
            except Rejected as exc:
                self.stats.shed[exc.reason] += 1
                req.future._reject(exc, now)
                raise
            self.stats.admitted += 1
        return req.future

    # -- the loop body -----------------------------------------------------

    def poll(self) -> int:
        """One scheduling step: shed expired requests, then dispatch every
        ready batch (EDF order). Returns the number of dispatches."""
        now = self.clock()
        with self._lock:
            expired = self.scheduler.shed_expired(now)
            for req in expired:
                self.stats.shed[SHED_WAIT_EXCEEDED] += 1
            batches = self.scheduler.ready(now)
        for req in expired:
            req.future._reject(Rejected(
                SHED_WAIT_EXCEEDED,
                f"request {req.seq} waited past max_wait="
                f"{self.config.max_wait}s"), now)
        return self._dispatch_batches(batches)

    def drain(self) -> int:
        """Force-dispatch everything queued, one fused dispatch per shape
        bucket regardless of batch-size limits (the synchronous flush
        path). Returns the number of dispatches."""
        with self._lock:
            batches = self.scheduler.force_all()
        return self._dispatch_batches(batches)

    def _dispatch_batches(self, batches: List[_Batch]) -> int:
        for batch in batches:
            with self._lock:
                self.stats.dispatches += 1
                dispatch_seq = self.stats.dispatches
                self.stats.batched_requests += len(batch.requests)
                self.stats.dispatched[batch.trigger] += 1
            self.dispatcher.dispatch(batch, dispatch_seq, self.clock())
            with self._lock:
                self.stats.completed += len(batch.requests)
        return len(batches)

    # -- background pump ---------------------------------------------------

    def start(self, interval: float = 1e-3) -> None:
        """Pump ``poll`` on a daemon thread every ``interval`` seconds —
        async serving: callers just ``submit`` and wait on futures."""
        if self._thread is not None:
            raise RuntimeError("serving loop already started")
        self._stop.clear()

        def pump():
            while not self._stop.is_set():
                self.poll()
                self._stop.wait(interval)

        self._thread = threading.Thread(target=pump, daemon=True,
                                        name="serving-loop")
        self._thread.start()

    def stop(self, *, drain: bool = True) -> None:
        """Stop the background pump (optionally draining what's queued)."""
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join()
        self._thread = None
        if drain:
            self.drain()


class ServedEstimate(NamedTuple):
    """One serviced request: the step-1 summary, the step-2/3 factors, and
    (for probe-carrying services with ``with_error``/quality-gated modes)
    the a-posteriori ErrorEngine estimate the rank gate read."""

    summary: SketchSummary
    factors: LowRankFactors
    error: Optional[ErrorEstimate] = None


def as_served(result: PipelineResult) -> ServedEstimate:
    """Repackage a per-request ``PipelineResult`` slice as the
    ``ServedEstimate`` the SketchService API serves."""
    return ServedEstimate(result.summary, result.estimate.factors,
                          error=result.estimate.error)
