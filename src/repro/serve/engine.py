"""Batched serving engine: preallocated KV caches, prefill + jitted decode
loop, greedy or temperature sampling."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.factory import Model


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0       # 0 -> greedy
    seed: int = 0


class Engine:
    def __init__(self, model: Model, params, cfg: ServeConfig = ServeConfig()):
        self.model = model
        self.params = params
        self.cfg = cfg
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)

    def _sample(self, key, logits):
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.cfg.temperature, axis=-1).astype(jnp.int32)

    def generate(self, batch: Dict[str, jax.Array]) -> jax.Array:
        """batch['tokens']: (B, P) prompts (+ stub-frontend aux inputs).
        Returns (B, P + max_new_tokens) token matrix."""
        tokens = batch["tokens"]
        B, P = tokens.shape
        total = P + self.cfg.max_new_tokens
        caches = self.model.init_cache(B, total)
        logits, caches = self._prefill(self.params, batch, caches)
        key = jax.random.PRNGKey(self.cfg.seed)
        out = [tokens]
        cur = self._sample(key, logits[:, -1, :])[:, None]
        for t in range(self.cfg.max_new_tokens - 1):
            out.append(cur)
            logits, caches = self._decode(self.params, caches, cur,
                                          jnp.int32(P + t))
            key = jax.random.fold_in(key, t)
            cur = self._sample(key, logits[:, -1, :])[:, None]
        out.append(cur)
        return jnp.concatenate(out, axis=1)
