"""Batched serving engines.

``Engine``       — LM serving: preallocated KV caches, prefill + jitted
                   decode loop, greedy or temperature sampling.
``SketchService`` — sketch serving: shape-bucketed micro-batching front-end
                   for one-pass (A, B) requests. ``flush()`` returns each
                   request's summary; ``flush_factors(r)`` runs the full
                   two-engine pipeline (SummaryEngine sketch, then
                   EstimationEngine completion) and returns each request's
                   top-r factors of A^T B — each shape bucket is ONE batched
                   ``build_summary`` dispatch chained into ONE batched
                   ``estimate_product`` dispatch.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.estimation_engine import estimate_product
from repro.core.summary_engine import build_summary
from repro.core.types import LowRankFactors, SketchSummary
from repro.models.factory import Model


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0       # 0 -> greedy
    seed: int = 0


class Engine:
    def __init__(self, model: Model, params, cfg: ServeConfig = ServeConfig()):
        self.model = model
        self.params = params
        self.cfg = cfg
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)

    def _sample(self, key, logits):
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.cfg.temperature, axis=-1).astype(jnp.int32)

    def generate(self, batch: Dict[str, jax.Array]) -> jax.Array:
        """batch['tokens']: (B, P) prompts (+ stub-frontend aux inputs).
        Returns (B, P + max_new_tokens) token matrix."""
        tokens = batch["tokens"]
        B, P = tokens.shape
        total = P + self.cfg.max_new_tokens
        caches = self.model.init_cache(B, total)
        logits, caches = self._prefill(self.params, batch, caches)
        key = jax.random.PRNGKey(self.cfg.seed)
        out = [tokens]
        cur = self._sample(key, logits[:, -1, :])[:, None]
        for t in range(self.cfg.max_new_tokens - 1):
            out.append(cur)
            logits, caches = self._decode(self.params, caches, cur,
                                          jnp.int32(P + t))
            key = jax.random.fold_in(key, t)
            cur = self._sample(key, logits[:, -1, :])[:, None]
        out.append(cur)
        return jnp.concatenate(out, axis=1)


class SketchService:
    """Micro-batching front-end for one-pass summary requests.

    Serving scenario: many concurrent callers each need the step-1 summary of
    their own (A, B) pair (per-layer gradients, per-tenant co-occurrence
    shards, ...). Dispatching them one by one wastes accelerator launches;
    ``SketchService`` queues requests, buckets them by shape, and flushes each
    bucket as ONE batched ``build_summary`` dispatch (the engine's vmapped
    mode), preserving per-request keys — results are bit-identical to
    dispatching each request alone.

    >>> svc = SketchService(k=128, backend="scan")
    >>> t0 = svc.submit(key0, A0, B0)
    >>> t1 = svc.submit(key1, A1, B1)
    >>> out = svc.flush()              # {ticket: SketchSummary}
    >>> # or the full pipeline: sketch -> estimate, top-r factors per request
    >>> fac = svc.flush_factors(r=5)   # {ticket: ServedEstimate}
    """

    def __init__(self, k: int = 128, *, method: str = "gaussian",
                 backend: str = "scan", block: int = 1024,
                 precision: Optional[str] = None):
        self.k = k
        self.method = method
        self.backend = backend
        self.block = block
        self.precision = precision
        self._queue: List[Tuple[int, jax.Array, jax.Array, jax.Array]] = []
        self._next_ticket = 0

    def submit(self, key: jax.Array, A: jax.Array, B: jax.Array) -> int:
        """Queue one (A, B) pair under its own key; returns a ticket."""
        assert A.ndim == 2 and B.ndim == 2 and A.shape[0] == B.shape[0], \
            (A.shape, B.shape)
        ticket = self._next_ticket
        self._next_ticket += 1
        self._queue.append((ticket, key, A, B))
        return ticket

    @property
    def pending(self) -> int:
        return len(self._queue)

    def _drain_buckets(self):
        """Group queued requests by shape+dtype signature and clear the queue.
        Buckets key on shapes AND dtypes (of A, B, and the key) so stacking
        never promotes a request's arrays — results stay identical to solo
        dispatches."""
        buckets = collections.defaultdict(list)
        for ticket, key, A, B in self._queue:
            sig = (A.shape, str(A.dtype), B.shape, str(B.dtype),
                   key.shape, str(key.dtype))
            buckets[sig].append((ticket, key, A, B))
        self._queue = []
        return buckets

    def _stack_and_sketch(self, requests):
        """Stack one bucket's requests and run the batched step-1 dispatch.
        Returns (tickets, keys, A, B, batched summaries)."""
        tickets = [req[0] for req in requests]
        keys = jnp.stack([req[1] for req in requests])
        A = jnp.stack([req[2] for req in requests])
        B = jnp.stack([req[3] for req in requests])
        summaries = build_summary(
            keys, A, B, self.k, method=self.method, backend=self.backend,
            block=self.block, precision=self.precision)
        return tickets, keys, A, B, summaries

    def flush(self) -> Dict[int, SketchSummary]:
        """One batched SummaryEngine dispatch per bucket; drains the queue."""
        out: Dict[int, SketchSummary] = {}
        for requests in self._drain_buckets().values():
            tickets, _, _, _, batched = self._stack_and_sketch(requests)
            for i, ticket in enumerate(tickets):
                out[ticket] = jax.tree.map(lambda x: x[i], batched)
        return out

    def flush_factors(self, r: int, *, m: Optional[int] = None, T: int = 6,
                      est_method: str = "rescaled_jl",
                      est_backend: str = "jit",
                      use_splits: bool = False) -> Dict[int, "ServedEstimate"]:
        """The sketch->estimate pipeline: per shape bucket, one batched
        ``build_summary`` dispatch feeds one batched ``estimate_product``
        dispatch, and each request gets the top-r factors of its A^T B
        (plus the summary, for callers that also want the side information).

        Each request's estimation key is ``fold_in(request key, 1)`` — a
        fixed derivation from the key the caller submitted, so results are
        reproducible per request and independent of bucket composition.
        ``est_method='lela_waltmin'`` stacks the queued (A, B) pairs as the
        exact second pass (the service holds them anyway while queueing).
        """
        out: Dict[int, ServedEstimate] = {}
        for requests in self._drain_buckets().values():
            tickets, keys, A, B, summaries = self._stack_and_sketch(requests)
            est_keys = jax.vmap(lambda kk: jax.random.fold_in(kk, 1))(keys)
            exact = (A, B) if est_method == "lela_waltmin" else None
            ests = estimate_product(
                est_keys, summaries, r, method=est_method,
                backend=est_backend, m=m, T=T, use_splits=use_splits,
                exact_pair=exact)
            for i, ticket in enumerate(tickets):
                out[ticket] = ServedEstimate(
                    jax.tree.map(lambda x: x[i], summaries),
                    jax.tree.map(lambda x: x[i], ests.factors))
        return out


class ServedEstimate(NamedTuple):
    """One serviced request: the step-1 summary and the step-2/3 factors."""
    summary: SketchSummary
    factors: LowRankFactors
