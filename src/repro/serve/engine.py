"""Batched serving engines.

``Engine``       — LM serving: preallocated KV caches, prefill + jitted
                   decode loop, greedy or temperature sampling.
``SketchService`` — sketch serving: a thin synchronous adapter over the
                   continuously-batched ``serve.scheduler.ServingLoop``.
                   ``submit``/``flush`` keep their historical bit-exact
                   semantics (each shape bucket is ONE plan-compiled fused
                   dispatch through the compile-once
                   ``core.pipeline.PipelineEngine`` cache), while the loop
                   underneath adds admission control, SLO deadlines,
                   backpressure/load-shedding and multi-tenant key
                   namespacing for async callers (see docs/serving.md).
                   ``flush()`` returns each request's summary;
                   ``flush_factors(r)`` the top-r factors of each A^T B.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import pipeline
from repro.core.streaming import (
    StreamingSummarizer, StreamState, WindowedSummarizer, WindowState)
from repro.core.types import SketchSummary
from repro.models.factory import Model
from repro.serve.scheduler import (
    PipelineWork, ServedEstimate, ServeFuture, ServingLoop, SummaryWork,
    as_served)

__all__ = ["Engine", "ServeConfig", "SketchService", "ServedEstimate"]


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0       # 0 -> greedy
    seed: int = 0


class Engine:
    def __init__(self, model: Model, params, cfg: ServeConfig = ServeConfig()):
        self.model = model
        self.params = params
        self.cfg = cfg
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)

    def _sample(self, key, logits):
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.cfg.temperature, axis=-1).astype(jnp.int32)

    def generate(self, batch: Dict[str, jax.Array]) -> jax.Array:
        """batch['tokens']: (B, P) prompts (+ stub-frontend aux inputs).
        Returns (B, P + max_new_tokens) token matrix."""
        tokens = batch["tokens"]
        B, P = tokens.shape
        total = P + self.cfg.max_new_tokens
        caches = self.model.init_cache(B, total)
        logits, caches = self._prefill(self.params, batch, caches)
        key = jax.random.PRNGKey(self.cfg.seed)
        out = [tokens]
        cur = self._sample(key, logits[:, -1, :])[:, None]
        for t in range(self.cfg.max_new_tokens - 1):
            out.append(cur)
            logits, caches = self._decode(self.params, caches, cur,
                                          jnp.int32(P + t))
            key = jax.random.fold_in(key, t)
            cur = self._sample(key, logits[:, -1, :])[:, None]
        out.append(cur)
        return jnp.concatenate(out, axis=1)


@dataclasses.dataclass
class _StreamSession:
    """One live accumulator: its summarizer config, state, and append cursor.

    ``summarizer``/``state`` are either a ``StreamingSummarizer`` driving a
    ``StreamState`` (vanilla or decayed) or a ``WindowedSummarizer`` driving
    a ``WindowState`` — both expose the same update/finalize surface, so
    the session methods never branch on the variant except in
    ``advance_stream`` (decay tick vs. window slide)."""
    key: jax.Array
    summarizer: Union[StreamingSummarizer, WindowedSummarizer]
    state: Union[StreamState, WindowState]
    next_row: int
    rows_seen: int


class SketchService:
    """Micro-batching front-end for one-pass summary requests.

    Serving scenario: many concurrent callers each need the step-1 summary of
    their own (A, B) pair (per-layer gradients, per-tenant co-occurrence
    shards, ...). Dispatching them one by one wastes accelerator launches;
    ``SketchService`` queues requests and flushes them through a
    ``ServingLoop`` — the scheduler buckets them by shape and each bucket
    dispatches as ONE plan-compiled executable from the shared
    ``PipelineEngine`` cache (the engine's batched/vmapped mode), preserving
    per-request keys — results are bit-identical to dispatching each request
    alone, and a warm plan (repeat shapes) is one cache lookup + one fused
    dispatch per bucket, zero retraces. ``submit(..., tenant=)`` namespaces
    a request's randomness under a tenant id (``pipeline.tenant_key``)
    without splitting the warm executable cache; async callers wanting
    continuous batching, deadlines and load-shedding can drive the
    ``ServingLoop`` directly (``service.loop``, docs/serving.md).

    Two request styles share the service:

    * **one-shot**: ``submit(key, A, B)`` whole pairs, then ``flush()`` /
      ``flush_factors(r)`` — batched micro-dispatch per shape bucket;
    * **streaming sessions**: ``open_stream(key, d, n1, n2)`` then
      ``append(sid, A_chunk, B_chunk)`` row chunks over time; ``query(sid)``
      reads the live accumulator's summary at any point and
      ``stream_factors(sid, r)`` runs the same estimation pipeline (and the
      same per-request key derivation) ``flush_factors`` uses — appending a
      pair chunk-by-chunk then querying equals submitting it whole
      (bit-identical when the appended chunk size matches the service
      ``block``; see docs/streaming.md).

    >>> import jax
    >>> key = jax.random.PRNGKey(0)
    >>> A = jax.random.normal(key, (64, 6))
    >>> B = jax.random.normal(jax.random.fold_in(key, 1), (64, 4))
    >>> svc = SketchService(k=8, backend="scan", block=32)
    >>> t0 = svc.submit(key, A, B)                 # one-shot request
    >>> svc.flush()[t0].A_sketch.shape
    (8, 6)
    >>> sid = svc.open_stream(key, 64, 6, 4)       # streaming session
    >>> svc.append(sid, A[:32], B[:32])
    32
    >>> svc.append(sid, A[32:], B[32:])
    64
    >>> svc.query(sid).A_sketch.shape              # live accumulator summary
    (8, 6)
    >>> est = svc.stream_factors(sid, r=2, m=64, T=2)
    >>> est.factors.U.shape
    (6, 2)
    """

    def __init__(self, k: int = 128, *, method: str = "gaussian",
                 backend: str = "scan", block: int = 1024,
                 precision: Optional[str] = None, probes: int = 0,
                 cosketch: int = 0, tuning=None,
                 engine: Optional[pipeline.PipelineEngine] = None,
                 loop: Optional[ServingLoop] = None):
        self.k = k
        self.method = method
        self.backend = backend
        self.block = block
        self.precision = precision
        self.probes = probes
        self.cosketch = cosketch      # refinement co-sketch width (0 = off)
        self.tuning = tuning          # Optional[kernels.tuning.TuningSpec]
        if loop is not None and engine is not None and \
                loop.engine is not engine:
            raise ValueError(
                "pass engine= OR loop=, not a loop pinned to a different "
                "engine — the service dispatches through loop.engine")
        self.loop = loop if loop is not None else ServingLoop(engine=engine)
        self.engine = self.loop.engine
        self._queue: List[Tuple[int, jax.Array, jax.Array, jax.Array,
                                Optional[Union[int, str]],
                                Optional[float]]] = []
        self._next_ticket = 0
        self._streams: Dict[int, _StreamSession] = {}
        self._next_stream = 0

    def submit(self, key: jax.Array, A: jax.Array, B: jax.Array, *,
               tenant: Optional[Union[int, str]] = None,
               deadline: Optional[float] = None) -> int:
        """Queue one (A, B) pair under its own key; returns a ticket.

        ``tenant`` namespaces the request's randomness under a tenant id
        (folded via ``pipeline.tenant_key`` at dispatch; None preserves
        the historical key derivation bit-for-bit). ``deadline`` is the
        request's SLO budget in seconds, honored when the underlying
        ``ServingLoop`` is polled asynchronously (a synchronous ``flush``
        dispatches everything regardless). Raises ``ValueError`` (never a
        strippable ``assert``) on non-2-D inputs or mismatched streamed
        row dimensions.
        """
        if jnp.ndim(A) != 2 or jnp.ndim(B) != 2:
            raise ValueError(
                f"submit expects 2-D (d, n) matrices, got A with shape "
                f"{jnp.shape(A)} and B with shape {jnp.shape(B)}")
        if A.shape[0] != B.shape[0]:
            raise ValueError(
                f"A and B must share the streamed row dimension d, got "
                f"A with shape {A.shape} vs B with shape {B.shape}")
        ticket = self._next_ticket
        self._next_ticket += 1
        self._queue.append((ticket, key, A, B, tenant, deadline))
        return ticket

    @property
    def pending(self) -> int:
        return len(self._queue)

    def _enqueue(self, work) -> Dict[int, ServeFuture]:
        """Hand the queued requests to the serving loop under one work spec
        (flush decides summary-only vs full-pipeline at flush time)."""
        futures = {}
        for ticket, key, A, B, tenant, deadline in self._queue:
            futures[ticket] = self.loop.submit(
                key, A, B, work=work, tenant=tenant, deadline=deadline)
        self._queue = []
        return futures

    def _sketch_spec(self) -> pipeline.SketchSpec:
        """The service's step-1 configuration as a declarative plan stage."""
        return pipeline.SketchSpec(
            method=self.method, backend=self.backend, k=self.k,
            block=self.block, precision=self.precision, probes=self.probes,
            cosketch=self.cosketch)

    def flush(self) -> Dict[int, SketchSummary]:
        """One cached batched summary executable per bucket; drains the
        queue. An empty queue returns ``{}`` without touching the engine."""
        if not self._queue:
            return {}
        futures = self._enqueue(SummaryWork(self._sketch_spec(),
                                            tuning=self.tuning))
        self.loop.drain()
        return {ticket: f.result() for ticket, f in futures.items()}

    def flush_factors(self, r=None, *, tol: Optional[float] = None,
                      r_max: Optional[int] = None, m: Optional[int] = None,
                      T: int = 6, est_method: str = "rescaled_jl",
                      est_backend: str = "jit", use_splits: bool = False,
                      with_error: bool = False,
                      refine=None) -> Dict[int, "ServedEstimate"]:
        """The sketch->estimate pipeline: per shape bucket, ONE plan-compiled
        fused executable (batched summary + estimation + optional error in a
        single dispatch, cached across flushes), and each request gets the
        top-r factors of its A^T B (plus the summary, for callers that also
        want the side information).

        Rank selection is either fixed (``r=<int>``) or quality-gated:
        ``r='auto'`` with ``tol=<relative Frobenius error>`` reads each
        bucket's per-rank error curve ONCE (a single fused summary+SVD-sweep
        dispatch — the ``adaptive_rank`` factorization) to fast-forward the
        doubling schedule past ranks that provably fail for some request
        (capped at ``r_max``), then gates on the *served* factors'
        a-posteriori estimate — escalating further only if the curve was
        optimistic about the completion method — so every request's
        ``ServedEstimate.error`` meets ``tol`` whenever a rank within the
        cap can. The common case is one estimation dispatch per bucket
        instead of a dispatch + blocking host sync per doubling round.
        Quality-gated (and ``with_error=True``) serving needs a
        probe-carrying service (``SketchService(probes=p)``).

        Each request's estimation key is ``fold_in(request key, 1)`` — a
        fixed derivation from the key the caller submitted, so results are
        reproducible per request and independent of bucket composition.
        ``est_method='lela_waltmin'`` stacks the queued (A, B) pairs as the
        exact second pass (the service holds them anyway while queueing).
        ``est_method='power'`` with ``refine=RefineSpec(...)`` serves
        refined reconstructions (needs ``SketchService(cosketch=s)``); the
        spec joins the plan, so warm pinned-refinement serving never
        re-traces.
        """
        gated = self._check_gate(r, tol, with_error)
        if not self._queue:
            return {}
        plan = self._plan(r=r if not gated else None, tol=tol, r_max=r_max,
                          m=m, T=T, est_method=est_method,
                          est_backend=est_backend, use_splits=use_splits,
                          with_error=with_error, gated=gated, refine=refine)
        futures = self._enqueue(PipelineWork(plan))
        self.loop.drain()
        return {ticket: as_served(f.result())
                for ticket, f in futures.items()}

    def _check_gate(self, r, tol, with_error) -> bool:
        """Validate a rank-selection request; True when quality-gated
        (``r='auto'``/tol-driven) — ONE rulebook for flush_factors and
        stream_factors."""
        gated = (r == "auto" or (r is None and tol is not None))
        if gated and tol is None:
            raise ValueError("r='auto' needs tol= (the relative-error gate)")
        if not gated and not isinstance(r, int):
            raise ValueError(f"r must be an int or 'auto', got {r!r}")
        if (gated or with_error) and self.probes <= 0:
            raise ValueError(
                "quality-gated/with_error serving needs a probe-carrying "
                "service — construct SketchService(probes=p)")
        return gated

    def _plan(self, *, r, tol, r_max, m, T, est_method, est_backend,
              use_splits, with_error, gated,
              refine=None) -> pipeline.PipelinePlan:
        """One flush/stream request as a declarative plan (the executable-
        cache key). Gate-only knobs are normalized away on the fixed-rank
        path so equivalent requests share cache entries."""
        rank = (pipeline.RankPolicy(r=None, tol=tol, r_max=r_max) if gated
                else pipeline.RankPolicy(r=r))
        return pipeline.PipelinePlan(
            sketch=self._sketch_spec(),
            estimation=pipeline.EstimationSpec(
                method=est_method, backend=est_backend, m=m, T=T,
                use_splits=use_splits),
            rank=rank, key_layout="service", with_error=with_error,
            tuning=self.tuning, refine=refine)

    # -- streaming accumulator sessions ------------------------------------

    def open_stream(self, key: jax.Array, d: int, n1: int, n2: int, *,
                    state: Optional[Union[StreamState, WindowState]] = None,
                    decay: float = 1.0,
                    window: Optional[int] = None) -> int:
        """Open a stateful accumulator session for a (d, n1, n2) stream.

        The session inherits the service's ``k``/``method``/``precision``.
        Pass ``state`` (e.g. restored via ``ckpt.checkpoint
        .restore_stream_state``) to resume a previously checkpointed pass
        instead of starting empty — it must match this session's shapes and
        carry the same base key (the sketch randomness lives in the state;
        a mismatched key would silently break the documented parity between
        ``stream_factors`` and one-shot ``flush_factors``). Returns the
        stream id.

        Drifting streams (docs/streaming.md): ``decay=gamma`` opens an
        exponentially-decayed session (``advance_stream`` ticks its clock);
        ``window=b`` opens a sliding-window session over ``b`` epochs
        (``advance_stream`` slides it; ``d`` becomes the per-epoch row
        space and the append cursor restarts each epoch). The two policies
        are mutually exclusive. ``decay=1.0`` / ``window=None`` is
        bit-identical to the historical session path. To resume a windowed
        session pass a ``WindowState`` from ``restore_window_state``.
        """
        if decay != 1.0 and window is not None:
            raise ValueError(
                f"pass decay= OR window=, not both (got decay={decay}, "
                f"window={window}): a session forgets by exponential decay "
                f"or by sliding window, not both at once")
        if window is not None:
            return self._open_window_stream(key, d, n1, n2,
                                            n_buckets=window, state=state)
        summ = StreamingSummarizer(self.k, method=self.method,
                                   precision=self.precision,
                                   probes=self.probes,
                                   cosketch=self.cosketch, decay=decay)
        if state is None:
            state = summ.init(key, (d, n1, n2))
        elif isinstance(state, WindowState):
            raise ValueError(
                "resumed state is a WindowState but the session was opened "
                "without window= — pass window=<n_buckets> to resume a "
                "windowed session")
        else:
            shapes = (state.A_acc.shape, state.B_acc.shape,
                      int(state.d_total))
            want = ((self.k, n1), (self.k, n2), d)
            if shapes != want:
                raise ValueError(
                    f"resumed state does not match this session: state has "
                    f"(A_acc, B_acc, d_total) = {shapes}, session needs "
                    f"{want}")
            if state.n_probes != self.probes:
                raise ValueError(
                    f"resumed state carries {state.n_probes} probe columns "
                    f"but the service is configured with probes="
                    f"{self.probes} — probe blocks cannot be grown or "
                    f"dropped mid-pass")
            if state.n_cosketch != self.cosketch:
                raise ValueError(
                    f"resumed state carries a co-sketch block of width "
                    f"{state.n_cosketch} but the service is configured with "
                    f"cosketch={self.cosketch} — co-sketch blocks cannot be "
                    f"grown or dropped mid-pass")
            if state.key is not None and not jnp.array_equal(
                    jax.random.key_data(state.key)
                    if jnp.issubdtype(state.key.dtype, jax.dtypes.prng_key)
                    else state.key,
                    jax.random.key_data(key)
                    if jnp.issubdtype(key.dtype, jax.dtypes.prng_key)
                    else key):
                raise ValueError(
                    "resumed state carries a different base key than the "
                    "session key — sketch and estimation randomness would "
                    "disagree; pass the key the pass was started with")
            if (self.method == "srht") != (state.signs is not None):
                raise ValueError(
                    f"resumed state method does not match the service's "
                    f"method={self.method!r}")
            if state.decayed != (decay < 1.0):
                raise ValueError(
                    f"resumed state {'carries' if state.decayed else 'has no'}"
                    f" decay clock but the session was opened with "
                    f"decay={decay} — a pass cannot change its decay policy "
                    f"mid-stream")
            if state.decayed and float(state.decay_rate) != float(decay):
                raise ValueError(
                    f"resumed state was decayed at rate "
                    f"{float(state.decay_rate)} but the session was opened "
                    f"with decay={decay}")
        sid = self._next_stream
        self._next_stream += 1
        self._streams[sid] = _StreamSession(
            key=key, summarizer=summ, state=state,
            next_row=int(state.row_high), rows_seen=int(state.rows_seen))
        return sid

    def _open_window_stream(self, key, d, n1, n2, *, n_buckets, state) -> int:
        summ = WindowedSummarizer(self.k, n_buckets, method=self.method,
                                  precision=self.precision,
                                  probes=self.probes,
                                  cosketch=self.cosketch)
        if state is None:
            state = summ.init(key, (d, n1, n2))
        else:
            if not isinstance(state, WindowState):
                raise ValueError(
                    f"resuming a windowed session needs a WindowState from "
                    f"restore_window_state, got {type(state).__name__}")
            if len(state.buckets) != n_buckets:
                raise ValueError(
                    f"resumed window carries {len(state.buckets)} buckets "
                    f"but the session was opened with window={n_buckets} — "
                    f"window rings cannot be resized on resume")
            ref = state.buckets[0]
            shapes = (ref.A_acc.shape, ref.B_acc.shape, int(ref.d_total))
            want = ((self.k, n1), (self.k, n2), d)
            if shapes != want:
                raise ValueError(
                    f"resumed window does not match this session: buckets "
                    f"have (A_acc, B_acc, d_total) = {shapes}, session "
                    f"needs {want}")
            if ref.n_probes != self.probes:
                raise ValueError(
                    f"resumed window carries {ref.n_probes} probe columns "
                    f"but the service is configured with probes="
                    f"{self.probes}")
            if ref.n_cosketch != self.cosketch:
                raise ValueError(
                    f"resumed window carries a co-sketch block of width "
                    f"{ref.n_cosketch} but the service is configured with "
                    f"cosketch={self.cosketch}")
            if not jnp.array_equal(state.key, key):
                raise ValueError(
                    "resumed window carries a different base key than the "
                    "session key — bucket keys fold from the base key, so "
                    "the randomness would disagree; pass the key the "
                    "window was started with")
        sid = self._next_stream
        self._next_stream += 1
        slot = int(state.head) % n_buckets
        self._streams[sid] = _StreamSession(
            key=key, summarizer=summ, state=state,
            next_row=int(state.buckets[slot].row_high),
            rows_seen=sum(int(b.rows_seen) for b in state.buckets))
        return sid

    def advance_stream(self, stream_id: int, dt: int = 1) -> None:
        """Tick a drifting session's time axis by ``dt``.

        Decayed sessions advance their logical clock (each tick multiplies
        previously absorbed mass by the session's ``decay``, settled
        lazily); windowed sessions slide ``dt`` epochs (the oldest buckets
        expire and the append cursor restarts at 0 for the new head epoch).
        Raises ``ValueError`` on a vanilla session — it has no time axis;
        open the stream with ``decay=`` or ``window=``. Raises ``KeyError``
        naming the id when the stream is unknown or closed.
        """
        sess = self._session(stream_id)
        if isinstance(sess.summarizer, WindowedSummarizer):
            sess.state = sess.summarizer.slide(sess.state, dt)
            sess.next_row = 0
        elif sess.summarizer.decay < 1.0:
            sess.state = sess.summarizer.advance(sess.state, dt)
        else:
            raise ValueError(
                f"stream {stream_id} has no time axis — open it with "
                f"decay= or window= to advance/slide it")

    def _session(self, stream_id: int) -> _StreamSession:
        """The live session for an id, or a descriptive ``KeyError`` — an
        unknown/already-closed id must name itself, not surface as a bare
        dict miss."""
        try:
            return self._streams[stream_id]
        except KeyError:
            raise KeyError(
                f"unknown or closed stream id {stream_id!r} (open streams: "
                f"{sorted(self._streams)})") from None

    def append(self, stream_id: int, A_chunk: jax.Array, B_chunk: jax.Array,
               row_offset: Optional[int] = None) -> int:
        """Absorb one row chunk into the live accumulator.

        ``row_offset`` defaults to the session's cursor (contiguous
        ingestion); pass it explicitly for out-of-order chunk arrival.
        Returns total rows absorbed so far (a host-side count: appending
        never blocks on the device, keeping async dispatch overlapped).
        Raises ``KeyError`` naming the id when the stream is unknown or
        closed.
        """
        sess = self._session(stream_id)
        off = sess.next_row if row_offset is None else row_offset
        sess.state = sess.summarizer.update(sess.state, A_chunk, B_chunk, off)
        sess.next_row = max(sess.next_row, off + A_chunk.shape[0])
        sess.rows_seen += A_chunk.shape[0]
        return sess.rows_seen

    def append_async(self, stream_id: int, chunks, *,
                     prefetch: int = 2) -> int:
        """Absorb an iterator of ``(A_chunk, B_chunk)`` pairs with
        double-buffered host->device pipelining.

        Drives the session's accumulator through
        ``StreamingSummarizer.ingest``: up to ``prefetch`` upcoming chunks
        are staged onto the device (``jax.device_put``) while the fused
        update for the current chunk runs, so a long contiguous append
        approaches memory-bandwidth speed. Bit-identical to the equivalent
        ``append`` loop at the same chunk boundaries. Chunks are contiguous
        from the session cursor (windowed sessions ingest into the head
        epoch). Returns total rows absorbed so far (host-side count — the
        iterator is consumed, the device is never synced).
        """
        sess = self._session(stream_id)
        rows = 0

        def _counted():
            nonlocal rows
            for A_chunk, B_chunk in chunks:
                rows += A_chunk.shape[0]
                yield A_chunk, B_chunk

        sess.state = sess.summarizer.ingest(
            sess.state, _counted(), row_offset=sess.next_row,
            prefetch=prefetch)
        sess.next_row += rows
        sess.rows_seen += rows
        return sess.rows_seen

    def query(self, stream_id: int) -> SketchSummary:
        """Finalized summary of the live accumulator (non-destructive: the
        session keeps absorbing chunks afterwards)."""
        sess = self._session(stream_id)
        return sess.summarizer.finalize(sess.state)

    def export_stream(self, stream_id: int, *, wire=None,
                      tol: Optional[float] = None):
        """The live accumulator as a compressed wire image for transfer.

        Non-destructive. ``wire`` names a ``streaming.WireSpec`` precision
        (default lossless f32); ``tol`` instead runs the probe-measured
        gate (``streaming.choose_wire_spec`` — cheapest precision whose
        measured relative error fits; needs ``SketchService(probes=p)``).
        Windowed sessions export their merged window under the session's
        *base* key — the window's shared probe/co-sketch matrices derive
        from it, so the far side regenerates them correctly; the export is
        a query snapshot (ingestion resumes in the per-epoch buckets, not
        in the export). The bytes for the wire come from
        ``streaming.wire_pack`` on the returned image.
        """
        from repro.core import streaming
        sess = self._session(stream_id)
        state = sess.state
        if isinstance(sess.summarizer, WindowedSummarizer):
            state = sess.summarizer.merged(state)._replace(key=sess.key)
        if tol is not None:
            spec, _ = streaming.choose_wire_spec(state, tol)
        else:
            spec = "f32" if wire is None else wire
        return streaming.compress_state(state, spec)

    def stream_factors(self, stream_id: int, r=None, *,
                       tol: Optional[float] = None,
                       r_max: Optional[int] = None,
                       m: Optional[int] = None, T: int = 6,
                       est_method: str = "rescaled_jl",
                       est_backend: str = "jit",
                       use_splits: bool = False,
                       with_error: bool = False,
                       refine=None) -> ServedEstimate:
        """``flush_factors`` against the live accumulator: finalize the
        session's state and run the same compiled estimation path
        (``PipelineEngine.run_from_summary``) with the same per-request key
        derivation (``fold_in(session key, 1)``) — a stream fed
        chunk-by-chunk yields the same factors as the equivalent one-shot
        ``submit`` + ``flush_factors`` request. The same quality-gated mode
        is available: ``r='auto'`` with ``tol=`` gates this session's rank
        on its one-sweep error curve (needs ``SketchService(probes=p)``).
        Raises ``KeyError`` naming the id when the stream is unknown or
        closed."""
        sess = self._session(stream_id)
        gated = self._check_gate(r, tol, with_error)
        plan = self._plan(r=r if not gated else None, tol=tol, r_max=r_max,
                          m=m, T=T, est_method=est_method,
                          est_backend=est_backend, use_splits=use_splits,
                          with_error=with_error, gated=gated, refine=refine)
        summary = sess.summarizer.finalize(sess.state)
        est = self.engine.run_from_summary(plan, sess.key, summary)
        return ServedEstimate(summary, est.factors, error=est.error)

    def close_stream(self, stream_id: int) -> StreamState:
        """Tear down a session; returns its final state (checkpointable).
        Raises ``KeyError`` naming the id when the stream is unknown or
        already closed."""
        self._session(stream_id)            # descriptive KeyError path
        return self._streams.pop(stream_id).state
