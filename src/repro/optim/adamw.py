"""AdamW in pure JAX (no optax in this container): pytree state, optional
bf16 moments (halves optimizer HBM for the 100B+ configs), decoupled weight
decay, global-norm clipping."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    moment_dtype: Any = jnp.float32      # bf16 halves optimizer memory

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, self.moment_dtype)
        return AdamWState(jnp.zeros((), jnp.int32),
                          jax.tree.map(zeros, params),
                          jax.tree.map(zeros, params))

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.float32(self.lr)

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        if self.clip_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-12))
            grads = jax.tree.map(lambda g: g * scale, grads)

        b1, b2 = self.b1, self.b2
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
            mhat = m32 / bc1
            vhat = v32 / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if p.ndim >= 2 and self.weight_decay:
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            pnew = p.astype(jnp.float32) - lr * delta
            return (pnew.astype(p.dtype), m32.astype(self.moment_dtype),
                    v32.astype(self.moment_dtype))

        # flatten/unflatten (param trees contain tuples as *internal* nodes,
        # so tuple-leaf tricks would mis-fire)
        flat_p, treedef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state.mu)
        flat_v = jax.tree.leaves(state.nu)
        news = [upd(g, m, v, p)
                for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_params = treedef.unflatten([t[0] for t in news])
        new_mu = treedef.unflatten([t[1] for t in news])
        new_nu = treedef.unflatten([t[2] for t in news])
        return new_params, AdamWState(step, new_mu, new_nu)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2)
                        for l in jax.tree.leaves(tree)))
