"""SMP-PCA gradient compression — the paper as a distributed-training feature.

Setting: data-parallel workers w = 1..W each hold a local gradient G_w
(n_in x n_out) for every large dense layer; the update needs G = sum_w G_w.
Communicating G costs n_in*n_out per layer. Observe that G is literally the
paper's matrix product:

    A := vstack_w(I_{n_in})      (d = W*n_in, n1 = n_in)
    B := vstack_w(G_w)           (d = W*n_in, n2 = n_out)
    A^T B = sum_w G_w = G

and the rows of (A, B) are *already distributed* across workers exactly as in
the paper's Spark setting. One pass of Algorithm 1 over this stream:

    A~ = sum_w Pi_w                 (each worker's k x n_in slice of Pi)
    B~ = sum_w Pi_w G_w             (k x n_out)
    ||A_i|| = sqrt(W)               (known analytically)
    ||B_j||^2 = sum_w ||G_w[:, j]||^2

so the all-reduce payload is k*(n_in + n_out) + n_out floats instead of
n_in*n_out — the psum over workers IS the paper's treeAggregate. Every worker
then runs the identical (same-seeded) sampling + rescaled-JL + WAltMin
completion and applies the same rank-r gradient. PowerSGD-style error
feedback (residual accumulation into the next step's input) restores
convergence for what the rank-r approximation drops.

Because sketches are linear, microbatch gradient accumulation streams through
the same summary (the paper's arbitrary-order one-pass claim, at the
optimizer level).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.smppca import smppca_from_summary
from repro.core.summary_engine import identity_product_summary


class CompressionConfig(NamedTuple):
    rank: int = 8
    sketch_k: int = 128
    sample_factor: int = 8      # m = factor * (n1+n2) * rank
    min_dim: int = 64           # compress 2D leaves with min(dims) >= this
    als_iters: int = 4


class CompressionState(NamedTuple):
    err: Any                    # residual pytree (zeros where not compressed)
    step: jax.Array


MIN_DIM = 64


def _compressible(leaf) -> bool:
    """2D dense-layer grads, or scan-stacked (L, n1, n2) layer groups (the
    batched engine mode sketches all L layers in one dispatch)."""
    return leaf.ndim in (2, 3) and min(leaf.shape[-2:]) >= MIN_DIM


def init_state(grads_like) -> CompressionState:
    err = jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32) if _compressible(g)
        else jnp.zeros((), jnp.float32), grads_like)
    return CompressionState(err, jnp.zeros((), jnp.int32))


def _m_for(n1: int, n2: int, cfg: CompressionConfig) -> int:
    return int(cfg.sample_factor * (n1 + n2) * cfg.rank)


def compress_leaf(key: jax.Array, G: jax.Array, cfg: CompressionConfig,
                  axis: Optional[str] = None, n_workers: int = 1
                  ) -> jax.Array:
    """Compress one gradient matrix via SMP-PCA; returns the rank-r
    reconstruction. ``axis``: inside shard_map, psum the one-pass summary
    over DP workers (G is then each worker's *local* grad). A stacked
    (L, n1, n2) layer group compresses all L layers in one batched engine
    dispatch."""
    if G.ndim == 3:
        keys = jax.random.split(key, G.shape[0])
        return jax.vmap(lambda kk, g: compress_leaf(
            kk, g, cfg, axis=axis, n_workers=n_workers))(keys, G)
    n1, n2 = G.shape
    summary = identity_product_summary(
        key, G.astype(jnp.float32), cfg.sketch_k,
        axis=axis, n_workers=n_workers)
    res = smppca_from_summary(
        jax.random.fold_in(key, 1), summary, r=cfg.rank,
        m=_m_for(n1, n2, cfg), T=cfg.als_iters)
    return res.factors.U @ res.factors.V.T


def compress_grads(key: jax.Array, grads, state: CompressionState,
                   cfg: CompressionConfig = CompressionConfig(),
                   axis: Optional[str] = None, n_workers: int = 1):
    """Compress every eligible leaf. Returns (new_grads, new_state, stats).

    With ``axis`` set (inside shard_map over DP workers): input grads are
    *local*; output compressed grads are the identical global reconstruction
    on every worker; non-compressible leaves are psum-averaged normally.
    """
    flat, treedef = jax.tree.flatten(grads)
    eflat = jax.tree.leaves(state.err)
    out, err_new = [], []
    n_comp = 0
    saved_bytes = 0.0
    total_bytes = 0.0
    for i, (g, e) in enumerate(zip(flat, eflat)):
        total_bytes += g.size * 4
        if _compressible(g):
            kk = jax.random.fold_in(key, i)
            g_in = g.astype(jnp.float32) + e
            ghat = compress_leaf(kk, g_in, cfg, axis=axis,
                                 n_workers=n_workers)
            if axis is not None:
                ghat = ghat / n_workers     # mean-reduction convention
                resid = g_in - ghat
            else:
                resid = g_in - ghat
            out.append(ghat.astype(g.dtype))
            err_new.append(resid)
            n_comp += 1
            n1, n2 = g.shape[-2:]
            n_layers = g.shape[0] if g.ndim == 3 else 1
            saved_bytes += g.size * 4 - \
                4 * n_layers * (cfg.sketch_k * (n1 + n2) + n2)
        else:
            gg = jax.lax.pmean(g, axis) if axis is not None else g
            out.append(gg)
            err_new.append(jnp.zeros((), jnp.float32))
    stats = {"n_compressed": n_comp,
             "comm_fraction": 1.0 - saved_bytes / max(total_bytes, 1.0)}
    return (treedef.unflatten(out),
            CompressionState(treedef.unflatten(err_new), state.step + 1),
            stats)
