from repro.optim.adamw import AdamW, AdamWState, global_norm
from repro.optim.schedule import constant, warmup_cosine
