"""Compare two BENCH_*.json artifacts and annotate perf regressions.

    python tools/bench_compare.py BASELINE.json CURRENT.json [--threshold 0.2]

The CI trajectory gate: the bench-smoke job downloads the previous
main-branch artifact and runs this against the fresh one. Regressions are
**annotated, never failed** — the tool always exits 0 on a completed or
refused comparison (only usage errors exit non-zero), emitting GitHub
``::warning::`` lines for every tracked metric that moved more than
``--threshold`` (default 20%) in the bad direction.

Comparisons are only meaningful like-for-like, so both artifacts must carry
the ``meta`` block ``benchmarks/run.py`` stamps (git sha, jax version,
backend, smoke flag): a missing ``meta``, a backend mismatch (cpu vs gpu),
or a smoke-vs-full mismatch makes the tool REFUSE the comparison (printed
as ``SKIP``, still exit 0 — an absent or foreign baseline must not block
CI).

Tracked metrics are per-record by name within each suite's ``results`` list
(plus the nested ``traffic`` report inside ``BENCH_serving.json``):
lower-is-better wall times / latencies / shed rate, higher-is-better
throughput / occupancy / achieved kernel bandwidth (``achieved_gbps`` from
``BENCH_kernels.json``). Records or metrics present on only one side are
reported as informational, not warnings.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Tuple

#: metric name -> True if lower is better
TRACKED = {
    "us_per_call": True,
    "cold_us_per_request": True,
    "warm_us_per_request": True,
    "p50_ms": True,
    "p99_ms": True,
    "mean_ms": True,
    "shed_rate": True,
    "rows_per_s": False,
    "measured_rps": False,
    "occupancy": False,
    "achieved_gbps": False,
    "tracking_error": True,     # drift cells in BENCH_streaming.json
    "spectral_error": True,     # estimation/refinement cells — accuracy gate
    "chunks_per_sec": False,    # ingest overlap cells in BENCH_ingest.json
    "wire_bytes_per_state": True,   # compressed-wire cells — size gate
}


def _records(report: dict, prefix: str = "") -> Dict[str, dict]:
    """Flatten a report into {record path: record dict} over ``results``
    lists, following the nested ``traffic`` report if present."""
    out: Dict[str, dict] = {}
    for rec in report.get("results", ()):
        name = rec.get("name")
        if isinstance(name, str):
            out[f"{prefix}{name}"] = rec
    if isinstance(report.get("traffic"), dict):
        out.update(_records(report["traffic"], prefix=f"{prefix}traffic/"))
    return out


def check_meta(base: dict, cur: dict) -> Optional[str]:
    """The refusal reason if the two artifacts are not comparable."""
    mb, mc = base.get("meta"), cur.get("meta")
    if not isinstance(mb, dict) or not isinstance(mc, dict):
        return "missing meta block (re-run benchmarks/run.py to stamp one)"
    for field in ("backend", "smoke"):
        if mb.get(field) != mc.get(field):
            return (f"{field} mismatch: baseline={mb.get(field)!r} "
                    f"current={mc.get(field)!r}")
    return None


def compare(base: dict, cur: dict, threshold: float
            ) -> Tuple[List[str], List[str]]:
    """(regression warnings, informational lines) for two reports."""
    warnings: List[str] = []
    infos: List[str] = []
    brecs, crecs = _records(base), _records(cur)
    for path in sorted(set(brecs) | set(crecs)):
        if path not in brecs or path not in crecs:
            side = "baseline" if path in brecs else "current"
            infos.append(f"cell {path} only in {side}")
            continue
        for metric, lower_better in TRACKED.items():
            b, c = brecs[path].get(metric), crecs[path].get(metric)
            if not isinstance(b, (int, float)) or \
                    not isinstance(c, (int, float)):
                continue
            if b <= 0 or c <= 0:
                continue             # rates can legitimately be 0; no ratio
            worse = (c / b - 1.0) if lower_better else (b / c - 1.0)
            if worse > threshold:
                arrow = "rose" if lower_better else "fell"
                warnings.append(
                    f"{path}.{metric} {arrow} {worse * 100:.0f}% "
                    f"({b:.4g} -> {c:.4g})")
    return warnings, infos


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="previous main-branch BENCH_*.json")
    ap.add_argument("current", help="freshly produced BENCH_*.json")
    ap.add_argument("--threshold", type=float, default=0.2,
                    help="relative regression that triggers a warning")
    args = ap.parse_args(argv)

    try:
        with open(args.baseline) as f:
            base = json.load(f)
        with open(args.current) as f:
            cur = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"SKIP: unreadable artifact ({e})", flush=True)
        return 0

    reason = check_meta(base, cur)
    if reason is not None:
        print(f"SKIP: refusing comparison — {reason}", flush=True)
        return 0

    warnings, infos = compare(base, cur, args.threshold)
    for line in infos:
        print(f"note: {line}", flush=True)
    for line in warnings:
        print(f"::warning title=bench regression::{line}", flush=True)
    print(f"bench_compare: {len(warnings)} regression(s) over "
          f"{args.threshold * 100:.0f}% threshold "
          f"({base.get('meta', {}).get('git_sha', '?')[:12]} -> "
          f"{cur.get('meta', {}).get('git_sha', '?')[:12]})", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
