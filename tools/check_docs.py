"""Docs gate: run public-API doctests + resolve README/docs relative links.

    PYTHONPATH=src python tools/check_docs.py            # both checks
    PYTHONPATH=src python tools/check_docs.py --links-only

Doctests cover the public API surface (build_summary, estimate_product,
estimate_error/adaptive_rank, SketchService, StreamingSummarizer) — the
examples in those docstrings are
executable documentation and this is what keeps them honest. The link check
walks README.md and docs/**/*.md and fails on any relative link or image
whose target does not exist (http(s)/mailto/anchor links are skipped).
Run by the `docs` CI job and by tests/test_docs.py (links only).
"""
from __future__ import annotations

import argparse
import doctest
import importlib
import os
import re
import sys

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

DOCTEST_MODULES = (
    "repro.core.summary_engine",
    "repro.core.estimation_engine",
    "repro.core.error_engine",
    "repro.core.refinement",
    "repro.core.pipeline",
    "repro.core.streaming",
    "repro.dist.multihost",
    "repro.serve.engine",
    "repro.serve.scheduler",
    "repro.kernels.tuning",
)

_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")


def iter_markdown_files():
    """README.md plus every markdown file under docs/."""
    yield os.path.join(REPO, "README.md")
    docs = os.path.join(REPO, "docs")
    for dirpath, _, files in os.walk(docs):
        for f in sorted(files):
            if f.endswith(".md"):
                yield os.path.join(dirpath, f)


def check_links() -> list:
    """All broken relative links as (file, target) pairs."""
    broken = []
    for md in iter_markdown_files():
        with open(md) as f:
            text = f.read()
        for target in _LINK.findall(text):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = os.path.normpath(
                os.path.join(os.path.dirname(md), path))
            if not os.path.exists(resolved):
                broken.append((os.path.relpath(md, REPO), target))
    return broken


def run_doctests() -> int:
    """Total doctest failures across the public-API modules."""
    failures = 0
    for name in DOCTEST_MODULES:
        mod = importlib.import_module(name)
        result = doctest.testmod(mod, verbose=False)
        status = "ok" if result.failed == 0 else "FAIL"
        print(f"doctest {name}: {result.attempted} examples, "
              f"{result.failed} failures [{status}]", flush=True)
        failures += result.failed
    return failures


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--links-only", action="store_true",
                    help="skip doctests (no jax import)")
    args = ap.parse_args()

    broken = check_links()
    for md, target in broken:
        print(f"BROKEN LINK {md}: {target}", flush=True)
    n_md = len(list(iter_markdown_files()))
    print(f"link check: {n_md} files, {len(broken)} broken", flush=True)

    failures = 0 if args.links_only else run_doctests()
    return 1 if (broken or failures) else 0


if __name__ == "__main__":
    sys.exit(main())
